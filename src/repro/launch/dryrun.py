import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this driver:
  1. builds the step (train/prefill/decode) with ESP + per-arch shardings,
  2. `.lower(**input_specs(...))` with ShapeDtypeStruct stand-ins,
  3. `.compile()` on the 16×16 single-pod mesh and the 2×16×16 multi-pod mesh,
  4. records `memory_analysis()` (fits?), `cost_analysis()` (FLOPs/bytes) and
     the collective-byte census parsed from the compiled HLO (while-loop
     bodies are multiplied by their parsed trip counts) — the §Roofline terms.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch glm4-9b --shape prefill_32k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--out results.json]
"""

import argparse
import json
import sys
import time
import traceback
from typing import Any, Dict, Optional

import jax
import numpy as np


# TPU v5e constants (per chip) — roofline brief
PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9


def _collective_bytes_from_hlo(hlo_text: str) -> Dict[str, float]:
    """Sum operand bytes of collectives in compiled HLO, scaling ops inside
    while-loop bodies by the loop trip count."""
    from repro.launch.hlo import collective_census

    return collective_census(hlo_text)


def run_cell(
    arch: str,
    shape_name: str,
    *,
    multi_pod: bool = False,
    esp: bool = True,
    mesh=None,
    verbose: bool = True,
    options: Optional[Dict[str, Any]] = None,
) -> Dict[str, Any]:
    """options (hillclimb variants, EXPERIMENTS.md §Perf):
      ring_slice_tp: de-duplicated ring legs across tp (A2)
      kernel_adjusted: census excludes Pallas-kernel-resident attention
        intermediates (A1 — the paper's own custom-kernel configuration)
      ssm_chunk: override the recurrent chunk length (B)
      moe_capacity_factor: override MoE capacity (C)
    """
    from repro.configs import SHAPES, get_config, shape_applicable
    from repro.launch import steps as steps_lib
    from repro.launch.mesh import make_production_mesh
    from repro.launch.sharding import param_shardings, param_specs
    from jax.sharding import NamedSharding, PartitionSpec as P

    import dataclasses

    options = options or {}
    cfg = get_config(arch)
    for field in ("ssm_chunk", "moe_capacity_factor"):
        if field in options:
            cfg = dataclasses.replace(cfg, **{field: options[field]})
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "status": "skipped", "why": why}

    # bf16 attention dots for TPU-faithful memory accounting (see
    # models/attention.py: XLA:CPU would otherwise materialize f32 operand
    # converts that the MXU performs natively)
    from repro.models import attention as _attn

    _attn.set_dot_accum_f32(False)

    t0 = time.time()
    mesh = mesh or make_production_mesh(multi_pod=multi_pod)
    n_chips = int(np.prod(list(mesh.shape.values())))
    res: Dict[str, Any] = {
        "arch": arch,
        "shape": shape_name,
        "mesh": dict(mesh.shape),
        "chips": n_chips,
        "esp": esp,
        "options": dict(options),
    }
    try:
        specs = steps_lib.input_specs(cfg, shape, mesh)
        shards = steps_lib.input_shardings(cfg, shape, mesh)

        if shape.kind == "train":
            # gradient accumulation: 8 microbatches keeps per-layer activation
            # footprints inside HBM at global_batch=256 (see EXPERIMENTS.md)
            model, step = steps_lib.make_train_step(cfg, mesh, microbatches=8)
        elif shape.kind == "prefill":
            model, step = steps_lib.make_prefill_step(
                cfg, mesh, esp=esp,
                esp_opts={"ring_slice_tp": True} if options.get("ring_slice_tp") else None,
            )
        else:
            model, step = steps_lib.make_decode_step(cfg, mesh, esp=esp)

        params_shape = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        pspecs = param_shardings(cfg, mesh, params_shape,
                                 train=shape.kind == "train")

        with mesh:
            if shape.kind == "train":
                opt_shape = steps_lib.opt_state_shapes(params_shape)
                ospecs = steps_lib.opt_shardings(cfg, mesh, params_shape)
                lowered = jax.jit(
                    step,
                    in_shardings=(pspecs, ospecs, shards["batch"]),
                ).lower(params_shape, opt_shape, specs["batch"])
            elif shape.kind == "prefill":
                lowered = jax.jit(
                    step,
                    in_shardings=(
                        shards["batch"], shards["positions"], pspecs,
                    ),
                ).lower(specs["batch"], specs["positions"], params_shape)
            else:
                # the serving loop owns the cache buffers and re-donates them
                # every step (real decode loops alias in-place)
                lowered = jax.jit(
                    step,
                    in_shardings=(shards["tokens"], shards["cache"], pspecs),
                    donate_argnums=(1,),
                ).lower(specs["tokens"], specs["cache"], params_shape)

            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        res["lower_s"] = round(t_lower, 2)
        res["compile_s"] = round(t_compile, 2)
        res["memory"] = {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": (
                getattr(mem, "temp_size_in_bytes", 0)
                + getattr(mem, "argument_size_in_bytes", 0)
                + getattr(mem, "output_size_in_bytes", 0)
            ),
        }
        res["hbm_model"] = estimate_hbm(
            cfg, shape, mesh,
            getattr(mem, "argument_size_in_bytes", 0) or 0,
            getattr(mem, "output_size_in_bytes", 0) or 0,
        )
        # raw XLA numbers (NOTE: while-loop bodies counted ONCE — kept for
        # reference; the roofline uses the trip-count-expanded HLO census)
        res["cost_raw"] = {
            "flops": float(cost.get("flops", 0.0)) if cost else 0.0,
            "bytes_accessed": float(cost.get("bytes accessed", 0.0)) if cost else 0.0,
        }

        hlo = compiled.as_text()
        from repro.launch.hlo import hlo_census

        census = hlo_census(
            hlo,
            exclude_scope=options.get(
                "exclude_scope",
                "esp_partial_attention" if options.get("kernel_adjusted") else None,
            ),
        )
        census["total_bytes"] = census["collective_bytes"]
        res["collectives"] = {
            k: census[k]
            for k in (
                "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute", "total_bytes",
            )
        }
        flops = census["flops"]  # per-device, trip-count expanded
        bytes_acc = census["bytes"]
        res["cost"] = {"flops": flops, "bytes_accessed": bytes_acc}

        # ---- roofline terms (seconds), per device ----
        comp_t = flops / PEAK_FLOPS
        mem_t = bytes_acc / HBM_BW
        coll_bytes = census.get("total_bytes", 0.0)
        coll_t = coll_bytes / ICI_BW
        model_flops = model_flops_estimate(cfg, shape)
        res["roofline"] = {
            "compute_s": comp_t,
            "memory_s": mem_t,
            "collective_s": coll_t,
            "dominant": max(
                [("compute", comp_t), ("memory", mem_t), ("collective", coll_t)],
                key=lambda kv: kv[1],
            )[0],
            "model_flops_total": model_flops,
            "useful_flops_ratio": (
                model_flops / (flops * n_chips) if flops else None
            ),
        }
        res["status"] = "ok"
        if verbose:
            r = res["roofline"]
            print(
                f"[{arch} × {shape_name} × {n_chips}] OK "
                f"compute={r['compute_s']*1e3:.2f}ms memory={r['memory_s']*1e3:.2f}ms "
                f"collective={r['collective_s']*1e3:.2f}ms dominant={r['dominant']} "
                f"peak_mem={res['memory']['peak_bytes']/2**30:.2f}GiB "
                f"useful={r['useful_flops_ratio'] and round(r['useful_flops_ratio'],3)}"
            )
            print("  memory_analysis:", res["memory"])
            print("  cost_analysis: flops=%.3e bytes=%.3e" % (flops, bytes_acc))
    except Exception as e:  # noqa: BLE001
        res["status"] = "error"
        res["error"] = f"{type(e).__name__}: {e}"
        res["traceback"] = traceback.format_exc()[-2000:]
        if verbose:
            print(f"[{arch} × {shape_name}] FAIL: {res['error']}")
    return res


def estimate_hbm(cfg, shape, mesh, arg_bytes: int, out_bytes: int) -> Dict[str, float]:
    """TPU-HBM occupancy model (documented in EXPERIMENTS.md §Dry-run).

    XLA:CPU's memory_analysis() inflates `temp` with (a) copies of the
    parameters/cache into the temp arena (TPU keeps args in place), (b) f32
    conversion buffers for bf16 math (MXU-native on TPU) and (c) scheduler
    hoisting under an unbounded-memory model. The TPU estimate is:
      resident  = per-device argument bytes (params + cache) + outputs
      transient = the largest per-layer working set actually live at once
    """
    import numpy as np

    n_model = mesh.shape.get("model", 1)
    n_data = mesh.shape.get("data", 1)
    n_pod = mesh.shape.get("pod", 1)
    b, s = shape.global_batch, shape.seq_len
    d = cfg.d_model
    bl = max(b // (n_pod * n_data), 1)  # batch per device (batch-sharded dims)
    if shape.kind == "prefill":
        sl = max(s // n_data, 1)
        act = bl * sl * d * 2  # one [B_l, S_l, d] bf16 buffer
        score = bl * sl * min(sl, s) * max(cfg.n_heads // n_model, 1) * 4
        transient = 8 * act + score  # ~8 live activation buffers + scores
    elif shape.kind == "decode":
        s_kv = min(s, cfg.sliding_window or s)
        kv_slice = (s_kv // max(n_data * n_model, 1)) * cfg.n_kv_heads * cfg.head_dim * 4
        transient = 6 * bl * d * 2 + 3 * b * kv_slice  # few layers' kv slices
    else:  # train (8 microbatches, remat: per-layer carry + grads f32)
        mb = 8
        act = (bl // mb if bl >= mb else 1) * s * d * 2
        layer_carries = cfg.n_layers * act  # residual stream saved per layer
        transient = layer_carries + 10 * act
    return {
        "resident_bytes": float(arg_bytes + out_bytes),
        "transient_bytes": float(transient),
        "tpu_peak_bytes": float(arg_bytes + out_bytes + transient),
        "fits_16g": bool(arg_bytes + out_bytes + transient < 16 * 2**30),
    }


def model_flops_estimate(cfg, shape) -> float:
    """MODEL_FLOPS = 6·N·D (train) or 2·N·D (+ attention) for serving."""
    n_active = cfg.param_count(active_only=True)
    d_tokens = shape.tokens if shape.kind != "decode" else shape.global_batch
    base = (6 if shape.kind == "train" else 2) * n_active * d_tokens
    # attention term
    n_attn = cfg.n_attention_applications
    hd = cfg.n_heads * cfg.head_dim
    if shape.kind == "decode":
        kv = shape.seq_len if not cfg.sliding_window else min(
            shape.seq_len, cfg.sliding_window
        )
        attn = 2 * 2 * n_attn * hd * kv * shape.global_batch
    elif cfg.family == "ssm":
        attn = 0
    else:
        w = cfg.sliding_window or shape.seq_len
        attn = 2 * 2 * n_attn * hd * shape.global_batch * (
            shape.seq_len * min(w, shape.seq_len) / 2
        )
        attn *= 3 if shape.kind == "train" else 1
    return float(base + attn)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--no-esp", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    from repro.configs import ASSIGNED, SHAPES

    cells = []
    if args.all:
        for arch in ASSIGNED:
            for shape in SHAPES:
                cells.append((arch, shape))
    else:
        assert args.arch and args.shape, "--arch and --shape (or --all)"
        cells.append((args.arch, args.shape))

    results = []
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    for arch, shape in cells:
        for mp in meshes:
            results.append(
                run_cell(arch, shape, multi_pod=mp, esp=not args.no_esp)
            )
    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
        print(f"wrote {args.out}")
    n_err = sum(1 for r in results if r["status"] == "error")
    print(
        f"cells: {len(results)}  ok: {sum(1 for r in results if r['status']=='ok')} "
        f"skipped: {sum(1 for r in results if r['status']=='skipped')}  errors: {n_err}"
    )
    return 1 if n_err else 0


if __name__ == "__main__":
    sys.exit(main())

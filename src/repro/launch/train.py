"""Training driver: runs real train steps on CPU for a reduced config
(functional check of the train_step used by the dry-run's train_4k cells),
with checkpoint/restore.

  PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-4b --steps 20
"""
from __future__ import annotations

import argparse
import pickle
import sys
import time


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="lwm-7b")
    ap.add_argument("--steps", type=int, default=10)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--grad-compression", default=None, choices=[None, "int8"])
    ap.add_argument("--checkpoint", default=None)
    ap.add_argument("--resume", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import get_config, reduced
    from repro.launch import steps as steps_lib

    cfg = reduced(get_config(args.arch))
    model, train_step = steps_lib.make_train_step(
        cfg, None, lr=args.lr, grad_compression=args.grad_compression,
        remat=False, loss_chunk=64,
    )
    params = model.init(jax.random.PRNGKey(args.seed))
    opt = steps_lib.init_opt_state(params)
    start = 0
    if args.resume:
        with open(args.resume, "rb") as f:
            ckpt = pickle.load(f)
        params = jax.tree.map(jnp.asarray, ckpt["params"])
        opt = jax.tree.map(jnp.asarray, ckpt["opt"])
        start = ckpt["step"]
        print(f"resumed from {args.resume} at step {start}")

    step_jit = jax.jit(train_step)
    rng = np.random.default_rng(args.seed)
    b, s = args.batch, args.seq

    def make_batch():
        toks = rng.integers(0, cfg.vocab_size, (b, s + 1))
        batch = {
            "tokens": jnp.asarray(toks[:, :-1], jnp.int32),
            "labels": jnp.asarray(toks[:, 1:], jnp.int32),
        }
        if cfg.frontend == "patch_stub":
            batch["patch_embeds"] = jnp.asarray(
                rng.normal(size=(b, cfg.n_frontend_tokens, cfg.d_model)) * 0.05,
                jnp.dtype(cfg.dtype),
            )
            batch["labels"] = jnp.asarray(
                np.concatenate(
                    [np.full((b, cfg.n_frontend_tokens), -1), toks[:, 1:]], axis=1
                ),
                jnp.int32,
            )
        if cfg.frontend == "audio_stub":
            batch["frames"] = jnp.asarray(
                rng.normal(size=(b, cfg.encoder_seq, cfg.d_model)) * 0.05,
                jnp.dtype(cfg.dtype),
            )
        return batch

    # Sanity-check training is overfitting a FIXED batch: fresh iid-uniform
    # tokens every step have no learnable structure (optimal loss stays at
    # ln(vocab)), so the loss-decreases exit criterion would be a coin flip.
    batch = make_batch()
    t0 = time.time()
    losses = []
    for i in range(start, start + args.steps):
        params, opt, m = step_jit(params, opt, batch)
        losses.append(float(m["loss"]))
        print(f"step {i}: loss={losses[-1]:.4f} gnorm={float(m['grad_norm']):.3f}")
    dt = time.time() - t0
    print(f"{args.steps} steps in {dt:.1f}s; loss {losses[0]:.4f} -> {losses[-1]:.4f}")

    if args.checkpoint:
        with open(args.checkpoint, "wb") as f:
            pickle.dump(
                {
                    "params": jax.tree.map(np.asarray, params),
                    "opt": jax.tree.map(np.asarray, opt),
                    "step": start + args.steps,
                },
                f,
            )
        print(f"checkpointed to {args.checkpoint}")
    return 0 if losses[-1] < losses[0] else 1


if __name__ == "__main__":
    sys.exit(main())

"""Compiled-HLO census: FLOPs, bytes and collective traffic with while-loop
trip-count expansion.

XLA's `compiled.cost_analysis()` reports the while-loop *body* once, so a
scan-over-layers program under-counts by ~n_layers. This module walks the
compiled module's call graph (while bodies x their `known_trip_count`,
fusions, calls) and sums:

  * FLOPs: 2 · |output| · |contracted dims| per dot (matmul-dominated models;
    elementwise FLOPs are excluded — noted in EXPERIMENTS.md);
  * bytes: operand + output bytes per non-trivial op (HBM-traffic proxy:
    fusion boundaries are exactly where XLA materializes buffers);
  * collectives: per-device ICI traffic per op class with ring-algorithm
    scaling on the parsed replica-group size.
"""
from __future__ import annotations

import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "u64": 8,
}

_SHAPE_RE = re.compile(r"\b(\w+)\[([\d,]*)\]")
_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)
# ops whose operand/output bytes we count toward HBM traffic (buffers are
# materialized at these boundaries); pure reshapes/bitcasts/GTE excluded.
_BYTES_OPS = (
    "fusion", "dot", "convolution", "copy", "transpose", "concatenate",
    "dynamic-slice", "dynamic-update-slice", "gather", "scatter", "reduce",
    "broadcast", "iota", "sort", "pad", "slice", "select-and-scatter",
    "reduce-window", "cholesky", "triangular-solve", "convert",
) + _COLLECTIVES


def _shape_dims(tok: str) -> Tuple[str, List[int]]:
    m = _SHAPE_RE.match(tok.strip())
    if not m:
        return "f32", []
    dims = [int(d) for d in m.group(2).split(",") if d]
    return m.group(1), dims


def _shape_bytes_str(tok: str) -> int:
    dt, dims = _shape_dims(tok)
    n = 1
    for d in dims:
        n *= d
    return _DTYPE_BYTES.get(dt, 4) * n


def _all_shapes(line: str) -> List[str]:
    return [f"{m.group(1)}[{m.group(2)}]" for m in _SHAPE_RE.finditer(line)]


def _group_size(line: str, default: int = 1) -> int:
    m = re.search(r"replica_groups=\[([\d,]+)\]<=\[", line)
    if m:
        dims = [int(x) for x in m.group(1).split(",") if x]
        return dims[-1] if dims else default
    m = re.search(r"replica_groups=\{\{([^}]*)\}", line)
    if m:
        return max(len([x for x in m.group(1).split(",") if x.strip()]), 1)
    return default


def _split_computations(hlo: str) -> Dict[str, List[str]]:
    comps: Dict[str, List[str]] = {}
    cur: Optional[str] = None
    for line in hlo.splitlines():
        m = re.match(r"^\s*(ENTRY\s+)?%?([\w\.\-]+)\s*\(.*->.*\{\s*$", line)
        if m:
            cur = ("ENTRY " if m.group(1) else "") + m.group(2)
            comps[cur] = []
            continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is not None:
            comps[cur].append(line)
    return comps


_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(\([^=]*?\)|\S+)\s+([\w\-]+)\(")


def _parse_line(line: str):
    """(name, out_shape_str, opcode) or None."""
    m = _DEF_RE.match(line)
    if not m:
        return None
    return m.group(1), m.group(2), m.group(3)


def _dot_flops(line: str, out_shape: str, name_shapes: Dict[str, str]) -> float:
    _, out_dims = _shape_dims(out_shape)
    out_n = 1
    for d in out_dims:
        out_n *= d
    # two HLO text flavors: `dot(%lhs, %rhs)` (operand names only) and
    # `dot(f32[2,64]{1,0} %lhs, ...)` (inline operand shapes, newer XLA) —
    # prefer the inline shape, fall back to the name table
    mo = re.search(r"dot\(\s*(?:(\w+\[[\d,]*\])\S*\s+)?%?([\w\.\-]+)\s*,", line)
    mc = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", line)
    if not mo or not mc:
        return 2.0 * out_n  # degenerate
    lhs_shape = mo.group(1) or name_shapes.get(mo.group(2))
    if lhs_shape is None:
        return 2.0 * out_n
    _, lhs_dims = _shape_dims(lhs_shape)
    k = 1
    for idx in (int(x) for x in mc.group(1).split(",") if x):
        if idx < len(lhs_dims):
            k *= lhs_dims[idx]
    return 2.0 * out_n * k


def _tuple_bytes(out_shape: str) -> int:
    # "(f32[2,3], s32[4])" or single shape
    return sum(_shape_bytes_str(s) for s in _all_shapes(out_shape)) or 0


def hlo_census(hlo: str, exclude_scope: Optional[str] = None) -> Dict[str, float]:
    """exclude_scope: drop the HBM *bytes* of ops whose jax name-scope
    metadata contains this string (used for kernel-accounting: a Pallas
    flash kernel keeps those intermediates in VMEM). FLOPs and collectives
    still count."""
    comps = _split_computations(hlo)

    # call graph edges: (callee, multiplier, is_fusion). Ops INSIDE a fused
    # computation never touch HBM: their bytes are excluded (the fusion call
    # site's operand/output bytes are what's materialized), but their dot
    # FLOPs still count.
    edges: Dict[str, List[Tuple[str, float, bool]]] = {}
    for cname, lines in comps.items():
        for line in lines:
            if re.search(r"\bwhile\(", line):
                mb = re.search(r"body=%?([\w\.\-]+)", line)
                mt = re.search(r"known_trip_count[^0-9]*(\d+)", line)
                mc = re.search(r"condition=%?([\w\.\-]+)", line)
                trips = float(mt.group(1)) if mt else None
                if trips is None and mc:
                    trips = float(_cond_trip(comps.get(mc.group(1), [])))
                if mb:
                    edges.setdefault(cname, []).append(
                        (mb.group(1), trips or 1.0, False)
                    )
            else:
                mf = re.search(r"(?:calls|to_apply)=%?([\w\.\-]+)", line)
                if mf and ("fusion(" in line or re.search(r"\bcall\(", line)):
                    edges.setdefault(cname, []).append((mf.group(1), 1.0, True))

    # ops that don't produce fresh data: reading their "output" is reading a
    # loop-invariant / pass-through buffer
    _NON_COMPUTE = {"parameter", "get-tuple-element", "constant", "tuple",
                    "bitcast"}

    def direct(cname: str) -> Dict[str, float]:
        lines = comps.get(cname, [])
        name_shapes: Dict[str, str] = {}
        produced: set = set()  # names defined by actual compute in this comp
        for line in lines:
            p = _parse_line(line)
            if p:
                name_shapes[p[0]] = p[1]
                if p[2] not in _NON_COMPUTE:
                    produced.add(p[0])
        flops = 0.0
        bytes_ = 0.0  # per-trip traffic (multiplied by loop trip counts)
        once = 0.0  # loop-invariant operand reads (counted once: on TPU the
        # buffer streams from HBM once per loop — cache/VMEM resident after,
        # and for sliced stacked params trips x slice == the full array)
        coll = {c: 0.0 for c in _COLLECTIVES}
        coll_counts = {c: 0 for c in _COLLECTIVES}
        for line in lines:
            p = _parse_line(line)
            if not p:
                continue
            name, out_shape, opcode = p
            if opcode == "dot":
                flops += _dot_flops(line, out_shape, name_shapes)
            base = opcode.replace("-start", "")
            if base in _COLLECTIVES:
                shapes = _all_shapes(line)
                payload = max((_shape_bytes_str(s) for s in shapes), default=0)
                g = _group_size(line)
                if base == "all-reduce":
                    b = 2 * (g - 1) / max(g, 1) * payload
                elif base in ("all-gather", "reduce-scatter", "all-to-all"):
                    b = (g - 1) / max(g, 1) * payload
                else:
                    b = payload
                coll[base] += b
                coll_counts[base] += 1
            if opcode in _BYTES_OPS or base in _BYTES_OPS:
                # kernel accounting: a flash kernel still streams the dot
                # operands (q/kv/o) through HBM once, but its softmax
                # intermediates (scores/exp/mask/converts) live in VMEM
                if (
                    exclude_scope and opcode != "dot"
                    and any(sc in line for sc in exclude_scope.split(","))
                ):
                    continue
                bytes_ += _tuple_bytes(out_shape)
                for mo in re.finditer(r"%([\w\.\-]+)", line.split("=", 1)[1]):
                    s = name_shapes.get(mo.group(1))
                    if not s:
                        continue
                    if mo.group(1) in produced:
                        bytes_ += _shape_bytes_str(s)
                    else:
                        once += _shape_bytes_str(s)
        return {"flops": flops, "bytes": bytes_, "once": once, **coll,
                "_counts": coll_counts}

    memo: Dict[Tuple[str, bool], Dict[str, float]] = {}

    def total(cname: str, in_fusion: bool = False, depth=0) -> Dict[str, float]:
        key = (cname, in_fusion)
        if key in memo:
            return memo[key]
        if depth > 24:
            return {"flops": 0.0, "bytes": 0.0, **{c: 0.0 for c in _COLLECTIVES}}
        acc = direct(cname)
        if in_fusion:
            acc["bytes"] = 0.0
            acc["once"] = 0.0
        for callee, mult, fuse in edges.get(cname, []):
            sub = total(callee, in_fusion or fuse, depth + 1)
            for k in ("flops", "bytes", *_COLLECTIVES):
                acc[k] = acc.get(k, 0.0) + mult * sub.get(k, 0.0)
            # loop-invariant reads are NOT multiplied by trip counts
            acc["once"] = acc.get("once", 0.0) + sub.get("once", 0.0)
        memo[key] = acc
        return acc

    entry = next((c for c in comps if c.startswith("ENTRY ")), None)
    if entry is None and comps:
        entry = max(comps, key=lambda c: len(comps[c]))
    res = total(entry) if entry else {}
    out = {
        "flops": res.get("flops", 0.0),
        "bytes": res.get("bytes", 0.0) + res.get("once", 0.0),
        "bytes_per_trip": res.get("bytes", 0.0),
        "bytes_invariant": res.get("once", 0.0),
    }
    for c in _COLLECTIVES:
        out[c] = res.get(c, 0.0)
    out["collective_bytes"] = sum(out[c] for c in _COLLECTIVES)
    return out


def _cond_trip(cond_lines: List[str]) -> int:
    consts = {}
    for line in cond_lines:
        m = re.search(r"%?([\w\.\-]+)\s*=\s*s\d+\[\]\s*constant\((\d+)\)", line)
        if m:
            consts[m.group(1)] = int(m.group(2))
    for line in cond_lines:
        if "compare(" in line:
            for name, val in consts.items():
                if name in line:
                    return max(val, 1)
    return max(consts.values()) if consts else 1


# Backwards-compatible wrapper used by dryrun.py
def collective_census(hlo: str, n_devices_default: int = 1) -> Dict[str, float]:
    c = hlo_census(hlo)
    out = {k: c[k] for k in _COLLECTIVES}
    out["total_bytes"] = c["collective_bytes"]
    out["flops"] = c["flops"]
    out["bytes"] = c["bytes"]
    return out

"""Step builders + input specs for every (arch × shape × mesh) cell.

Three step kinds per the assigned shapes:
  * train_step  — loss (chunked CE over the vocab-sharded unembed) + grads +
                  sharded AdamW (ZeRO-1 over `data`), remat on the layer scan;
  * prefill_step — ESP striped-ring prefill; emits last-position logits + the
                  populated KV cache (the proactive-retention object);
  * decode_step — ESP multi-master decode; one token per request against the
                  token-granularity sharded cache; returns new KV for the
                  masters to append (the pool owns placement).

`input_specs` returns ShapeDtypeStruct stand-ins (weak-type-correct,
shardable, no allocation) and `input_shardings` the matching NamedShardings.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeSpec
from repro.core.esp import ESPAttnImpl
from repro.launch import sharding as shlib
from repro.models import build_model
from repro.models.transformer import Cache


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _ns(mesh, spec):
    return NamedSharding(mesh, spec)


def build_model_for(cfg: ModelConfig, mesh: Optional[Mesh], kind: str,
                    *, esp: bool = True, remat: bool = False,
                    dop: Optional[int] = None, esp_opts: Optional[dict] = None):
    """Model wired with ESP attention + sharding constraints for `kind`."""
    attn_impl = None
    constrain = None
    if mesh is not None:
        constrain = shlib.make_constrain(cfg, mesh, kind)
        if esp and kind in ("prefill", "decode") and "data" in mesh.axis_names:
            attn_impl = ESPAttnImpl(
                mesh, cfg, sp_axis="data",
                tp_axis="model" if "model" in mesh.axis_names else None,
                force_batch_mode=(cfg.family in ("hybrid", "ssm")),
                dop=dop, **(esp_opts or {}),
            )
    return build_model(cfg, attn_impl=attn_impl, constrain=constrain, remat=remat)


# ================================================================ input specs


def _batch_axes(mesh: Mesh, b: int, extra_model: bool = False):
    axes = []
    rem = b
    order = ["pod", "data", "model"] if extra_model else ["pod", "data"]
    for a in order:
        if a in mesh.axis_names and rem % mesh.shape[a] == 0:
            axes.append(a)
            rem //= mesh.shape[a]
    return tuple(axes) if axes else None


def _pod_axis(mesh: Mesh, b: int):
    if "pod" in mesh.axis_names and b % mesh.shape["pod"] == 0:
        return ("pod",)
    return None


def decode_cache_len(cfg: ModelConfig, shape: ShapeSpec) -> int:
    """KV tokens held at decode: SWA archs keep only the window."""
    s = shape.seq_len
    if cfg.sliding_window:
        s = min(s, cfg.sliding_window)
    # keep it shardable over data(16) x model(16)
    return max(s, 256)


def input_specs(cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh) -> Dict[str, Any]:
    """kwargs of ShapeDtypeStructs for the step of `shape.kind`."""
    b, s = shape.global_batch, shape.seq_len
    dt = jnp.dtype(cfg.dtype)
    if shape.kind == "train":
        batch: Dict[str, Any] = {
            "tokens": _sds((b, s), jnp.int32),
            "labels": _sds((b, s), jnp.int32),
        }
        if cfg.frontend == "patch_stub":
            n_img = cfg.n_frontend_tokens
            batch["tokens"] = _sds((b, s - n_img), jnp.int32)
            # labels span the full (image+text) sequence; image positions
            # carry -1 (masked out of the CE loss)
            batch["labels"] = _sds((b, s), jnp.int32)
            batch["patch_embeds"] = _sds((b, n_img, cfg.d_model), dt)
        if cfg.frontend == "audio_stub":
            batch["frames"] = _sds((b, cfg.encoder_seq, cfg.d_model), dt)
        return {"batch": batch}
    if shape.kind == "prefill":
        batch = {"tokens": _sds((b, s), jnp.int32)}
        if cfg.frontend == "patch_stub":
            n_img = cfg.n_frontend_tokens
            batch["tokens"] = _sds((b, s - n_img), jnp.int32)
            batch["patch_embeds"] = _sds((b, n_img, cfg.d_model), dt)
        if cfg.frontend == "audio_stub":
            batch["frames"] = _sds((b, cfg.encoder_seq, cfg.d_model), dt)
        return {"batch": batch, "positions": _sds((s,), jnp.int32)}
    # decode
    s_kv = decode_cache_len(cfg, shape)
    n_attn = cfg.n_attention_applications
    cache: Dict[str, Any] = {"length": _sds((b,), jnp.int32)}
    if n_attn:
        kv = _sds((n_attn, b, s_kv, cfg.n_kv_heads, cfg.head_dim), dt)
        cache["k"] = kv
        cache["v"] = kv
    if cfg.family == "hybrid":
        n_super = cfg.n_layers // cfg.hybrid_mamba_per_block
        m_per = cfg.hybrid_mamba_per_block
        d_in = cfg.ssm_expand * cfg.d_model
        nh = d_in // cfg.ssm_head_dim
        cache["ssm_h"] = _sds(
            (n_super, m_per, b, nh, cfg.ssm_head_dim, cfg.ssm_state), jnp.float32
        )
        cache["ssm_conv"] = _sds(
            (n_super, m_per, b, cfg.ssm_conv_width - 1, d_in + 2 * cfg.ssm_state),
            jnp.float32,
        )
    if cfg.family == "ssm":
        every = cfg.xlstm_slstm_every or (cfg.n_layers + 1)
        n_super = max(cfg.n_layers // every, 1)
        m_per = (cfg.n_layers // n_super) - 1
        d_in = int(cfg.xlstm_proj_factor * cfg.d_model)
        dh = d_in // cfg.n_heads
        h = cfg.n_heads
        cache["xl_c"] = _sds((n_super, m_per, b, h, dh, dh), jnp.float32)
        cache["xl_n"] = _sds((n_super, m_per, b, h, dh), jnp.float32)
        cache["xl_m"] = _sds((n_super, m_per, b, h), jnp.float32)
        cache["sl_c"] = _sds((n_super, b, d_in), jnp.float32)
        cache["sl_n"] = _sds((n_super, b, d_in), jnp.float32)
        cache["sl_h"] = _sds((n_super, b, d_in), jnp.float32)
        cache["sl_m"] = _sds((n_super, b, d_in), jnp.float32)
    if cfg.is_encoder_decoder:
        cache["cross_k"] = _sds(
            (cfg.n_layers, b, cfg.encoder_seq, cfg.n_kv_heads, cfg.head_dim), dt
        )
        cache["cross_v"] = cache["cross_k"]
    return {"tokens": _sds((b,), jnp.int32), "cache": cache}


def input_shardings(cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh) -> Dict[str, Any]:
    """NamedSharding tree matching input_specs."""
    b = shape.global_batch
    ba = _batch_axes(mesh, b)
    pod_b = _pod_axis(mesh, b)
    kd = shlib.kv_div(cfg, mesh)
    dhm = (not cfg.family in ("hybrid", "ssm")) and shlib.heads_mode(cfg, mesh) and kd

    def ns(spec):
        return _ns(mesh, spec)

    if shape.kind == "train":
        out: Dict[str, Any] = {
            "batch": {
                "tokens": ns(P(ba, None)),
                "labels": ns(P(ba, None)),
            }
        }
        if cfg.frontend == "patch_stub":
            out["batch"]["patch_embeds"] = ns(P(ba, None, None))
        if cfg.frontend == "audio_stub":
            out["batch"]["frames"] = ns(P(ba, None, None))
        return out
    if shape.kind == "prefill":
        out = {
            "batch": {"tokens": ns(P(pod_b, "data"))},
            "positions": ns(P("data")),
        }
        if cfg.frontend == "patch_stub":
            out["batch"]["patch_embeds"] = ns(P(pod_b, "data", None))
        if cfg.frontend == "audio_stub":
            out["batch"]["frames"] = ns(P(pod_b, None, None))
        return out
    # decode: multi-master masters over (pod, data); KV seq over data(+model)
    master_ax = ba
    cache: Dict[str, Any] = {"length": ns(P(None))}
    if cfg.n_attention_applications:
        if dhm:  # heads mode: seq over data, kv heads over model
            kv_spec = P(None, pod_b, "data", "model", None)
        else:  # seq over (data, model)
            kv_spec = P(None, pod_b, ("data", "model"), None, None)
        cache["k"] = ns(kv_spec)
        cache["v"] = ns(kv_spec)
    if cfg.family == "hybrid":
        cache["ssm_h"] = ns(P(None, None, master_ax))
        cache["ssm_conv"] = ns(P(None, None, master_ax))
    if cfg.family == "ssm":
        for key in ("xl_c", "xl_n", "xl_m"):
            cache[key] = ns(P(None, None, master_ax))
        for key in ("sl_c", "sl_n", "sl_h", "sl_m"):
            cache[key] = ns(P(None, master_ax))
    if cfg.is_encoder_decoder:
        cache["cross_k"] = ns(P(None, pod_b, None, None, None))
        cache["cross_v"] = cache["cross_k"]
    return {"tokens": ns(P(master_ax)), "cache": cache}


# ============================================================== cache adapt


def cache_from_flat(cfg: ModelConfig, flat: Dict[str, Any]) -> Cache:
    """Rebuild the model Cache object from the flat spec dict."""
    from repro.models import ssm as ssm_mod
    from repro.models import xlstm as xl_mod

    ssm_state = None
    if cfg.family == "hybrid":
        ssm_state = ssm_mod.SSMState(h=flat["ssm_h"], conv=flat["ssm_conv"])
    if cfg.family == "ssm":
        mst = xl_mod.MLSTMState(c=flat["xl_c"], n=flat["xl_n"], m=flat["xl_m"])
        sst = xl_mod.SLSTMState(
            c=flat["sl_c"], n=flat["sl_n"], h=flat["sl_h"], m=flat["sl_m"]
        )
        ssm_state = (mst, sst)
    return Cache(
        k=flat.get("k"),
        v=flat.get("v"),
        length=flat["length"],
        ssm=ssm_state,
        cross_k=flat.get("cross_k"),
        cross_v=flat.get("cross_v"),
    )


# ================================================================== steps


def make_prefill_step(cfg: ModelConfig, mesh: Mesh, *, esp: bool = True,
                      dop: Optional[int] = None,
                      esp_opts: Optional[dict] = None):
    model = build_model_for(cfg, mesh, "prefill", esp=esp, dop=dop,
                            esp_opts=esp_opts)

    def prefill_step(batch, positions, params):
        logits, cache = model.prefill(
            params, batch, positions, last_logit_only=True
        )
        next_token = jnp.argmax(logits[:, -1], axis=-1)
        return next_token, cache

    return model, prefill_step


def make_decode_step(cfg: ModelConfig, mesh: Mesh, *, esp: bool = True,
                     dop: Optional[int] = None):
    model = build_model_for(cfg, mesh, "decode", esp=esp, dop=dop)

    def decode_step(tokens, cache, params):
        cache_obj = cache_from_flat(cfg, cache)
        logits, new_cache, kvs = model.decode(params, tokens, cache_obj)
        next_token = jnp.argmax(logits, axis=-1)
        out = {"next_token": next_token, "length": new_cache.length}
        if kvs is not None:
            out["new_k"], out["new_v"] = kvs
        if new_cache.ssm is not None and cfg.family == "hybrid":
            out["ssm_h"] = new_cache.ssm.h
            out["ssm_conv"] = new_cache.ssm.conv
        elif new_cache.ssm is not None and cfg.family == "ssm":
            mst, sst = new_cache.ssm
            out.update(xl_c=mst.c, xl_n=mst.n, xl_m=mst.m,
                       sl_c=sst.c, sl_n=sst.n, sl_h=sst.h, sl_m=sst.m)
        return out

    return model, decode_step


# ------------------------------------------------------------------ training


def init_opt_state(params):
    mk = lambda: jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return {"m": mk(), "v": mk(), "step": jnp.zeros((), jnp.int32)}


def opt_state_shapes(params_shape):
    zeros = jax.tree.map(
        lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32), params_shape
    )
    return {"m": zeros, "v": zeros, "step": jax.ShapeDtypeStruct((), jnp.int32)}


def zero1_specs(param_spec_tree, params_shape, mesh: Mesh):
    """ZeRO-1: shard optimizer moments over `data` on the first dim that is
    unsharded and divisible (falls back to the param's own sharding). Each
    data-rank then owns 1/|data| of the moments; the post-update all-gather
    of params is the classic ZeRO-1 collective."""
    dsz = mesh.shape.get("data", 1)

    def one(spec: P, shp):
        dims = list(spec) + [None] * (len(shp.shape) - len(spec))

        def used(ax):
            for d in dims:
                if d == ax or (isinstance(d, tuple) and ax in d):
                    return True
            return False

        if "data" in mesh.axis_names and not used("data"):
            for i, (d, cur) in enumerate(zip(shp.shape, dims)):
                if cur is None and d % dsz == 0 and d >= dsz:
                    dims[i] = "data"
                    break
        return P(*dims)

    return jax.tree.map(
        one, param_spec_tree, params_shape,
        is_leaf=lambda x: isinstance(x, P),
    )


def opt_shardings(cfg, mesh: Mesh, params_shape):
    """Full opt-state sharding tree {m, v, step}."""
    from repro.launch.sharding import param_specs
    from jax.sharding import NamedSharding

    z = jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        zero1_specs(param_specs(cfg, mesh, params_shape, train=True),
                    params_shape, mesh),
        is_leaf=lambda x: isinstance(x, P),
    )
    return {"m": z, "v": jax.tree.map(lambda x: x, z),
            "step": NamedSharding(mesh, P())}


def make_train_step(cfg: ModelConfig, mesh: Optional[Mesh], *, lr: float = 3e-4,
                    wd: float = 0.01, loss_chunk: int = 1024,
                    grad_compression: Optional[str] = None,
                    remat: bool = True, microbatches: int = 1):
    model = build_model_for(cfg, mesh, "train", esp=False, remat=remat)

    def loss_fn(params, batch):
        x, aux = model.hidden(params, batch)
        labels = batch["labels"]
        b, s, d = x.shape
        chunk = min(loss_chunk, s)
        pad = (-s) % chunk
        if pad:
            x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
            labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
        nc = (s + pad) // chunk
        xc = x.reshape(b, nc, chunk, d).swapaxes(0, 1)
        lc = labels.reshape(b, nc, chunk).swapaxes(0, 1)

        def chunk_nll(carry, inp):
            xx, ll = inp
            logits = model.unembed(params, xx)  # [B, chunk, V] f32
            logz = jax.scipy.special.logsumexp(logits, axis=-1)
            ll_safe = jnp.maximum(ll, 0)
            tok_logit = jnp.take_along_axis(
                logits, ll_safe[..., None], axis=-1
            )[..., 0]
            nll = jnp.where(ll >= 0, logz - tok_logit, 0.0)
            cnt = jnp.sum(ll >= 0)
            return carry, (jnp.sum(nll), cnt)

        _, (nlls, cnts) = jax.lax.scan(chunk_nll, 0.0, (xc, lc))
        loss = jnp.sum(nlls) / jnp.maximum(jnp.sum(cnts), 1)
        return loss + 0.01 * aux, (loss, aux)

    def compress(g):
        if grad_compression != "int8":
            return g

        def q(x):
            if x.dtype not in (jnp.float32, jnp.bfloat16):
                return x
            scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-8) / 127.0
            xi = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
            return (xi.astype(x.dtype) * scale).astype(x.dtype)

        return jax.tree.map(q, g)

    b1, b2, eps = 0.9, 0.95, 1e-8

    def train_step(params, opt_state, batch):
        if microbatches > 1:
            # gradient accumulation: batch-major split keeps each microbatch
            # contiguous in (and sharded like) the global batch dim
            def split(a):
                b = a.shape[0]
                return a.reshape(microbatches, b // microbatches, *a.shape[1:])

            mbatches = jax.tree.map(split, batch)

            def acc_body(carry, mb):
                g_acc, l_acc, a_acc = carry
                (_, (l, a)), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, mb
                )
                g_acc = jax.tree.map(
                    lambda x, y: x + y.astype(jnp.float32), g_acc, g
                )
                return (g_acc, l_acc + l, a_acc + a), None

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            (grads, loss, aux), _ = jax.lax.scan(
                acc_body, (g0, jnp.float32(0.0), jnp.float32(0.0)), mbatches
            )
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            loss, aux = loss / microbatches, aux / microbatches
        else:
            (_, (loss, aux)), grads = jax.value_and_grad(
                loss_fn, has_aux=True
            )(params, batch)
        grads = compress(grads)
        step = opt_state["step"] + 1
        sf = step.astype(jnp.float32)
        bc1 = 1.0 - b1**sf
        bc2 = 1.0 - b2**sf

        def upd(p, g, m, v):
            gf = g.astype(jnp.float32)
            m_new = b1 * m + (1 - b1) * gf
            v_new = b2 * v + (1 - b2) * gf * gf
            u = (m_new / bc1) / (jnp.sqrt(v_new / bc2) + eps)
            p_new = p.astype(jnp.float32) - lr * (u + wd * p.astype(jnp.float32))
            return p_new.astype(p.dtype), m_new, v_new

        out = jax.tree.map(upd, params, grads, opt_state["m"], opt_state["v"])
        leaves, treedef = jax.tree.flatten(out, is_leaf=lambda x: isinstance(x, tuple))
        new_params = jax.tree.unflatten(treedef, [l[0] for l in leaves])
        new_m = jax.tree.unflatten(treedef, [l[1] for l in leaves])
        new_v = jax.tree.unflatten(treedef, [l[2] for l in leaves])
        gnorm = jnp.sqrt(
            sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                for g in jax.tree.leaves(grads))
        )
        return new_params, {"m": new_m, "v": new_v, "step": step}, {
            "loss": loss, "aux": aux, "grad_norm": gnorm,
        }

    return model, train_step

"""Per-architecture sharding rules (DESIGN.md §3 mesh mapping).

Axes: `data` = ESP sequence parallelism between elastic instances;
`model` = intra-instance tensor parallelism; `pod` = replica axis.

Head-divisibility decides attention sharding (heads-mode vs batch-mode);
MoE experts shard over `model` (+ expert-hidden over `data` for arctic's
128 experts, which cannot replicate across `data`). Recurrent-layer weights
(mamba/xlstm) replicate — their compute parallelism is batch/sequence.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig

# §Perf experiment C1 (arctic): shard the MoE grouped-capacity dim over `data`
# so expert-TP contraction psums shrink by the data-axis width.
MOE_GROUP_C_OVER_DATA = False


def axes_of(mesh: Mesh) -> Dict[str, Optional[str]]:
    names = mesh.axis_names
    return {
        "pod": "pod" if "pod" in names else None,
        "data": "data" if "data" in names else None,
        "model": "model" if "model" in names else None,
    }


def tp_size(mesh: Mesh) -> int:
    return mesh.shape.get("model", 1)


def heads_mode(cfg: ModelConfig, mesh: Mesh) -> bool:
    tp = tp_size(mesh)
    return tp == 1 or cfg.n_heads % tp == 0


def kv_div(cfg: ModelConfig, mesh: Mesh) -> bool:
    tp = tp_size(mesh)
    return tp == 1 or cfg.n_kv_heads % tp == 0


def _div(n: int, mesh: Mesh, axis: Optional[str]) -> bool:
    return axis is not None and n % mesh.shape[axis] == 0


# ===================================================== parameter shardings


def param_specs(cfg: ModelConfig, mesh: Mesh, params_shape, train: bool = False) -> Any:
    """PartitionSpec tree matching `params_shape` (an eval_shape of init).

    train=True replicates the embedding table: the SPMD partitioner cannot
    handle the take-grad (scatter-add) against a d-sharded table inside the
    microbatch loop, and the moments stay ZeRO-sharded over `data` anyway."""
    hm = heads_mode(cfg, mesh)
    kd = kv_div(cfg, mesh)
    tp = tp_size(mesh)
    arctic_ep = cfg.n_experts > 0 and _div(cfg.n_experts, mesh, "model")

    def rule(path, leaf) -> P:
        names = [
            getattr(p, "key", getattr(p, "name", "")) for p in path
        ]
        key = names[-1] if names else ""
        shape = leaf.shape
        nd = len(shape)
        lead = nd  # count leading stacked dims to left-pad specs
        def pad(spec_tail):
            return P(*([None] * (nd - len(spec_tail)) + list(spec_tail)))

        # ---- attention ----
        if key in ("wq",):  # [.., d, H, dh]
            return pad([None, "model", None]) if hm else P()
        if key in ("wk", "wv"):
            return pad([None, "model", None]) if (hm and kd) else P()
        if key in ("bq",):
            return pad(["model", None]) if hm else P()
        if key in ("bk", "bv"):
            return pad(["model", None]) if (hm and kd) else P()
        if key == "wo":  # [.., H, dh, d]
            return pad(["model", None, None]) if hm else P()
        # ---- ffn ----
        if key in ("w_gate", "w_up", "w_down") and "moe" in names:
            f_axis_ok = _div(cfg.d_ff, mesh, "data")
            if arctic_ep:
                if key == "w_down":  # [.., E, f, d]
                    return pad(["model", "data" if f_axis_ok else None, None])
                return pad(["model", None, "data" if f_axis_ok else None])
            # few experts: TP inside each expert
            if key == "w_down":  # [.., E, f, d]
                return pad([None, "model", None])
            return pad([None, None, "model"])  # [.., E, d, f]
        if key in ("w_gate", "w_up"):  # [.., d, f]
            f = shape[-1]
            return pad([None, "model"]) if f % tp == 0 else P()
        if key == "w_down":  # [.., f, d]
            f = shape[-2]
            return pad(["model", None]) if f % tp == 0 else P()
        if key == "router":
            return P()
        # ---- embeddings ----
        if key == "embed":
            if train:
                return P()
            big = int(np.prod(shape)) * 2 > 1_000_000_000
            return P(None, "model") if (big and shape[1] % tp == 0) else P()
        if key == "lm_head":
            return P(None, "model") if shape[1] % tp == 0 else P()
        if key == "pos_embed":
            return P()
        # recurrent cells / norms / everything else: replicated
        return P()

    return jax.tree_util.tree_map_with_path(rule, params_shape)


def param_shardings(cfg: ModelConfig, mesh: Mesh, params_shape,
                    train: bool = False) -> Any:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        param_specs(cfg, mesh, params_shape, train=train),
        is_leaf=lambda x: isinstance(x, P),
    )


# ===================================================== activation constrain


def make_constrain(cfg: ModelConfig, mesh: Mesh, kind: str) -> Callable:
    """constrain(x, tag) for the model builders. kind: train|prefill|decode."""
    ax = axes_of(mesh)
    pod, data, model = ax["pod"], ax["data"], ax["model"]
    hm = heads_mode(cfg, mesh)
    recurrent = cfg.family in ("hybrid", "ssm")
    arctic_ep = cfg.n_experts > 0 and _div(cfg.n_experts, mesh, "model")

    def batch_axes(b: int, extra_model: bool = False):
        """Largest divisible prefix of (pod, data[, model]) for a batch dim."""
        axes = []
        rem = b
        for a in ([pod, data, model] if extra_model else [pod, data]):
            if a and rem % mesh.shape[a] == 0:
                axes.append(a)
                rem //= mesh.shape[a]
        return tuple(axes) if axes else None

    def cspec(x, spec):
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

    def constrain(x, tag: str):
        shp = x.shape
        if tag == "act":
            if kind == "train":
                return cspec(x, P(batch_axes(shp[0]), None, None))
            if kind == "prefill":
                # recurrent archs keep batch over model (cells are batch-
                # parallel); attention archs keep acts seq-sharded only
                if recurrent:
                    ba = batch_axes(shp[0], extra_model=True)
                    # batch gets pod(+model); seq over data
                    ba = tuple(a for a in (ba or ()) if a != data) or None
                    return cspec(x, P(ba, data, None))
                ba = batch_axes(shp[0])
                ba = tuple(a for a in (ba or ()) if a != data) or None
                return cspec(x, P(ba, data, None))
            # decode acts [B, 1, d]: masters = batch over (pod, data)
            return cspec(x, P(batch_axes(shp[0]), None, None))
        if tag in ("q", "kv", "attn_out") and kind in ("train",):
            if hm:
                hax = model if (tag != "kv" or kv_div(cfg, mesh)) else None
                return cspec(x, P(batch_axes(shp[0]), None, hax, None))
            ba = batch_axes(shp[0], extra_model=True)
            return cspec(x, P(ba, None, None, None))
        if tag in ("q", "kv", "attn_out") and kind == "prefill":
            # the ESP shard_map in_specs do the resharding; only pin the seq
            # axis so XLA doesn't gather the whole sequence
            if hm:
                ba = batch_axes(shp[0])
                ba = tuple(a for a in (ba or ()) if a != data) or None
                hax = model if (tag != "kv" or kv_div(cfg, mesh)) else None
                return cspec(x, P(ba, data, hax, None))
            return x
        if tag == "moe_group":  # [E, C, d]
            if arctic_ep:
                c_ax = data if MOE_GROUP_C_OVER_DATA else None
                return cspec(x, P(model, c_ax, None))
            return cspec(x, P(None, batch_axes(shp[1]) or data, None))
        if tag == "moe_hidden":  # [E, C, f]
            if arctic_ep:
                if MOE_GROUP_C_OVER_DATA:
                    return cspec(x, P(model, data, None))
                return cspec(x, P(model, None, "data" if _div(cfg.d_ff, mesh, "data") else None))
            return cspec(x, P(None, batch_axes(shp[1]) or data, model if cfg.d_ff % tp_size(mesh) == 0 else None))
        if tag == "logits":
            v = shp[-1]
            vs = model if v % tp_size(mesh) == 0 else None
            if kind == "train":
                return cspec(x, P(batch_axes(shp[0]), None, vs))
            if x.ndim == 3:
                if recurrent:
                    ba = batch_axes(shp[0], extra_model=True)
                    ba = tuple(a for a in (ba or ()) if a != data) or None
                    return cspec(x, P(ba, data, vs if not (ba and model in ba) else None))
                ba = batch_axes(shp[0])
                ba = tuple(a for a in (ba or ()) if a != data) or None
                return cspec(x, P(ba, data, vs))
            return cspec(x, P(batch_axes(shp[0]), vs))
        if tag == "enc_act":  # whisper encoder [B, 1500, d]
            return cspec(x, P(batch_axes(shp[0]), None, None))
        if tag == "enc_out":
            # encoder output feeds seq-sharded decoder cross-attn: replicate
            # across `data` (37 MB — cheaper than per-layer resharding)
            ba = (pod,) if (pod and shp[0] % mesh.shape[pod] == 0) else None
            return cspec(x, P(ba, None, None))
        return x

    return constrain

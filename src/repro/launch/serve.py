"""End-to-end serving driver (the paper's kind of system => serving driver).

Runs the LoongServe engine over a synthetic workload, in `sim` mode (SIB
clock; paper-scale) or `real` mode (reduced model actually generating tokens
through the distributed pools).

  PYTHONPATH=src python -m repro.launch.serve --arch lwm-7b --dataset mixed \
      --rate 0.5 --n 64 --system loongserve
  PYTHONPATH=src python -m repro.launch.serve --real --n 8 --dataset sharegpt
"""
from __future__ import annotations

import argparse
import json
import sys


def build_engine(system: str, cfg, n_instances: int, capacity: int, **kw):
    from repro.baselines import (
        ChunkedPrefillEngine,
        FixedGroupsEngine,
        PDDisaggEngine,
        StaticTPEngine,
    )
    from repro.engine.server import LoongServeEngine

    if system == "loongserve":
        return LoongServeEngine(cfg, n_instances, capacity, **kw)
    if system == "vllm-tp":
        return StaticTPEngine(cfg, n_instances, capacity, **kw)
    if system == "chunked":
        return ChunkedPrefillEngine(cfg, n_instances, capacity, **kw)
    if system == "pd-disagg":
        return PDDisaggEngine(cfg, n_instances, capacity, **kw)
    if system == "replicated":
        groups = [[i] for i in range(n_instances)]
        return FixedGroupsEngine(cfg, n_instances, capacity, groups=groups, **kw)
    raise ValueError(system)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="lwm-7b")
    ap.add_argument("--system", default="loongserve",
                    choices=["loongserve", "vllm-tp", "chunked", "pd-disagg",
                             "replicated"])
    ap.add_argument("--dataset", default="mixed",
                    choices=["sharegpt", "leval", "lveval", "mixed"])
    ap.add_argument("--rate", type=float, default=0.5)
    ap.add_argument("--n", type=int, default=64)
    ap.add_argument("--instances", type=int, default=8)
    ap.add_argument("--capacity", type=int, default=250_000)
    ap.add_argument("--real", action="store_true",
                    help="reduced model, real token generation on CPU")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", action="store_true")
    args = ap.parse_args(argv)

    from repro.configs import get_config, reduced
    from repro.data import poisson_workload, with_prompts

    cfg = get_config(args.arch)
    kw = {}
    if args.real:
        import jax

        from repro.models import build_model

        cfg = reduced(cfg)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(args.seed))
        kw = dict(store_values=True, model=model, params=params)
        capacity = 4096
        reqs = poisson_workload(args.dataset, args.n, args.rate,
                                seed=args.seed, max_len=256)
        for r in reqs:
            r.max_new_tokens = min(r.max_new_tokens, 16)
        with_prompts(reqs, cfg.vocab_size, args.seed)
    else:
        capacity = args.capacity
        reqs = poisson_workload(args.dataset, args.n, args.rate, seed=args.seed)

    eng = build_engine(args.system, cfg, args.instances, capacity, **kw)
    for r in reqs:
        eng.submit(r)
    metrics = eng.run()
    summary = metrics.summary()
    if args.json:
        print(json.dumps(summary, indent=1))
    else:
        print(f"=== {args.system} on {args.dataset} (rate {args.rate}) ===")
        for k, v in summary.items():
            print(f"  {k:28s} {v}")
        if args.real and metrics.finished:
            r0 = metrics.finished[0]
            print(f"  sample output tokens: {r0.output_tokens[:8]}")
    return 0


if __name__ == "__main__":
    sys.exit(main())

"""Launch layer: production mesh, sharding rules, steps, multi-pod dry-run."""

"""Production mesh construction.

Single pod: (16, 16) = ("data", "model") — 256 chips. `data` is the ESP
sequence-parallel axis between elastic instances; `model` is intra-instance
tensor parallelism (DESIGN.md §3).
Multi-pod: (2, 16, 16) = ("pod", "data", "model") — 512 chips; `pod` is a
pure replica/data axis (ESP rings never cross pods; ICI stays intra-pod).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(data: int = 4, model: int = 2, pod: int = 0):
    """Small host-device mesh for CPU tests (XLA_FLAGS device count)."""
    if pod:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))

"""Multi-master paged decode: the model-side plug for the paged kernel.

LoongServe §4.2 decodes with elastic instances: each master broadcasts its
query, every instance computes an unnormalized partial over the KV shard it
holds, and the master LSE-merges the partials.  `PagedDecodeAttnImpl` is that
dataflow expressed through the model's pluggable `attn_impl` seam: per layer
it issues exactly ONE `ops.paged_decode_partial` launch per instance — over
the instance's pool storage in place, routed by per-request block tables —
then merges the per-instance partials with the new token's own KV partial.
No dense per-request gather, and launch count is independent of batch size.

The impl subclasses `DefaultAttnImpl`, so outside a `begin_step`/`end_step`
window (e.g. prefill, or oracle-style dense decode with an explicit cache) it
behaves exactly like the default dense math.
"""
from __future__ import annotations

from typing import List, NamedTuple, Optional

import jax.numpy as jnp

from repro.kernels import ops
from repro.models import attention as attn
from repro.models.transformer import DefaultAttnImpl


class PagedShard(NamedTuple):
    """One instance's share of a decode batch.

    k_pages/v_pages: [n_attn, n_pages, P, KVH, D] device mirror of the
    instance's pool storage; table/lengths: that pool's block table for the
    batch; pos: [n_pages, P] global position per slot — only needed (and
    only uploaded) for sliding-window masking."""

    k_pages: jnp.ndarray
    v_pages: jnp.ndarray
    table: jnp.ndarray
    lengths: jnp.ndarray
    pos: Optional[jnp.ndarray] = None


class PagedDecodeAttnImpl(DefaultAttnImpl):
    """Batched paged decode attention across elastic instances."""

    def __init__(self, impl: Optional[str] = None):
        self._shards: Optional[List[PagedShard]] = None
        self._layer = 0
        self._impl = impl  # kernel impl override (None -> ops default)

    def begin_step(self, shards: List[PagedShard]) -> None:
        """Arm the paged path for one decode iteration.  decode_attn is
        called once per layer in stack order; the layer cursor indexes the
        per-layer storage planes."""
        self._shards = shards
        self._layer = 0

    def end_step(self) -> None:
        self._shards = None

    def decode_attn(self, q, k_cache, v_cache, k_new, v_new, cache_len, *,
                    window, softcap):
        if self._shards is None or k_cache is not None:
            return super().decode_attn(
                q, k_cache, v_cache, k_new, v_new, cache_len,
                window=window, softcap=softcap,
            )
        li = self._layer
        self._layer += 1
        b = q.shape[0]
        # the query's global position == cached token count (its own KV is
        # k_new, merged below) — window predicate qp - kp < window
        qpos = jnp.broadcast_to(jnp.asarray(cache_len), (b,)).astype(jnp.int32)
        part = attn.partial_attention(q, k_new, v_new, None, softcap=softcap)
        # the master device the per-shard partials return to (the paper's
        # "send back partial results"): pool mirrors bound to their own
        # data-shard devices (mesh executor) compute each partial in place
        # over the shard and only the tiny (o, m, l) rides home for the
        # LSE-merge.  Single-device pools skip the transfer entirely.
        def _dev(x):
            try:  # concrete arrays only — tracers have no .devices()
                return next(iter(x.devices()))
            except Exception:
                return None

        home = _dev(q)
        for s in self._shards:
            sdev = _dev(s.k_pages)
            q_s, qpos_s = q, qpos
            if home is not None and sdev is not None and sdev != home:
                # the q broadcast: ship the tiny query (and its positions)
                # to the shard's device so the partial computes WHERE the KV
                # stripe lives
                import jax

                q_s = jax.device_put(q, sdev)
                qpos_s = jax.device_put(qpos, sdev)
            p = ops.paged_decode_partial(
                q_s, s.k_pages[li], s.v_pages[li], s.table, s.lengths, s.pos,
                query_pos=qpos_s, window=window, softcap=softcap,
                impl=self._impl,
            )
            if home is not None and sdev is not None and sdev != home:
                # only the tiny (o, m, l) partial rides back to the master
                import jax

                p = attn.Partial(*(jax.device_put(x, home) for x in p))
            part = attn.merge_partial(part, p)
        return attn.finalize_partial(part).astype(q.dtype)

"""Multi-master paged decode: the model-side plug for the paged kernel.

LoongServe §4.2 decodes with elastic instances: each master broadcasts its
query, every instance computes an unnormalized partial over the KV shard it
holds, and the master LSE-merges the partials.  `PagedDecodeAttnImpl` is that
dataflow expressed through the model's pluggable `attn_impl` seam: per layer
it issues exactly ONE `ops.paged_decode_partial` launch per instance — over
the instance's pool storage in place, routed by per-request block tables —
then merges the per-instance partials with the new token's own KV partial.
No dense per-request gather, and launch count is independent of batch size.

Two merge deployments behind the same arming call, mirroring the prefill
impl's ring split:

  * per-shard loop (default): partials are merged sequentially in Python;
    under per-device pool mirrors the query ships out to each shard's device
    and only the tiny (o, m, l) partial rides home (both transfers counted
    in `ops.comm_bytes`).  Every merge is a host-driven sync point.
  * SPMD (``mesh=``, the mesh executor): the layer's merge runs as ONE
    shard_map region over the mesh's "data" axis — each rank's pool mirror
    is the local shard of the sharded paged operand, and the LSE-merge is a
    `pmax`+`psum` on the weighted (o·exp(m-M), l·exp(m-M)) accumulator
    (`core.esp.paged_decode_spmd`), schedulable by XLA against independent
    compute unless ``overlap=False`` pins it behind a barrier.
  * in-program batch-sharded (``axis_name=``, armed INSIDE the whole-
    iteration shard_map body of `core.esp.paged_decode_iteration_spmd`):
    each rank runs the non-attention stack for only its B/n batch slice and
    the per-layer boundary is all_gather(q-slice) in / psum_scatter of the
    LSE-merged output back to batch shards (LoongServe §4.2 multi-master).

The impl subclasses `DefaultAttnImpl`, so outside a `begin_step`/`end_step`
window (e.g. prefill, or oracle-style dense decode with an explicit cache) it
behaves exactly like the default dense math.
"""
from __future__ import annotations

from typing import List, NamedTuple, Optional, Union

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.models import attention as attn
from repro.models.transformer import DefaultAttnImpl


class PagedShard(NamedTuple):
    """One instance's share of a decode batch (per-shard loop mode).

    k_pages/v_pages: [n_attn, n_pages, P, KVH, D] device mirror of the
    instance's pool storage; table/lengths: that pool's block table for the
    batch; pos: [n_pages, P] global position per slot — only needed (and
    only uploaded) for sliding-window masking."""

    k_pages: jnp.ndarray
    v_pages: jnp.ndarray
    table: jnp.ndarray
    lengths: jnp.ndarray
    pos: Optional[jnp.ndarray] = None


class SpmdPagedShards(NamedTuple):
    """The whole group's shards as ONE mesh-sharded operand set (SPMD mode):
    leading axis = data rank, each rank's slice aliasing its own pool mirror
    (`KVPool.device_paged_kv` + `jax.make_array_from_single_device_arrays`
    assembly in the mesh executor — zero KV movement).

    k_pages/v_pages: [n, n_attn, n_pages, P, KVH, D]; table
    [n, B, max_pages]; lengths [n, B]; pos [n, n_pages, P] (window only)."""

    k_pages: jnp.ndarray
    v_pages: jnp.ndarray
    table: jnp.ndarray
    lengths: jnp.ndarray
    pos: Optional[jnp.ndarray] = None


def _ship(x, dev, key: str):
    """`jax.device_put` with comm accounting: the per-shard loop's explicit
    cross-device hops (q broadcast out, partial home) stay visible to
    benchmarks via `ops.comm_bytes[key]` — shapes are concrete here, so the
    byte count is exact."""
    ops.count_transfer(key, x)
    return jax.device_put(x, dev)


def _dev(x):
    try:  # concrete arrays only — tracers have no .devices()
        return next(iter(x.devices()))
    except Exception:
        return None


class PagedDecodeAttnImpl(DefaultAttnImpl):
    """Batched paged decode attention across elastic instances."""

    def __init__(self, impl: Optional[str] = None):
        self._shards: Optional[
            Union[List[PagedShard], SpmdPagedShards]
        ] = None
        self._layer = 0
        self._n_planes: Optional[int] = None
        self._mesh = None  # SPMD mode: shard_map merge (esp.paged_decode_spmd)
        self._overlap = True
        self._impl = impl  # kernel impl override (None -> ops default)
        self._axis = None  # in-program mode: batch-sharded iteration body
        self._n_ranks = 1
        self._qpos_full = None

    def begin_step(self, shards, *, mesh=None, overlap: bool = True,
                   axis_name: Optional[str] = None, n_ranks: int = 1,
                   query_pos=None) -> None:
        """Arm the paged path for one decode iteration.  decode_attn is
        called once per layer in stack order; the layer cursor indexes the
        per-layer storage planes.  With ``mesh=`` the shards must be one
        `SpmdPagedShards` (mesh-sharded over "data") and the per-layer merge
        runs as one shard_map collective; ``overlap=False`` pins that
        collective behind an optimization barrier (benchmark baseline).

        With ``axis_name=`` the impl is armed INSIDE an already-manual
        shard_map body (the batch-sharded iteration,
        `esp.paged_decode_iteration_spmd`): shards are this rank's LOCAL
        `SpmdPagedShards` view (leading shard dim 1), ``n_ranks`` the axis
        size, and ``query_pos`` the FULL replicated [B] cached-length vector
        (the all-gathered query needs full-batch masking while the model
        stack only sees the rank's slice)."""
        self._shards = shards
        self._layer = 0
        self._mesh = mesh
        self._overlap = overlap
        self._axis = axis_name
        self._n_ranks = n_ranks
        self._qpos_full = query_pos
        if mesh is not None or axis_name is not None:
            assert isinstance(shards, SpmdPagedShards), type(shards)
            self._n_planes = int(shards.k_pages.shape[1])
        else:
            # all shards mirror the same layer stack; an empty shard list
            # (no KV anywhere) leaves the cursor unverified
            self._n_planes = (
                int(shards[0].k_pages.shape[0]) if shards else None
            )

    def end_step(self) -> None:
        """Disarm — and verify the layer cursor consumed EXACTLY the armed
        per-layer planes: a model/impl stack-order mismatch (extra or missing
        decode_attn calls) would otherwise read the wrong layer's pages
        silently.  Callers disarm from ``finally`` blocks, so the check is
        skipped while another exception is already propagating (a model
        error at layer k must stay the headline failure, not the cursor)."""
        import sys

        try:
            if (self._shards is not None and self._n_planes is not None
                    and sys.exc_info()[0] is None):
                assert self._layer == self._n_planes, (
                    f"paged decode consumed {self._layer} layer planes, "
                    f"pool stores {self._n_planes}"
                )
        finally:
            self._shards = None
            self._mesh = None
            self._n_planes = None
            self._layer = 0
            self._overlap = True
            self._axis = None
            self._n_ranks = 1
            self._qpos_full = None

    def decode_attn(self, q, k_cache, v_cache, k_new, v_new, cache_len, *,
                    window, softcap):
        if self._shards is None or k_cache is not None:
            return super().decode_attn(
                q, k_cache, v_cache, k_new, v_new, cache_len,
                window=window, softcap=softcap,
            )
        li = self._layer
        self._layer += 1
        if self._n_planes is not None:
            assert li < self._n_planes, (
                f"decode_attn called for layer {li} but the pool stores "
                f"{self._n_planes} planes (model/impl stack mismatch)"
            )
        b = q.shape[0]
        # the query's global position == cached token count (its own KV is
        # k_new, merged below) — window predicate qp - kp < window
        qpos = jnp.broadcast_to(jnp.asarray(cache_len), (b,)).astype(jnp.int32)
        if self._axis is not None:
            # in-program (batch-sharded) mode: already inside the iteration's
            # shard_map body — q/k_new/v_new are this rank's batch slice, the
            # boundary all_gathers q, computes the full-batch partial over the
            # rank's local pool plane and psum_scatters the merged result
            # back to batch shards (esp.paged_decode_attn_sharded)
            from repro.core.esp import paged_decode_attn_sharded

            s = self._shards
            out = paged_decode_attn_sharded(
                self._axis, self._n_ranks, q, k_new, v_new, self._qpos_full,
                s.k_pages[0, li], s.v_pages[0, li], s.table[0], s.lengths[0],
                s.pos[0] if s.pos is not None else None,
                window=window, softcap=softcap, overlap=self._overlap,
                impl=self._impl,
            )
            return out.astype(q.dtype)
        if self._mesh is not None:
            from repro.core.esp import paged_decode_spmd

            s = self._shards
            out = paged_decode_spmd(
                self._mesh, q, k_new, v_new, qpos,
                s.k_pages[:, li], s.v_pages[:, li], s.table, s.lengths,
                s.pos, window=window, softcap=softcap,
                overlap=self._overlap, impl=self._impl,
            )
            return out.astype(q.dtype)
        part = attn.partial_attention(q, k_new, v_new, None, softcap=softcap)
        # the master device the per-shard partials return to (the paper's
        # "send back partial results"): pool mirrors bound to their own
        # data-shard devices (mesh executor) compute each partial in place
        # over the shard and only the tiny (o, m, l) rides home for the
        # LSE-merge.  Single-device pools skip the transfer entirely.
        home = _dev(q)
        for s in self._shards:
            sdev = _dev(s.k_pages)
            q_s, qpos_s = q, qpos
            if home is not None and sdev is not None and sdev != home:
                # the q broadcast: ship the tiny query (and its positions)
                # to the shard's device so the partial computes WHERE the KV
                # stripe lives
                q_s = _ship(q, sdev, "decode_q_broadcast")
                qpos_s = _ship(qpos, sdev, "decode_q_broadcast")
            p = ops.paged_decode_partial(
                q_s, s.k_pages[li], s.v_pages[li], s.table, s.lengths, s.pos,
                query_pos=qpos_s, window=window, softcap=softcap,
                impl=self._impl,
            )
            if home is not None and sdev is not None and sdev != home:
                # only the tiny (o, m, l) partial rides back to the master
                p = attn.Partial(
                    *(_ship(x, home, "decode_partial_home") for x in p)
                )
            # counted so SPMD tests/benches can assert the sequential
            # Python-loop merge is NEVER reached when the mesh path is armed
            ops.dispatch_counts["decode_merge_loop"] += 1
            part = attn.merge_partial(part, p)
        return attn.finalize_partial(part).astype(q.dtype)

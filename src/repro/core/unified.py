"""Unified continuous-batching attention: chunked prefill + in-flight decode
on ONE packed ragged token axis (the LoongServe unified iteration).

Key identity: decode IS chunked prefill with chunk == 1.  Per layer, every
packed token row's attention output is

    finalize( merge( paged PREFIX partial over the pool storage,
                     packed causal CHUNK partial over this iteration's axis ) )

The prefix partial is the SAME primitive the paged decode path uses
(`ops.paged_decode_partial`) with per-TOKEN expanded operands — each packed
token carries its request's page table and the length of the FILLED prefix
(`KVPool.prefix_block_table`), so a mid-prefill request attends exactly the
chunks it has already written.  The chunk partial is the SAME primitive the
ESP ring prefill uses (`ops.prefill_ring_chunk` — ``n_shards=1`` in-process,
the full striped ppermute ring under shard_map) with the prefix partial passed
in as the carried flash state.  A decode row is a length-1 segment: its chunk
partial degenerates to the new token's self-attention partial, so the math is
bit-identical to the dedicated decode step's merge.

No attention FLOPs are duplicated across chunks: a (query, key) pair is
computed exactly once, in the iteration whose chunk contains the query — the
paged pool IS the carried (acc, m, l) flash state, materialized as KV instead
of statistics (and therefore failure-tolerant: a crashed iteration re-runs
from the pool, no stats to checkpoint).

Masking correctness on the packed axis: a prefill chunk occupies contiguous
packed slots AND contiguous positions, so packed-coordinate causality/window
inside `prefill_ring_chunk` equals position-based masking; every prefix
position is < the chunk's first position, so the prefix partial needs no
causal mask beyond slot validity (+ the per-token window predicate on global
positions).  Bucket-padding tokens form a trailing segment that attends only
itself causally and is never sampled or scattered.

Hole-filling chunk schedules (elastic fault recovery): nothing above assumes
a chunk starts at the request's prefill frontier — only that the pool holds
every position BELOW the chunk's start (`prefix_block_table`'s coverage
contract).  So when an instance failure loses a token span whose higher
positions survive on other instances, the recovery chain replays the lost
span as ordinary chunks: the PREFIX partial reads the salvaged pages, the
chunk partial recomputes only the hole, and the engine schedules holes
strictly ascending and before the frontier so the coverage contract holds at
every link.  A decode-phase request re-feeds its already-emitted tokens over
the hole (they are inputs now, not samples) and resumes decode at its
cursor.  Recovery is therefore the SAME unified iteration — no dedicated
recovery kernel, and the bit-exactness argument above applies unchanged.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Sequence

import jax.numpy as jnp
from jax import lax

from repro.models import attention as A
from repro.models.transformer import DefaultAttnImpl


class UnifiedShard(NamedTuple):
    """One instance's pool view for a unified step, with PER-TOKEN paged
    operands: row t of ``table``/``lengths`` is packed token t's page table
    and filled-prefix length in THIS pool (0 where the pool holds nothing
    for that token's request)."""

    k_pages: jnp.ndarray  # [L, n_pages, P, KVH, D]
    v_pages: jnp.ndarray  # [L, n_pages, P, KVH, D]
    page_pos: Optional[jnp.ndarray]  # [n_pages, P] (window masking only)
    table: jnp.ndarray  # [T, max_pages] int32
    lengths: jnp.ndarray  # [T] int32


def unified_chunk_attention(
    q, k, v, seq_offsets, positions, prefix_shards, *,
    max_seq_len: Optional[int] = None,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    impl: Optional[str] = None,
    block_q: int = 128,
    block_k: int = 128,
):
    """One layer of unified attention, single-process form.

    q/k/v [T, H|KVH, D]: this iteration's packed token axis (prefill chunks
    then decode rows); ``seq_offsets`` [S+1] its segment boundaries;
    ``positions`` [T] global positions; ``prefix_shards``: iterable of
    per-layer pool views ``(k_pages [n_pages,P,KVH,D], v_pages, table
    [T,max_pages], lengths [T], page_pos)``.  Returns the normalized
    [T, H, D] f32 output."""
    from repro.kernels import ops

    carry = None
    qt = q[:, None]  # [T, 1, H, D] — token axis as the partial's batch axis
    for kp, vp, tbl, lens, pos in prefix_shards:
        p = ops.paged_decode_partial(
            qt, kp, vp, tbl, lens, pos, query_pos=positions,
            window=window, softcap=softcap, impl=impl,
        )
        part = p if carry is None else A.merge_partial(carry, p)
        carry = A.Partial(*part)
    if carry is not None:
        carry = (carry.o[:, 0], carry.m[:, 0], carry.l[:, 0])
    o, m, l = ops.prefill_ring_chunk(
        q, k, v, seq_offsets, seq_offsets, carry,
        q_shard=0, k_shard=0, n_shards=1, window=window, softcap=softcap,
        max_seq_len=max_seq_len, impl=impl, block_q=block_q, block_k=block_k,
    )
    denom = jnp.where(l == 0.0, 1.0, l)  # l==0 rows are bucket padding
    return o / denom[..., None]


class UnifiedAttnImpl(DefaultAttnImpl):
    """Attention impl for the unified iteration, armed per engine step.

    Drives `model.prefill_packed(..., unroll=True)`: the static python layer
    loop calls `prefill_attn` once per layer and the impl keeps a layer
    cursor into the per-layer pool planes (the same begin/end contract as
    `core.paged_decode.PagedDecodeAttnImpl`).

    Two modes:
      * loop (LocalExecutor): ``shards`` is a list of `UnifiedShard`, one per
        instance holding prefix KV; each layer merges one prefix partial per
        shard into the n_shards=1 chunk fold.
      * axis (inside a shard_map body, `esp.unified_iteration_spmd`): the
        token axis is STRIPED over ``n_ranks``; each layer all_gathers the
        q stripes, computes this rank's prefix partial over its own pool
        plane, LSE-merges with pmax + psum_scatter back to the stripes, and
        folds the chunk-internal attention with the SAME ppermute ring the
        SPMD prefill uses — prefix merge (decode plane) and ring fold
        (prefill plane) live inside one layer of one program.
    """

    def __init__(self, impl: Optional[str] = None):
        self.impl = impl
        self._armed = False

    def begin_step(
        self, seq_offsets, positions, *,
        max_seq_len: Optional[int] = None,
        shards: Optional[Sequence[UnifiedShard]] = None,
        axis_name: Optional[str] = None,
        n_ranks: int = 1,
        double_buffer: bool = True,
        block_q: int = 128,
        block_k: int = 128,
    ) -> None:
        """Arm one step.  ``positions`` is the FULL packed-axis position
        vector ([T]; striped order in axis mode) — the per-token query_pos of
        the prefix partial.  In axis mode ``shards`` holds ONE `UnifiedShard`
        with this rank's pool plane and per-token operands over the full
        (gathered) axis."""
        assert not self._armed, "unified step already armed"
        self._offsets = jnp.asarray(seq_offsets, jnp.int32)
        self._positions = jnp.asarray(positions, jnp.int32)
        self._max_seq_len = max_seq_len
        self._shards = list(shards) if shards else []
        self._axis = axis_name
        self._n_ranks = n_ranks
        self._double_buffer = double_buffer
        self._block_q, self._block_k = block_q, block_k
        self._li = 0
        self._n_layers = (
            int(self._shards[0].k_pages.shape[0]) if self._shards else None
        )
        self._armed = True

    def end_step(self) -> None:
        assert self._armed
        li, n = self._li, self._n_layers
        self._armed = False
        self._shards = []
        import sys

        if sys.exc_info()[0] is None and n is not None:
            assert li == n, (li, n)

    # ------------------------------------------------------------- per layer
    def prefill_attn(self, q, k, v, q_pos, k_pos, *, causal, window, softcap):
        if not self._armed:
            return super().prefill_attn(
                q, k, v, q_pos, k_pos, causal=causal, window=window,
                softcap=softcap,
            )
        assert causal and q.shape[0] == 1, (causal, q.shape)
        li = self._li
        self._li += 1
        if self._axis is not None:
            out = self._attn_axis(li, q, k, v, window, softcap)
        else:
            shards_li = [
                (s.k_pages[li], s.v_pages[li], s.table, s.lengths, s.page_pos)
                for s in self._shards
            ]
            out = unified_chunk_attention(
                q[0], k[0], v[0], self._offsets, self._positions, shards_li,
                max_seq_len=self._max_seq_len, window=window, softcap=softcap,
                impl=self.impl, block_q=self._block_q, block_k=self._block_k,
            )
        return out[None].astype(q.dtype)

    def _attn_axis(self, li, q, k, v, window, softcap):
        """One layer boundary inside the shard_map body: decode-style prefix
        merge + prefill-style ring fold, on this rank's token stripe."""
        from repro.core import esp, striped
        from repro.kernels import ops

        sp, n = self._axis, self._n_ranks
        (sh,) = self._shards
        tl = q.shape[1]
        r = lax.axis_index(sp)
        # --- prefix plane: all_gather(q) -> local paged partial over this
        # rank's pool plane -> LSE psum_scatter back to the stripes (exactly
        # the batch-sharded decode boundary, with T for B) ---
        qg = ops.all_gather(q[0][:, None], sp, axis=0)  # [T, 1, H, D]
        part = esp._switched_paged_partial(
            sp, n, qg, sh.k_pages[li], sh.v_pages[li], sh.table, sh.lengths,
            sh.page_pos, query_pos=self._positions, window=window,
            softcap=softcap, impl=self.impl,
        )
        m_g = ops.pmax(part.m, sp)
        m_safe = jnp.where(jnp.isinf(m_g), 0.0, m_g)
        w = jnp.where(jnp.isinf(part.m), 0.0, jnp.exp(part.m - m_safe))
        o_s, l_s = ops.psum_scatter(
            (part.o * w[..., None], part.l * w), sp, scatter_dimension=0,
        )
        m_s = lax.dynamic_slice_in_dim(m_g, r * tl, tl, axis=0)
        carry = (o_s[:, 0], m_s[:, 0], l_s[:, 0])
        # --- chunk plane: the striped ppermute ring over this iteration's
        # packed KV, folded into the prefix carry (double-buffered like
        # `esp.ring_packed_prefill_spmd`) ---
        pairs = striped.ring_pairs(n)
        qb, kk, vv = q[0], k[0], v[0]
        ob = self._offsets
        for step in range(n):
            if step < n - 1 and self._double_buffer:
                nxt = ops.ring_ppermute((kk, vv), sp, pairs)
            carry = esp.switched_ring_chunk(
                sp, n, step, qb, kk, vv, ob, carry, window=window,
                softcap=softcap, max_seq_len=self._max_seq_len,
                impl=self.impl, block_q=self._block_q, block_k=self._block_k,
            )
            if step < n - 1:
                if self._double_buffer:
                    kk, vv = nxt
                else:
                    kk, vv, carry = lax.optimization_barrier((kk, vv, carry))
                    kk, vv = ops.ring_ppermute((kk, vv), sp, pairs)
        o, m, l = carry
        denom = jnp.where(l == 0.0, 1.0, l)
        return o / denom[..., None]

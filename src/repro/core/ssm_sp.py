"""Sequence parallelism for recurrent layers (hybrid zamba2 / xlstm archs).

ESP's striped KV ring is inapplicable to recurrent state (DESIGN.md §4); the
analogue implemented here is a 3-phase chunk-state handoff on the *contiguous*
layout:

  1. local state-only fold: each rank folds its sequence segment into a
     single (state, decay) summary from zero init — cheap (skips output math);
  2. log-step exclusive device scan over the `sp` axis (Hillis-Steele with
     ppermute) under the layer's state monoid (SSD: linear decay; mLSTM:
     max-stabilized log-space);
  3. local full pass seeded with the true incoming state.

sLSTM is inherently sequential (xLSTM §2.3): its input is all-gathered and the
scalar recurrence runs redundantly per rank (cheap — no matmuls in the scan),
each rank keeping its local slice.

Batch shards over `tp` when divisible (recurrent layers are batch-parallel);
weights stay replicated — recurrent-layer TP alternatives are a §Perf lever.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.core.shmap import shmap as _shmap
from repro.models import layers, ssm, xlstm


def _shift_pairs(n: int, shift: int = 1):
    return [(i, i + shift) for i in range(n - shift)]


def _select_last(x, sp, n, reduce="sum"):
    """Replicate the last rank's value to every rank."""
    idx = lax.axis_index(sp)
    if reduce == "max":
        return lax.pmax(jnp.where(idx == n - 1, x, -jnp.inf), sp)
    return lax.psum(jnp.where(idx == n - 1, x, jnp.zeros_like(x)), sp)


def _ssd_device_exclusive_scan(h_seg, d_seg, sp, n):
    """Exclusive scan of (decay, state) pairs over the sp axis. Returns the
    state entering each rank (zeros at rank 0). Hillis-Steele: log2(n) steps."""
    h, d = h_seg, d_seg
    shift = 1
    while shift < n:
        hr = lax.ppermute(h, sp, _shift_pairs(n, shift))
        dr = lax.ppermute(d, sp, _shift_pairs(n, shift))
        has = lax.axis_index(sp) >= shift
        dr = jnp.where(has, dr, 1.0)  # ppermute zero-fills; decay identity=1
        h = jnp.where(has[..., None, None, None],
                      hr * d[:, :, None, None] + h, h)
        d = jnp.where(has, dr * d, d)
        shift *= 2
    # exclusive = inclusive shifted right by one rank
    h_excl = lax.ppermute(h, sp, _shift_pairs(n, 1))
    return jnp.where(lax.axis_index(sp) >= 1, h_excl, jnp.zeros_like(h_excl))


def _mlstm_device_exclusive_scan(st: xlstm.MLSTMState, btot, sp, n):
    """Same, under the mLSTM max-stabilized monoid."""
    c, nn, m, b = st.c, st.n, st.m, btot
    shift = 1
    while shift < n:
        cr = lax.ppermute(c, sp, _shift_pairs(n, shift))
        nr = lax.ppermute(nn, sp, _shift_pairs(n, shift))
        mr = lax.ppermute(m, sp, _shift_pairs(n, shift))
        br = lax.ppermute(b, sp, _shift_pairs(n, shift))
        has = lax.axis_index(sp) >= shift
        mr = jnp.where(has, mr, -jnp.inf)  # identity
        br = jnp.where(has, br, 0.0)
        comb = xlstm.mlstm_combine_states(
            xlstm.MLSTMState(cr, nr, mr), xlstm.MLSTMState(c, nn, m), b
        )
        c = jnp.where(has[..., None, None, None], comb.c, c)
        nn = jnp.where(has[..., None, None], comb.n, nn)
        m = jnp.where(has[..., None], comb.m, m)
        b = jnp.where(has, br + b, b)
        shift *= 2
    cr = lax.ppermute(c, sp, _shift_pairs(n, 1))
    nr = lax.ppermute(nn, sp, _shift_pairs(n, 1))
    mr = lax.ppermute(m, sp, _shift_pairs(n, 1))
    first = lax.axis_index(sp) < 1
    return xlstm.MLSTMState(
        c=jnp.where(first[..., None, None, None], jnp.zeros_like(cr), cr),
        n=jnp.where(first[..., None, None], jnp.zeros_like(nr), nr),
        m=jnp.where(first[..., None], jnp.full_like(mr, -jnp.inf), mr),
    )


def _batch_axis(mesh, tp, batch):
    if tp and tp in mesh.axis_names and batch % mesh.shape[tp] == 0:
        return tp
    return None


# ===================================================================== mamba


def mamba2_forward_sp(mesh, sp, p, x, cfg, state, *, tp=None, interpret=False):
    """x [B, S(global), d] contiguous layout, sharded S over sp. Returns
    (y, SSMState) with the state replicated (the true global final state)."""
    assert state is None, "SP prefill starts from a fresh state"
    n = mesh.shape[sp]
    btp = _batch_axis(mesh, tp, x.shape[0])
    d_in = cfg.ssm_expand * cfg.d_model
    n_heads = d_in // cfg.ssm_head_dim

    def body(xb, pp):
        zxbcdt = jnp.einsum("btd,de->bte", xb, pp["w_in"])
        z, xs_, b_, c_, dt = ssm._split_proj(pp, zxbcdt, d_in, cfg.ssm_state, n_heads)
        xbc = jnp.concatenate([xs_, b_, c_], axis=-1)
        # conv handoff: receive the left neighbour's tail (zeros at rank 0)
        w = pp["conv_w"].shape[0]
        tail = xbc[:, xbc.shape[1] - (w - 1):, :]
        recv = lax.ppermute(tail, sp, _shift_pairs(n, 1))
        xbc, my_tail = ssm._causal_conv(xbc, pp["conv_w"], pp["conv_b"], recv)
        xs_ = xbc[..., :d_in]
        b_ = xbc[..., d_in : d_in + cfg.ssm_state]
        c_ = xbc[..., d_in + cfg.ssm_state :]
        dt = jax.nn.softplus(dt.astype(jnp.float32) + pp["dt_bias"][None, None, :])
        a = -jnp.exp(pp["A_log"])
        xh = xs_.reshape(*xs_.shape[:2], n_heads, cfg.ssm_head_dim)
        # 3-phase handoff
        h_seg, d_seg = ssm.ssd_state_only(xh, dt, a, b_, cfg.ssm_chunk)
        h_in = _ssd_device_exclusive_scan(h_seg, d_seg, sp, n)
        y, h_fin = ssm.ssd_chunk_scan(xh, dt, a, b_, c_, cfg.ssm_chunk, h_in)
        y = y + xh.astype(jnp.float32) * pp["D"][None, None, :, None]
        y = y.reshape(*xs_.shape[:2], d_in).astype(xb.dtype)
        y = ssm._gated_norm(y, z, pp["norm_scale"])
        out = jnp.einsum("bte,ed->btd", y, pp["w_out"])
        h_last = _select_last(h_fin, sp, n)
        conv_last = _select_last(my_tail.astype(jnp.float32), sp, n)
        return out, h_last, conv_last

    fn = _shmap(
        body, mesh,
        in_specs=(P(btp, sp, None), P()),
        out_specs=(P(btp, sp, None), P(btp), P(btp)),
    )
    out, h_last, conv_last = fn(x, p)
    return out, ssm.SSMState(h=h_last, conv=conv_last)


# ===================================================================== mlstm


def mlstm_forward_sp(mesh, sp, p, x, cfg, state, *, tp=None, interpret=False):
    assert state is None, "SP prefill starts from a fresh state"
    n = mesh.shape[sp]
    btp = _batch_axis(mesh, tp, x.shape[0])
    chunk = min(cfg.ssm_chunk or 64, max(x.shape[1] // n, 1))

    def body(xb, pp):
        q, k, v, o, ig, fg, z, dh = xlstm._mlstm_qkvif(pp, xb, cfg)
        seg, btot = xlstm.mlstm_state_only(k, v, ig, fg, chunk)
        st_in = _mlstm_device_exclusive_scan(seg, btot, sp, n)
        htilde, st_fin = xlstm.mlstm_chunkwise(q, k, v, ig, fg, chunk, st_in)
        h = htilde.reshape(*xb.shape[:2], -1) * o
        h = h * jax.nn.silu(z)
        out = jnp.einsum("bte,ed->btd", h, pp["w_down"])
        st_last = xlstm.MLSTMState(
            c=_select_last(st_fin.c, sp, n),
            n=_select_last(st_fin.n, sp, n),
            m=_select_last(st_fin.m, sp, n, reduce="max"),
        )
        return out, st_last

    fn = _shmap(
        body, mesh,
        in_specs=(P(btp, sp, None), P()),
        out_specs=(P(btp, sp, None), xlstm.MLSTMState(P(btp), P(btp), P(btp))),
    )
    return fn(x, p)


# ===================================================================== slstm


def slstm_forward_sp(mesh, sp, p, x, cfg, state, *, tp=None, interpret=False):
    assert state is None, "SP prefill starts from a fresh state"
    n = mesh.shape[sp]
    btp = _batch_axis(mesh, tp, x.shape[0])
    d_in = int(cfg.xlstm_proj_factor * cfg.d_model)

    def body(xb, pp):
        up = jnp.einsum("btd,de->bte", xb, pp["w_up"])
        xm, z = up[..., :d_in], up[..., d_in:]
        xm_full = lax.all_gather(xm, sp, axis=1, tiled=True)  # [B, S, d_in]
        st0 = xlstm.init_slstm_state(cfg, xb.shape[0])
        h_full, st = xlstm.slstm_scan(pp, xm_full, cfg, st0)
        s_l = xm.shape[1]
        h_loc = lax.dynamic_slice_in_dim(
            h_full, lax.axis_index(sp) * s_l, s_l, axis=1
        )
        h = h_loc * jax.nn.silu(z)
        out = jnp.einsum("bte,ed->btd", h, pp["w_down"])
        return out, st

    fn = _shmap(
        body, mesh,
        in_specs=(P(btp, sp, None), P()),
        out_specs=(
            P(btp, sp, None),
            xlstm.SLSTMState(P(btp), P(btp), P(btp), P(btp)),
        ),
    )
    return fn(x, p)

"""`shard_map` across jax versions (single shim for every SPMD module).

jax >= 0.6 exposes `jax.shard_map` (replication checking via ``check_vma``);
older releases ship it as `jax.experimental.shard_map.shard_map` with the
equivalent ``check_rep`` flag.  Every shard_map body in this repo uses manual
collectives with unannotated replication, so checking is disabled on both.
"""
from __future__ import annotations

import jax


def shmap(fn, mesh, in_specs, out_specs):
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=False,
        )
    from jax.experimental.shard_map import shard_map as _shard_map

    return _shard_map(
        fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=False
    )

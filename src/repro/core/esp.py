"""Elastic Sequence Parallelism: the SPMD production path (LoongServe §4).

`ESPAttnImpl` plugs into the model builders and replaces local attention with:

  * prefill: striped-attention ring over the `sp` mesh axis (between elastic
    instances). Each rank holds one sequence stripe; at every ring step it
    computes a flash-style *partial* against the KV stripe it currently holds
    and `ppermute`s the stripe to its ring neighbour — n steps make every
    query meet every key with zero redundant compute. Masks/RoPE are
    position-based so the striped permutation is exact.
  * decode: multi-master distributed decode. The KV cache is sharded across
    instances at token granularity; masters (batch shards over `sp`) compute
    q and the new token's KV locally, q is all-gathered (the paper's "send
    query tensors"), every rank computes a partial over its local KV shard,
    and partials are combined with an LSE-weighted reduce-scatter back to the
    masters — which then run their own FFN shard (multi-master == batch-
    sharded local layers).

Two head-sharding modes per DESIGN.md §3:
  * heads mode (n_heads % tp == 0): q heads shard over `tp`; KV heads shard
    too when divisible, otherwise each rank dynamic-slices the KV heads its
    q-head block needs (GQA group-aligned).
  * batch mode (odd head counts: qwen 20H, arctic 56H, whisper 6H): the
    attention batch shards over `tp` instead; heads stay whole.

The ring degree (DoP) can be the whole `sp` axis or disjoint subgroups of it
(`dop=`), matching LoongServe's iteration-level ESP groups.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.core import striped
from repro.core.shmap import shmap as _shmap
from repro.models import attention as A
from repro.models import ssm, xlstm
from repro.models.transformer import DefaultAttnImpl


def _slice_kv_heads(k, v, tp_idx, h_local: int, q_per_kv: int):
    """Select the KV heads a rank's q-head block needs when KV is replicated
    across tp. Requires blocks not to straddle KV groups (q_per_kv % h_local
    == 0 or h_local % q_per_kv == 0) — true for every assigned arch."""
    if h_local >= q_per_kv:
        n_loc = h_local // q_per_kv
        start = tp_idx * n_loc
    else:
        n_loc = 1
        start = (tp_idx * h_local) // q_per_kv
    k = lax.dynamic_slice_in_dim(k, start, n_loc, axis=2)
    v = lax.dynamic_slice_in_dim(v, start, n_loc, axis=2)
    return k, v


def ring_packed_prefill(
    q, k, v, seq_offsets, n_shards: int, *,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    max_seq_len: Optional[int] = None,
    impl: Optional[str] = None,
    block_q: int = 128,
    block_k: int = 128,
):
    """Ring-fused packed ragged prefill for one DoP>1 ESP group (single-
    process simulation of the striped ppermute ring).

    The packed token axis [T] is striped across the group's ``n_shards``
    instances (global packed index ``g`` -> shard ``g % n``, local slot
    ``g // n``).  Every instance starts holding its own KV stripe; the ring
    then replays `striped.ring_chunk_schedule` — the exact chunk rotation the
    SPMD `ring_pairs` ppermute produces — and at each step each instance
    folds the chunk it currently holds into its carried (acc, m, l) flash
    state with ONE packed ragged `ops.prefill_ring_chunk` launch.  n steps
    make every query meet every key exactly once (zero redundant compute);
    the per-instance states then finalize LSE-style (the same
    max/sum-exp-weighted merge decode's multi-master combine uses, folded
    into the carry) and un-stripe back to the packed order.

    q [T,H,D], k/v [T,KVH,D] in PACKED order; returns the normalized
    [T,H,D] f32 output, numerically equal to `ops.prefill_packed`."""
    from repro.kernels import ops

    t = q.shape[0]
    n = int(n_shards)
    assert n >= 1 and t % n == 0, (t, n)
    if n == 1:
        return ops.prefill_packed(
            q, k, v, seq_offsets, window=window, softcap=softcap,
            max_seq_len=max_seq_len, impl=impl, block_q=block_q,
            block_k=block_k,
        )
    # counted so mesh-executor tests can assert the in-process replay is
    # NEVER reached when the shard_map ring is armed
    ops.dispatch_counts["prefill_ring_replay"] += 1
    qs = [q[r::n] for r in range(n)]
    ks = [k[r::n] for r in range(n)]
    vs = [v[r::n] for r in range(n)]
    offs = list(striped.all_shard_offsets(seq_offsets, n))
    sched = striped.ring_chunk_schedule(n)
    carries: list = [None] * n
    for step in range(n):
        for r in range(n):
            c = sched[step][r]
            carries[r] = ops.prefill_ring_chunk(
                qs[r], ks[c], vs[c], offs[r], offs[c], carries[r],
                q_shard=r, k_shard=c, n_shards=n, window=window,
                softcap=softcap, max_seq_len=max_seq_len, impl=impl,
                block_q=block_q, block_k=block_k,
            )
    outs = []
    for r in range(n):
        o, m, l = carries[r]
        denom = jnp.where(l == 0.0, 1.0, l)  # l==0 rows are bucket padding
        outs.append(o / denom[..., None])
    return striped.unstripe(jnp.concatenate(outs, axis=0), n, axis=0)


def switched_ring_chunk(
    sp: str, n: int, step: int, q, k, v, seq_offsets, carry, *,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    max_seq_len: Optional[int] = None,
    impl: Optional[str] = None,
    block_q: int = 128,
    block_k: int = 128,
):
    """One ring-chunk fold inside a shard_map body, dispatching the
    CONFIGURED kernel impl instead of forcing the banded XLA fallback.

    The shard ids of ring step ``step`` are rank-derived (`lax.axis_index`),
    so — exactly like `_switched_paged_partial` on the decode side — non-XLA
    impls go through `lax.switch` over ``n`` statically-specialized branches:
    branch ``r`` bakes ``q_shard=r, k_shard=(r-step) % n`` (``step`` is a
    python loop constant) as the compile-time constants the Pallas kernel's
    tile-skip predicates need.  The XLA banded fallback accepts traced shard
    ids and dispatches directly.  ``seq_offsets`` are the GLOBAL packed
    offsets; per-shard offsets derive in place (`striped.shard_offsets`)."""
    from repro.kernels import ops

    eff = impl or ops.get_default_impl()
    if eff == "xla":
        r = lax.axis_index(sp)
        k_shard = (r - step) % n
        return ops.prefill_ring_chunk(
            q, k, v,
            striped.shard_offsets(seq_offsets, n, r),
            striped.shard_offsets(seq_offsets, n, k_shard),
            carry, q_shard=r, k_shard=k_shard, n_shards=n, window=window,
            softcap=softcap, max_seq_len=max_seq_len, impl="xla",
            block_q=block_q, block_k=block_k,
        )
    if carry is None:
        tl, h, d = q.shape
        carry = (
            jnp.zeros((tl, h, d), jnp.float32),
            jnp.full((tl, h), -jnp.inf, jnp.float32),
            jnp.zeros((tl, h), jnp.float32),
        )

    def branch(rank: int):
        k_shard = (rank - step) % n

        def run(operands):
            qb, kb, vb, cb = operands
            return ops.prefill_ring_chunk(
                qb, kb, vb,
                striped.shard_offsets(seq_offsets, n, rank),
                striped.shard_offsets(seq_offsets, n, k_shard),
                cb, q_shard=rank, k_shard=k_shard, n_shards=n, window=window,
                softcap=softcap, max_seq_len=max_seq_len, impl=eff,
                block_q=block_q, block_k=block_k,
            )

        return run

    return lax.switch(
        lax.axis_index(sp), [branch(r) for r in range(n)], (q, k, v, carry)
    )


def ring_packed_prefill_spmd(
    mesh: Mesh, q, k, v, seq_offsets, *,
    sp_axis: str = "data",
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    max_seq_len: Optional[int] = None,
    impl: Optional[str] = None,
    block_q: int = 128,
    block_k: int = 128,
    double_buffer: bool = True,
):
    """Mesh-native ring-fused packed ragged prefill: ONE shard_map program
    over the mesh's ``sp_axis`` in which each data rank physically owns its
    stripe of the packed token axis and the KV stripes rotate between
    devices with `lax.ppermute`.

    The packed axis [T] is striped over the ``n = mesh.shape[sp_axis]``
    ranks (global packed index ``g`` -> rank ``g % n``, local slot
    ``g // n``); rank r starts holding its own KV stripe.  At ring step s it
    folds the chunk it currently holds — provenance ``(r - s) mod n``,
    `striped.chunk_provenance` — into its carried (acc, m, l) flash state
    with one `ops.prefill_ring_chunk` launch, while (``double_buffer=True``)
    the NEXT stripe's ppermute is issued BEFORE the fold so the transfer
    overlaps the chunk compute; ``double_buffer=False`` pins the permute
    behind the fold with an optimization barrier (the sequential baseline
    the benchmark compares against).  Every ring leg goes through
    `ops.ring_ppermute` (dispatch + per-leg byte counters).

    The per-shard segment offsets are static metadata derived from the
    REPLICATED global ``seq_offsets`` inside the body (`striped
    .shard_offsets` with the traced rank / chunk provenance) rather than fed
    as a data-sharded [n, B+1] array: jax 0.4.x's SPMD partitioner
    mis-reshards tiny computed arrays entering a manual region on a
    multi-axis mesh, and the ring leg then only needs to move KV bytes.

    Shard ids reach the chunk kernel rank-derived, so the real (Pallas)
    kernel dispatches through `switched_ring_chunk`'s statically-specialized
    `lax.switch` branches — the same trick the decode path uses
    (`_switched_paged_partial`); the XLA banded fallback keeps its direct
    traced-shard-id dispatch.

    q [T,H,D], k/v [T,KVH,D] in PACKED order (T % n == 0); returns the
    normalized [T,H,D] f32 output, numerically equal to
    `ops.prefill_packed`."""
    from repro.kernels import ops

    n = int(mesh.shape[sp_axis])
    t = q.shape[0]
    assert n >= 1 and t % n == 0, (t, n)
    if n == 1:
        return ops.prefill_packed(
            q, k, v, seq_offsets, window=window, softcap=softcap,
            max_seq_len=max_seq_len, impl=impl, block_q=block_q,
            block_k=block_k,
        )
    ops.dispatch_counts["prefill_ring_spmd"] += 1
    pairs = striped.ring_pairs(n)
    sp = sp_axis

    def body(qb, kb, vb, ob):
        # qb/kb/vb: [Tl, ...] this rank's stripe; ob: [B+1] global offsets
        kk, vv = kb, vb
        carry = None
        for step in range(n):
            if step < n - 1 and double_buffer:
                # issue the NEXT stripe's transfer before folding this one:
                # no data dependency on the fold, so XLA/ICI can overlap the
                # ppermute with the chunk compute
                nxt = ops.ring_ppermute((kk, vv), sp, pairs)
            carry = switched_ring_chunk(
                sp, n, step, qb, kk, vv, ob, carry,
                window=window, softcap=softcap, max_seq_len=max_seq_len,
                impl=impl, block_q=block_q, block_k=block_k,
            )
            if step < n - 1:
                if double_buffer:
                    kk, vv = nxt
                else:
                    # sequential baseline: the barrier makes the transfer
                    # depend on the fold, so it cannot start early
                    kk, vv, carry = lax.optimization_barrier((kk, vv, carry))
                    kk, vv = ops.ring_ppermute((kk, vv), sp, pairs)
        o, m, l = carry
        denom = jnp.where(l == 0.0, 1.0, l)  # l==0 rows are bucket padding
        return o / denom[..., None]

    fn = _shmap(
        body, mesh,
        in_specs=(
            P(sp, None, None), P(sp, None, None), P(sp, None, None),
            P(None),
        ),
        out_specs=P(sp, None, None),
    )
    # striped layout = concat of per-rank stripes, so block-sharding the
    # leading axis over `sp` hands rank r exactly stripe r
    out = fn(
        striped.stripe(q, n, axis=0),
        striped.stripe(k, n, axis=0),
        striped.stripe(v, n, axis=0),
        jnp.asarray(seq_offsets, jnp.int32),
    )
    return striped.unstripe(out, n, axis=0)


def _switched_paged_partial(
    sp: str, n: int, q, k_pages, v_pages, table, lengths, page_pos, *,
    query_pos, window, softcap, impl: Optional[str],
):
    """Per-rank paged-decode partial inside a shard_map body, dispatching
    the CONFIGURED kernel impl instead of forcing the XLA fallback.

    The rank is only available as a traced value (`lax.axis_index`), but a
    `pallas_call` needs its grid/scalar-prefetch metadata static — so for
    non-XLA impls the launch goes through `lax.switch` over ``n``
    STATICALLY-specialized variants: branch ``r`` is traced with the rank as
    a compile-time constant, which is where any rank-derived static
    parameters (e.g. global-position bases for window masking on TPU) get
    baked into the kernel instead of reaching Pallas as tracers.  The block
    tables / lengths already arrive pre-sharded, so today's branches differ
    only by that static context; the XLA reference path needs none of this
    and dispatches directly."""
    from repro.kernels import ops

    eff = impl or ops.get_default_impl()
    if eff == "xla":
        return ops.paged_decode_partial(
            q, k_pages, v_pages, table, lengths, page_pos,
            query_pos=query_pos, window=window, softcap=softcap, impl="xla",
        )

    def branch(rank: int):  # noqa: ARG001 — today's branches differ only
        # by the static trace context `rank` pins (see docstring)
        def run(qb):
            return ops.paged_decode_partial(
                qb, k_pages, v_pages, table, lengths, page_pos,
                query_pos=query_pos, window=window, softcap=softcap,
                impl=eff,
            )
        return run

    return lax.switch(lax.axis_index(sp), [branch(r) for r in range(n)], q)


def paged_decode_spmd(
    mesh: Mesh, q, k_new, v_new, query_pos,
    k_pages, v_pages, table, lengths, page_pos=None, *,
    sp_axis: str = "data",
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    overlap: bool = True,
    impl: Optional[str] = None,
):
    """One decode layer's multi-master paged attention as ONE shard_map
    region over the mesh's ``sp_axis``: each data rank computes its
    `ops.paged_decode_partial` over the pool mirror it physically holds (the
    sharded ``k_pages``/``v_pages`` operand IS the per-rank mirror — no KV
    ever moves), and the LSE-merge of the per-instance partials is a
    collective on the weighted running accumulator:

        M   = pmax(m)                       (tiny [B, 1, H])
        o_s = psum(o · exp(m - M))          (the paper's "send back partial
        l_s = psum(l · exp(m - M))           results", §4.2, as ONE reduce)

    The query rides in replicated (``in_specs=P(None)``): the q broadcast is
    compiled into the program instead of a per-shard `device_put` loop.  The
    new token's own KV partial (computed master-side, outside the manual
    region) is data-independent of the reduce, so with ``overlap=True``
    (default, no barriers anywhere) XLA's scheduler is free to run the
    all-reduce asynchronously against it — and, because the whole decode
    iteration is one program, against any other independent compute in the
    layer stack (e.g. the next layer's weight loads feeding its QKV dot).
    ``overlap=False`` pins the collective with an `optimization_barrier`
    threading both the merge results and the new-token partial's inputs —
    nothing can be scheduled across the reduce (the sequential baseline the
    benchmark compares against, mirroring the prefill ring's
    ``double_buffer=False`` arm).

    q [B, 1, H, D]; k_new/v_new [B, 1, KVH, D]; query_pos [B] (the token's
    global position == cached length); k_pages/v_pages
    [n, n_pages, P, KVH, D] — one LAYER's paged storage, sharded over
    ``sp_axis`` (leading axis = rank); table [n, B, max_pages];
    lengths [n, B]; page_pos [n, n_pages, P] (only with window).  Returns
    the finalized merged output [B, 1, H, D] f32."""
    from repro.kernels import ops

    n = int(mesh.shape[sp_axis])
    assert int(k_pages.shape[0]) == n, (k_pages.shape, n)
    ops.dispatch_counts["paged_decode_spmd"] += 1
    sp = sp_axis
    has_pos = page_pos is not None

    def body(qb, qp, kb, vb, tb, lb, *pb):
        # kb/vb/tb/lb/pb: this rank's mirror view, leading shard dim 1
        part = _switched_paged_partial(
            sp, n, qb, kb[0], vb[0], tb[0], lb[0],
            pb[0][0] if has_pos else None,
            query_pos=qp, window=window, softcap=softcap, impl=impl,
        )
        m_g = ops.pmax(part.m, sp)
        m_safe = jnp.where(jnp.isinf(m_g), 0.0, m_g)
        w = jnp.where(jnp.isinf(part.m), 0.0, jnp.exp(part.m - m_safe))
        o_s, l_s = ops.psum((part.o * w[..., None], part.l * w), sp)
        return o_s, m_g, l_s

    specs = [P(None), P(None), P(sp), P(sp), P(sp), P(sp)]
    args = [q, jnp.asarray(query_pos, jnp.int32), k_pages, v_pages,
            table, lengths]
    if has_pos:
        specs.append(P(sp))
        args.append(page_pos)
    fn = _shmap(
        body, mesh, in_specs=tuple(specs),
        out_specs=(P(None), P(None), P(None)),
    )
    o_s, m_s, l_s = fn(*args)
    if not overlap:
        # barriered baseline: the reduce is pinned on the critical path —
        # even the new-token partial (whose inputs are threaded through the
        # barrier) must wait for it
        o_s, m_s, l_s, q, k_new, v_new = lax.optimization_barrier(
            (o_s, m_s, l_s, q, k_new, v_new)
        )
    p_new = A.partial_attention(q, k_new, v_new, None, softcap=softcap)
    merged = A.merge_partial(A.Partial(o_s, m_s, l_s), p_new)
    return A.finalize_partial(merged)


def paged_decode_attn_sharded(
    sp: str, n: int, q, k_new, v_new, query_pos_full,
    k_pages, v_pages, table, lengths, page_pos=None, *,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    overlap: bool = True,
    impl: Optional[str] = None,
):
    """One decode layer's BATCH-SHARDED multi-master paged attention
    boundary, called INSIDE an enclosing shard_map body (no region of its
    own — the whole iteration is one manual region, see
    `paged_decode_iteration_spmd`).

    Each rank owns a ``B/n`` batch slice of the non-attention stack, so the
    layer boundary is exactly LoongServe §4.2's collective schedule:

        qg  = all_gather(q-slice)            (the paper's "send query
                                              tensors": full-B q per rank)
        part = paged partial over LOCAL KV   (full B vs this rank's pool
                                              mirror — exactly as before)
        M   = pmax(m)                        (tiny [B, 1, H])
        o_s, l_s = psum_scatter(o·exp(m-M),  ("send back partial results"
                                l·exp(m-M))   addressed to the masters: the
                                              reduce RETURNS batch shards)
        merge with the rank-LOCAL new-token partial, finalize

    replacing PR 5's replicated pmax+psum: per-rank FLOPs for everything
    outside this boundary drop to ~1/n while the attention partial (already
    1/n via the KV sharding) is unchanged.  ``overlap=False`` pins the
    scatter behind an optimization barrier threading the new-token
    partial's inputs (sequential benchmark baseline); the default leaves
    XLA free to schedule the collectives against the stack's independent
    compute, preserving PR 5's overlap property.

    q/k_new/v_new: this rank's batch slice [B/n, 1, ...];
    query_pos_full [B] REPLICATED (every rank masks the full-B partial);
    k_pages/v_pages/table/lengths/page_pos: this rank's local pool-mirror
    plane (no leading rank axis).  Returns the rank's finalized output
    slice [B/n, 1, H, D] f32."""
    from repro.kernels import ops

    ops.dispatch_counts["paged_decode_sharded"] += 1
    b_l = q.shape[0]
    qg = ops.all_gather(q, sp, axis=0)  # [B, 1, H, D]
    part = _switched_paged_partial(
        sp, n, qg, k_pages, v_pages, table, lengths, page_pos,
        query_pos=query_pos_full, window=window, softcap=softcap, impl=impl,
    )
    m_g = ops.pmax(part.m, sp)
    m_safe = jnp.where(jnp.isinf(m_g), 0.0, m_g)
    w = jnp.where(jnp.isinf(part.m), 0.0, jnp.exp(part.m - m_safe))
    o_s, l_s = ops.psum_scatter(
        (part.o * w[..., None], part.l * w), sp, scatter_dimension=0,
    )
    m_s = lax.dynamic_slice_in_dim(m_g, lax.axis_index(sp) * b_l, b_l, axis=0)
    if not overlap:
        o_s, m_s, l_s, q, k_new, v_new = lax.optimization_barrier(
            (o_s, m_s, l_s, q, k_new, v_new)
        )
    p_new = A.partial_attention(q, k_new, v_new, None, softcap=softcap)
    merged = A.merge_partial(A.Partial(o_s, m_s, l_s), p_new)
    return A.finalize_partial(merged)


def paged_decode_iteration_spmd(
    mesh: Mesh, model, impl, params, toks, n_cached_full,
    k_pages, v_pages, table, lengths, page_pos, route, *,
    sp_axis: str = "data",
    overlap: bool = True,
):
    """The WHOLE batch-sharded decode iteration as ONE shard_map program:
    embed, QKV, FFN, norms, unembed and greedy sampling all run on each
    rank's ``B/n`` batch slice; only the per-layer attention boundary
    (`paged_decode_attn_sharded`, armed through ``impl``) and the final
    exchanges are collectives.

    In-program epilogue (nothing batch-wide ever leaves the device mesh
    replicated except tiny ids):

      * sampling: each rank argmaxes its OWN logits slice
        (`model.decode_sampled` — bit-identical to the engine's host
        `_sample_token`) and the sampled ids are all_gathered so every rank
        sees the full next-token vector — the in-program token exchange
        that lets each master route its own KV appends;
      * per-master KV-append routing: the step's new per-layer KV rows are
        all_gathered over the batch axis and each rank `take`s the rows of
        the requests IT masters (``route``, built by the executor from
        `DecodeBatch.masters`) — the routed output lands master-major, each
        master's rows physically on its own device, instead of the host
        re-slicing a replicated tensor.

    toks [B] int32 sharded over ``sp_axis`` (B % n == 0, bucket-padded);
    n_cached_full [B] REPLICATED (ranks slice their own view and window
    masking needs the full vector); k_pages/v_pages
    [n, L, n_pages, P, KVH, D], table [n, B, max_pages], lengths [n, B],
    page_pos [n, n_pages, P] (window only) — sharded over the leading rank
    axis; route [n, R] int32 batch indices (R = bucketed max
    requests-per-master, padding rows point at index 0 and are never read).
    Returns (sampled ids [B] replicated, k_routed, v_routed
    [L, n*R, 1, KVH, D] sharded master-major on the row axis)."""
    from repro.core.paged_decode import SpmdPagedShards
    from repro.kernels import ops
    from repro.models.transformer import Cache

    n = int(mesh.shape[sp_axis])
    bb = int(toks.shape[0])
    assert bb % n == 0 and int(k_pages.shape[0]) == n, (bb, k_pages.shape, n)
    b_l = bb // n
    ops.dispatch_counts["decode_iteration_spmd"] += 1
    sp = sp_axis
    has_pos = page_pos is not None

    def body(prm, tk, ncf, kb, vb, tb, lb, rt, *pb):
        # tk: this rank's batch slice [B/n]; kb/vb/tb/lb/pb: its pool-mirror
        # view (leading shard dim 1); ncf: full replicated cached lengths
        r = lax.axis_index(sp)
        ncl = lax.dynamic_slice_in_dim(ncf, r * b_l, b_l, axis=0)
        shards = SpmdPagedShards(kb, vb, tb, lb, pb[0] if has_pos else None)
        impl.begin_step(
            shards, axis_name=sp, n_ranks=n, query_pos=ncf, overlap=overlap,
        )
        try:
            nxt, _, kvs = model.decode_sampled(prm, tk, Cache(length=ncl))
        finally:
            impl.end_step()
        nxt_all = ops.all_gather(nxt, sp, axis=0)  # [B] tiny ids
        k_all = ops.all_gather(kvs[0], sp, axis=1)  # [L, B, 1, KVH, D]
        v_all = ops.all_gather(kvs[1], sp, axis=1)
        k_rt = jnp.take(k_all, rt[0], axis=1)  # this master's rows [L, R,...]
        v_rt = jnp.take(v_all, rt[0], axis=1)
        return nxt_all, k_rt, v_rt

    specs = [P(), P(sp), P(None), P(sp), P(sp), P(sp), P(sp), P(sp)]
    args = [params, toks, n_cached_full, k_pages, v_pages, table, lengths,
            route]
    if has_pos:
        specs.append(P(sp))
        args.append(page_pos)
    fn = _shmap(
        body, mesh, in_specs=tuple(specs),
        out_specs=(P(None), P(None, sp), P(None, sp)),
    )
    return fn(*args)


def unified_iteration_spmd(
    mesh: Mesh, model, impl, params, toks, positions, seq_offsets, last_idx,
    k_pages, v_pages, table, lengths, page_pos, *,
    sp_axis: str = "data",
    max_seq_len: Optional[int] = None,
    double_buffer: bool = True,
):
    """ONE shard_map program for a whole UNIFIED engine iteration: a bounded
    chunk of every admitted prompt's prefill tokens AND all in-flight decode
    tokens packed on a single ragged token axis, STRIPED over the group's
    data ranks.

    Each rank runs the full stack (embed, QKV, FFN, norms) on its token
    stripe; at every layer boundary the armed `core.unified.UnifiedAttnImpl`
    executes BOTH compute planes inside the same layer:

      * prefix plane (the decode-path schedule): all_gather(q stripes) ->
        per-rank paged partial over its OWN pool plane with per-token tables
        and filled-prefix lengths (`_switched_paged_partial`) -> pmax +
        psum_scatter LSE-merge addressed back to the stripes;
      * chunk plane (the prefill-path schedule): the striped `lax.ppermute`
        KV ring folded into the prefix carry (`switched_ring_chunk`, real
        kernel under `lax.switch`), double-buffered.

    A decode row is a length-1 segment whose prefix is its whole cache —
    the merge is bit-identical to `paged_decode_iteration_spmd`'s; a prefill
    chunk's prefix is the part of its prompt already written through
    `fill_packed`, so the pool IS the carried (acc, m, l) flash state across
    engine iterations.

    In-program epilogue: the final hidden stripes are all_gathered, each
    SEGMENT's last token row is unembedded and greedily argmaxed (bit-equal
    to the engine's host `_sample_token`), and the packed per-layer KV comes
    back token-sharded for write-through scatter.  Like the decode routed
    path, the SPMD program has no host NaN guard — chaos NaN injection is a
    LocalExecutor concern (documented degradation gap).

    The chunk schedule is position-agnostic: a segment may start ANYWHERE in
    its request as long as the pools cover every lower position (the
    fault-recovery hole-filling schedule — see `core.unified` — rides this
    same program; the engine marks hole segments non-final so their rows are
    never sampled).

    toks [T] int32 STRIPED order, sharded over ``sp_axis`` (T % n == 0);
    positions [T] int32 replicated, striped order (prefix query_pos; ranks
    slice their own stripe for RoPE); seq_offsets [S+1] replicated GLOBAL
    packed offsets; last_idx [S] replicated striped-coordinate indices of
    each segment's sampling row (bucket-pad rows point at 0, never read);
    k_pages/v_pages [n, L, n_pages, P, KVH, D], table [n, T, max_pages],
    lengths [n, T], page_pos [n, n_pages, P] (window only) — leading axis =
    rank.  Returns (ids [S] replicated, k_packed, v_packed [L, T, KVH, D]
    sharded on the striped token axis)."""
    from repro.core.unified import UnifiedShard
    from repro.kernels import ops

    n = int(mesh.shape[sp_axis])
    t = int(toks.shape[0])
    assert t % n == 0 and int(k_pages.shape[0]) == n, (t, k_pages.shape, n)
    t_l = t // n
    ops.dispatch_counts["unified_iteration_spmd"] += 1
    sp = sp_axis
    has_pos = page_pos is not None

    def body(prm, tk, posf, ob, li_, kb, vb, tb, lb, *pb):
        # tk: this rank's token stripe [T/n]; kb/vb/tb/lb/pb: its pool plane
        # + per-token paged operands over the FULL striped axis (leading
        # shard dim 1); posf/ob/li_: replicated
        r = lax.axis_index(sp)
        posl = lax.dynamic_slice_in_dim(posf, r * t_l, t_l, axis=0)
        shard = UnifiedShard(
            kb[0], vb[0], pb[0][0] if has_pos else None, tb[0], lb[0]
        )
        impl.begin_step(
            ob, posf, max_seq_len=max_seq_len, shards=[shard], axis_name=sp,
            n_ranks=n, double_buffer=double_buffer,
        )
        try:
            x, kv = model.prefill_packed_hidden(
                prm, {"tokens": tk[None]}, posl, unroll=True
            )
        finally:
            impl.end_step()
        xg = ops.all_gather(x[0], sp, axis=0)  # [T, d]
        sel = jnp.take(xg, li_, axis=0)
        logits = model.unembed(prm, sel[None])[0]  # [S, V]
        ids = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return ids, kv[0], kv[1]

    specs = [P(), P(sp), P(None), P(None), P(None), P(sp), P(sp), P(sp),
             P(sp)]
    args = [params, toks, positions, jnp.asarray(seq_offsets, jnp.int32),
            jnp.asarray(last_idx, jnp.int32), k_pages, v_pages, table,
            lengths]
    if has_pos:
        specs.append(P(sp))
        args.append(page_pos)
    fn = _shmap(
        body, mesh, in_specs=tuple(specs),
        out_specs=(P(None), P(None, sp), P(None, sp)),
    )
    return fn(*args)


class ESPAttnImpl(DefaultAttnImpl):
    def __init__(
        self,
        mesh: Mesh,
        cfg: ModelConfig,
        *,
        sp_axis: str = "data",
        tp_axis: Optional[str] = "model",
        dop: Optional[int] = None,
        force_batch_mode: bool = False,
        ring_slice_tp: bool = False,
        interpret: bool = False,
    ):
        self.mesh = mesh
        self.cfg = cfg
        self.sp = sp_axis
        self.tp = tp_axis if (tp_axis and tp_axis in mesh.axis_names) else None
        self.n_sp = mesh.shape[sp_axis]
        self.n_tp = mesh.shape[self.tp] if self.tp else 1
        self.dop = dop or self.n_sp
        assert self.n_sp % self.dop == 0
        # prefill head sharding mode. Hybrid/ssm archs force batch mode so
        # attention sharding matches the recurrent layers' (batch-over-tp)
        # activation layout with no per-layer reshard.
        self.heads_mode = (
            not force_batch_mode
            and (self.n_tp == 1 or cfg.n_heads % self.n_tp == 0)
        )
        self.kv_div = cfg.n_kv_heads % self.n_tp == 0 if self.n_tp > 1 else True
        # decode KV sharding mode (mode1: heads over tp; mode2: seq over both)
        self.decode_heads_mode = (
            not force_batch_mode
            and (
                self.n_tp == 1
                or (cfg.n_kv_heads % self.n_tp == 0 and cfg.n_heads % self.n_tp == 0)
            )
        )
        # beyond-paper (§Perf A2): when KV heads are replicated across tp
        # (GQA kv < tp), the naive ring circulates the SAME stripe on every
        # tp rank (tp-fold redundant ICI traffic). slice-ring sends each tp
        # rank 1/tp of the stripe's tokens and all-gathers locally after
        # receive — ring-leg traffic drops by tp.
        self.ring_slice_tp = ring_slice_tp
        self.interpret = interpret

    # ---------------------------------------------------------------- prefill
    def prefill_attn(self, q, k, v, q_pos, k_pos, *, causal, window, softcap):
        """q [B,S,H,D] in the (striped) layout matching q_pos; S shards over
        sp as the stripes. Returns [B,S,H,D]."""
        n_sp, tp = self.n_sp, self.tp
        if n_sp == 1:
            return super().prefill_attn(
                q, k, v, q_pos, k_pos, causal=causal, window=window, softcap=softcap
            )
        h_local = self.cfg.n_heads // self.n_tp if (self.heads_mode and tp) else self.cfg.n_heads
        q_per_kv = self.cfg.q_per_kv
        slice_kv = self.heads_mode and tp and not self.kv_div
        pairs = striped.ring_pairs(n_sp, self.dop)
        ring_len = self.dop
        sp = self.sp

        slice_ring = (
            self.ring_slice_tp and tp and self.n_tp > 1
            and (not self.kv_div or not self.heads_mode)
        )
        n_tp = self.n_tp
        # ranks holding IDENTICAL kv tensors form the de-dup group: all tp
        # ranks in batch mode; the q_per_kv/h_local block in heads mode
        if slice_ring and self.heads_mode and slice_kv:
            ring_group = max(q_per_kv // h_local, 1)
        else:
            ring_group = n_tp
        if slice_ring and ring_group < 2:
            slice_ring = False
        ag_groups = [
            [b * ring_group + i for i in range(ring_group)]
            for b in range(n_tp // ring_group)
        ] if slice_ring else None

        def body(qb, kb, vb, qp, kp):
            if slice_kv:
                kb, vb = _slice_kv_heads(
                    kb, vb, lax.axis_index(tp), h_local, q_per_kv
                )
            if qp.ndim > 1:  # squeeze leading sharded dummy dims
                qp, kp = qp.reshape(-1), kp.reshape(-1)
            acc = None
            kv_pos = kp
            kk, vv = kb, vb
            s_l = kb.shape[1]
            for step in range(ring_len):
                mask = A.mask_from_positions(
                    qp, kv_pos, causal=causal, window=window
                )
                part = A.partial_attention(qb, kk, vv, mask, softcap=softcap)
                acc = part if acc is None else A.merge_partial(acc, part)
                if step < ring_len - 1:
                    if slice_ring:
                        # A2 slice-ring: each rank of the de-dup group
                        # forwards only its 1/g token slice; receivers
                        # re-gather within the group.
                        tidx = lax.axis_index(tp) % ring_group
                        per = s_l // ring_group
                        ks = lax.dynamic_slice_in_dim(kk, tidx * per, per, 1)
                        vs = lax.dynamic_slice_in_dim(vv, tidx * per, per, 1)
                        ks, vs, kv_pos = lax.ppermute((ks, vs, kv_pos), sp, pairs)
                        kk = lax.all_gather(
                            ks, tp, axis=1, tiled=True,
                            axis_index_groups=ag_groups,
                        )
                        vv = lax.all_gather(
                            vs, tp, axis=1, tiled=True,
                            axis_index_groups=ag_groups,
                        )
                    else:
                        kk, vv, kv_pos = lax.ppermute(
                            (kk, vv, kv_pos), sp, pairs
                        )
            return A.finalize_partial(acc).astype(qb.dtype)

        if self.heads_mode:
            q_spec = P(None, sp, tp, None)
            kv_spec = P(None, sp, tp if (tp and self.kv_div) else None, None)
        else:  # batch mode: batch over tp (replicated if not divisible)
            btp = tp if (tp and q.shape[0] % self.n_tp == 0) else None
            q_spec = P(btp, sp, None, None)
            kv_spec = P(btp, sp, None, None)
        pos_spec = P(sp)
        fn = _shmap(
            body,
            self.mesh,
            in_specs=(q_spec, kv_spec, kv_spec, pos_spec, pos_spec),
            out_specs=q_spec,
        )
        q_pos = jnp.broadcast_to(jnp.asarray(q_pos), (q.shape[1],))
        k_pos = jnp.broadcast_to(jnp.asarray(k_pos), (k.shape[1],))
        return fn(q, k, v, q_pos, k_pos)

    # ---------------------------------------------------------------- decode
    def decode_attn(self, q, k_cache, v_cache, k_new, v_new, cache_len, *,
                    window, softcap):
        """Multi-master distributed decode (LoongServe §4.2).

        q [B,1,H,D]; caches [B,S,KVH,D] sharded over sp (and tp in mode2) on
        the sequence dim; k_new/v_new [B,1,KVH,D] live with the masters."""
        n_sp, tp, sp = self.n_sp, self.tp, self.sp
        if n_sp == 1 and self.n_tp == 1:
            return super().decode_attn(
                q, k_cache, v_cache, k_new, v_new, cache_len,
                window=window, softcap=softcap,
            )
        b = q.shape[0]
        multi_master = b % n_sp == 0 and b >= n_sp
        heads_mode = self.decode_heads_mode
        h_local = self.cfg.n_heads // self.n_tp if (heads_mode and tp) else self.cfg.n_heads
        n_tp = self.n_tp

        def body(qb, kb, vb, knb, vnb, cl):
            # --- local KV shard positions ---
            s_l = kb.shape[1]
            if heads_mode:
                lin = lax.axis_index(sp)
            else:
                lin = lax.axis_index(sp) * n_tp + (lax.axis_index(tp) if tp else 0)
            off = lin * s_l
            pos = off + jnp.arange(s_l)
            # --- gather queries from masters (the q broadcast) ---
            if multi_master:
                qg = lax.all_gather(qb, sp, axis=0, tiled=True)  # [B,1,h,D]
            else:
                qg = qb
            valid = pos[None, :] < cl[:, None]
            qpos = cl[:, None]
            mask = A.mask_from_positions(
                qpos, jnp.broadcast_to(pos, (b, s_l)), causal=True,
                window=window, k_valid=valid,
            )
            part = A.partial_attention(qg, kb, vb, mask, softcap=softcap)
            # --- LSE-weighted combine across KV shards ---
            axes = (sp,) if heads_mode else ((sp, tp) if tp else (sp,))
            m_g = lax.pmax(part.m, axes)
            m_safe = jnp.where(jnp.isinf(m_g), 0.0, m_g)
            w = jnp.where(jnp.isinf(part.m), 0.0, jnp.exp(part.m - m_safe))
            o_w = part.o * w[..., None]
            l_w = part.l * w
            if not heads_mode and tp:
                o_w = lax.psum(o_w, tp)
                l_w = lax.psum(l_w, tp)
            if multi_master:
                # reduce-scatter back to masters (batch shards over sp)
                o_s = lax.psum_scatter(o_w, sp, scatter_dimension=0, tiled=True)
                l_s = lax.psum_scatter(l_w, sp, scatter_dimension=0, tiled=True)
                b_l = b // n_sp
                m_s = lax.dynamic_slice_in_dim(
                    m_g, lax.axis_index(sp) * b_l, b_l, axis=0
                )
            else:
                o_s = lax.psum(o_w, sp)
                l_s = lax.psum(l_w, sp)
                m_s = m_g
            # --- merge the master-local new-token KV partial ---
            if heads_mode and tp and not self.kv_div:
                knb, vnb = _slice_kv_heads(
                    knb, vnb, lax.axis_index(tp), h_local, self.cfg.q_per_kv
                )
            p_new = A.partial_attention(qb, knb, vnb, None, softcap=softcap)
            merged = A.merge_partial(A.Partial(o_s, m_s, l_s), p_new)
            return A.finalize_partial(merged).astype(qb.dtype)

        bspec = sp if multi_master else None
        if heads_mode:
            q_spec = P(bspec, None, tp, None)
            kv_spec = P(None, sp, tp, None)
            new_spec = P(bspec, None, tp if self.kv_div else None, None)
        else:
            q_spec = P(bspec, None, None, None)
            kv_spec = P(None, (sp, tp) if tp else sp, None, None)
            new_spec = P(bspec, None, None, None)
        fn = _shmap(
            body,
            self.mesh,
            in_specs=(q_spec, kv_spec, kv_spec, new_spec, new_spec, P(None)),
            out_specs=q_spec,
        )
        cl = jnp.broadcast_to(jnp.asarray(cache_len), (b,))
        return fn(q, k_cache, v_cache, k_new, v_new, cl)

    # ------------------------------------------------------------ recurrent
    def ssm_scan(self, kind, p, x, cfg, state):
        """Sequence-parallel recurrent layers (hybrid/ssm archs).

        Mamba2/mLSTM use the 3-phase chunk-state handoff (local state-only
        fold -> log-step exclusive device scan -> local pass with the true
        incoming state). sLSTM is inherently sequential (xLSTM paper §2.3):
        we all-gather its input and scan redundantly, slicing the local part.
        These run on the *contiguous* (non-striped) layout; see
        DESIGN.md §Arch-applicability.
        """
        if self.n_sp == 1:
            return super().ssm_scan(kind, p, x, cfg, state)
        from repro.core import ssm_sp

        fns = {
            "mamba": ssm_sp.mamba2_forward_sp,
            "mlstm": ssm_sp.mlstm_forward_sp,
            "slstm": ssm_sp.slstm_forward_sp,
        }
        return fns[kind](
            self.mesh, self.sp, p, x, cfg, state, tp=self.tp,
            interpret=self.interpret,
        )

"""ESP core: striped ring prefill, multi-master decode, SP recurrent handoff."""
from repro.core.esp import ESPAttnImpl  # noqa: F401
from repro.core import striped  # noqa: F401

"""Striped sequence permutation (Striped Attention, Brandon et al. 2023).

Token t of the original sequence is assigned to SP rank (t mod n) at local
offset (t div n). Striping balances causal-mask work across ranks: at every
ring step each rank computes an (almost) equal number of unmasked entries,
unlike contiguous Ring Attention blocks where rank 0 is mostly masked.

All model math is position-based (RoPE, masks), so running the model on the
permuted layout with the matching `positions` array is exact.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np
import jax.numpy as jnp


def stripe_indices(seq_len: int, n: int) -> np.ndarray:
    """perm[i] = original index of the i-th token in striped layout.

    Striped layout = concat of per-rank stripes: rank r holds original
    tokens [r, r+n, r+2n, ...]. seq_len must be divisible by n.
    """
    assert seq_len % n == 0, (seq_len, n)
    local = seq_len // n
    idx = np.arange(seq_len).reshape(local, n).T.reshape(-1)  # [n*local]
    return idx


def unstripe_indices(seq_len: int, n: int) -> np.ndarray:
    """inv[j] = position in striped layout of original token j."""
    perm = stripe_indices(seq_len, n)
    inv = np.empty_like(perm)
    inv[perm] = np.arange(seq_len)
    return inv


def stripe(x: jnp.ndarray, n: int, axis: int = 1) -> jnp.ndarray:
    """Permute `axis` of x into striped layout."""
    idx = stripe_indices(x.shape[axis], n)
    return jnp.take(x, jnp.asarray(idx), axis=axis)


def unstripe(x: jnp.ndarray, n: int, axis: int = 1) -> jnp.ndarray:
    idx = unstripe_indices(x.shape[axis], n)
    return jnp.take(x, jnp.asarray(idx), axis=axis)


def striped_positions(seq_len: int, n: int, offset: int = 0) -> jnp.ndarray:
    """Global positions of tokens in the striped layout ([S] int32)."""
    return jnp.asarray(stripe_indices(seq_len, n) + offset, jnp.int32)


def ring_pairs(n: int, group: int | None = None) -> list[Tuple[int, int]]:
    """(src, dst) ppermute pairs for a ring; optionally rings within disjoint
    subgroups of size `group` (elastic ESP groups sharing one mesh axis)."""
    g = group or n
    assert n % g == 0
    pairs = []
    for base in range(0, n, g):
        for i in range(g):
            pairs.append((base + i, base + (i + 1) % g))
    return pairs


def ring_chunk_schedule(n: int, group: int | None = None) -> list[list[int]]:
    """``sched[step][rank]`` — which rank's original KV chunk each rank holds
    at every ring step, obtained by *simulating* the `ring_pairs` ppermute
    schedule (every rank starts with its own chunk; each step forwards it to
    the ring neighbour).  The packed-prefill ring driver replays this
    schedule chunk-by-chunk so the single-process simulation runs exactly the
    launches the SPMD ppermute ring would."""
    g = group or n
    pairs = ring_pairs(n, g)
    held = list(range(n))
    sched = [list(held)]
    for _ in range(g - 1):
        nxt = list(held)
        for src, dst in pairs:
            nxt[dst] = held[src]
        held = nxt
        sched.append(list(held))
    return sched


def chunk_provenance(n: int, step: int, group: int | None = None) -> list[int]:
    """Closed form of ``ring_chunk_schedule(n, group)[step]``: after ``step``
    forwards of the `ring_pairs` rotation, rank ``r`` holds the chunk that
    originated at rank ``base + (r - step) mod g`` of its subgroup.  The
    SPMD ring driver INLINES this formula with a traced ``axis_index`` in
    place of ``r`` (`esp.ring_packed_prefill_spmd`); this helper is the
    testable closed form the parity test pins against the simulated
    ppermute schedule — change them together."""
    g = group or n
    return [(r // g) * g + (r % g - step) % g for r in range(n)]


def all_shard_offsets(seq_offsets, n: int):
    """[n, B+1] per-shard segment offsets, stacked — the static per-shard
    schedule of a striped packed batch (row r = `shard_offsets(.., n, r)`),
    consumed by the in-process ring replay (`esp.ring_packed_prefill`).
    The mesh executor's shard_map body instead derives its row in place
    from the replicated global offsets with a traced shard id (see
    `esp.ring_packed_prefill_spmd`), so only KV bytes ride the ring."""
    return jnp.stack([shard_offsets(seq_offsets, n, r) for r in range(n)])


def shard_offsets(seq_offsets, n: int, shard: int):
    """Per-shard segment offsets of a striped packed axis.

    Global packed index ``g`` lives on shard ``g % n`` at local slot
    ``g // n``; entry ``b`` of the result is the number of shard-local tokens
    with global packed index < ``seq_offsets[b]`` — i.e. the boundaries of
    request b's contiguous run inside the shard's local order.  Works on
    numpy or traced jnp offsets."""
    off = jnp.asarray(seq_offsets, jnp.int32)
    return jnp.maximum((off - shard + n - 1) // n, 0).astype(jnp.int32)

"""Packed ragged prefill: the model-side plug for the packed-prefill kernel.

Mirrors `core.paged_decode`: the engine arms the impl for one packed prefill
step (`begin_step` with the batch's segment offsets), runs the model's
`prefill_packed` entry point, and disarms.  Per layer the impl issues exactly
ONE `ops.prefill_packed` launch for the whole batch — the prompts are packed
on a single token axis and the kernel's scalar-prefetched boundary array
masks cross-request attention — instead of O(batch) per-request
`model.prefill` programs, one per distinct prompt length.

DoP>1 ESP groups arm the same impl with ``dop=n``: the packed axis is then
striped across the group's n instances and attention runs as the fused
striped ring — one packed ragged `ops.prefill_ring_chunk` launch per
instance per ring step, carrying the (acc, m, l) flash state across steps —
so the paper's long-prompt multi-instance prefill gets packed-kernel speed
instead of the per-request serial fallback.  Two ring deployments behind
the same arming call: the in-process replay (`core.esp.ring_packed_prefill`,
LocalExecutor) and, with ``mesh=``, ONE shard_map program over the mesh's
"data" axis (`core.esp.ring_packed_prefill_spmd`, MeshExecutor) where each
instance physically holds its stripe and the KV chunks `ppermute` between
devices, double-buffered against the fold.

The impl subclasses `DefaultAttnImpl`, so outside a `begin_step`/`end_step`
window (per-request prefill, oracle comparisons) it behaves exactly like the
default dense math.
"""
from __future__ import annotations

from typing import Optional

from repro.kernels import ops
from repro.models.transformer import DefaultAttnImpl


class PackedPrefillAttnImpl(DefaultAttnImpl):
    """Segment-masked causal attention over a packed ragged prefill batch."""

    def __init__(self, impl: Optional[str] = None):
        self._offsets = None  # [B+1] packed segment boundaries
        self._max_seq_len: Optional[int] = None  # static reach bound
        self._dop: int = 1  # ESP group size: >1 runs the fused striped ring
        self._mesh = None  # DoP>1 on a real mesh: shard_map ring (esp.*_spmd)
        self._double_buffer = True
        self._impl = impl  # kernel impl override (None -> ops default)

    def begin_step(
        self, seq_offsets, max_seq_len: Optional[int] = None, dop: int = 1,
        mesh=None, double_buffer: bool = True,
    ) -> None:
        """Arm the packed path for one prefill step.  `max_seq_len` is a
        STATIC python upper bound on the longest prompt in the batch (the
        engine buckets it) — it sizes the banded XLA fallback's reach.
        `dop` (STATIC) is the ESP group size: with dop>1 the packed token
        axis (which the engine buckets to a multiple of dop) stripes across
        the group and attention runs the fused ring — in-process replay by
        default, or as ONE shard_map program over `mesh`'s "data" axis (the
        mesh executor; requires ``mesh.shape["data"] == dop``) with the KV
        stripes `ppermute`d between devices, double-buffered against the
        chunk compute unless ``double_buffer=False``."""
        self._offsets = seq_offsets
        self._max_seq_len = max_seq_len
        self._dop = int(dop)
        self._mesh = mesh
        self._double_buffer = double_buffer

    def end_step(self) -> None:
        self._offsets = None
        self._max_seq_len = None
        self._dop = 1
        self._mesh = None
        self._double_buffer = True

    def prefill_attn(self, q, k, v, q_pos, k_pos, *, causal, window, softcap):
        if self._offsets is None:
            return super().prefill_attn(
                q, k, v, q_pos, k_pos, causal=causal, window=window,
                softcap=softcap,
            )
        assert q.shape[0] == 1, "packed prefill uses batch dim 1"
        if self._dop > 1 and self._mesh is not None:
            from repro.core.esp import ring_packed_prefill_spmd

            assert int(self._mesh.shape["data"]) == self._dop, (
                dict(self._mesh.shape), self._dop
            )
            out = ring_packed_prefill_spmd(
                self._mesh, q[0], k[0], v[0], self._offsets, window=window,
                softcap=softcap, max_seq_len=self._max_seq_len,
                impl=self._impl, double_buffer=self._double_buffer,
            )
        elif self._dop > 1:
            from repro.core.esp import ring_packed_prefill

            out = ring_packed_prefill(
                q[0], k[0], v[0], self._offsets, self._dop, window=window,
                softcap=softcap, max_seq_len=self._max_seq_len,
                impl=self._impl,
            )
        else:
            out = ops.prefill_packed(
                q[0], k[0], v[0], self._offsets, window=window,
                softcap=softcap, max_seq_len=self._max_seq_len,
                impl=self._impl,
            )
        return out[None].astype(q.dtype)

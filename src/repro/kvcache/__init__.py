"""Unified distributed KV cache pool at single-token granularity."""
from repro.kvcache.pool import KVPool, OutOfSlots  # noqa: F401
from repro.kvcache.distributed import DistributedKVPool, PlacementPlan  # noqa: F401

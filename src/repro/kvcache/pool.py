"""Token-granularity KV pool backed by page-aligned storage.

LoongServe manages KV "at the granularity of a single token across instances
without any locality constraints" (§1, §4).  Logically nothing changed: a
request's tokens may land on any subset of instances.  Physically, each
instance now backs its slots with fixed-size *pages* so the decode kernel can
attend in place over the pool storage through a per-request block table —
no dense per-request gather on the hot path.

Layout invariant: a request's local tokens are packed densely, in append
order, into pages it owns exclusively.  Local index ``j`` lives in page
``pages[j // P]`` at offset ``j % P`` (slot id ``pages[j // P] * P + j % P``).
``page_size=1`` (the default) degenerates to exact token-granular accounting —
every token is its own page, so there is zero internal fragmentation and the
legacy OutOfSlots semantics hold bit-for-bit.  Larger pages trade a bounded
tail-page slack for kernel-friendly contiguity; ``free_slots`` then reports
whole free pages only (conservative), while a request can always extend into
its own tail slack.

All bookkeeping is vectorized numpy (free page stack, per-request page/pos
arrays) — no per-token dicts anywhere on the hot path.  `bytes_per_slot`
reflects the real bf16 KV footprint so pool capacities model HBM honestly.

KV lifecycle (host bookkeeping vs device-resident storage)
----------------------------------------------------------
The pool holds TWO coupled copies of the stored KV:

  * the host numpy arrays ``k``/``v``/``slot_pos`` — the management plane.
    Placement planning, migration, gather, SWA eviction and checkpoints all
    read/write these; they are cheap to mutate token-granularly.
  * a device mirror (``device_kv()``) — the compute plane the paged decode
    kernel attends *in place* through block tables.

Writes through ``write``/``fill`` land on the host copy and mark the touched
slots dirty; the next ``device_kv()`` call uploads only those slots (or does
one full resync after load/failure).  ``fill_packed`` is the write-through
fast path for packed prefill: the KV is already device-resident (produced by
the packed prefill step), so it is scattered straight into the mirror
device-to-device and the slots are marked STALE on the host instead of being
downloaded — the prefill critical path stays device-only.  The host
management copy lazily resyncs FROM the mirror only when a management
operation actually reads it (``gather`` for migration/debug, SWA compaction,
checkpointing); ``host_syncs`` counts those forced downloads and the
``mirror_full_syncs``/``mirror_uploaded_slots`` counters let tests and
benchmarks assert the zero-re-upload invariant.

Ring-step KV ownership (DoP>1 ESP prefill) — see DESIGN.md §6
-------------------------------------------------------------
Under the fused striped ring, the packed token axis of a prefill batch is
striped across the group's instances (global packed column ``g`` belongs to
instance ``g % n``); each ring step circulates the KV *chunks* between
instances, but ownership never moves: every instance write-throughs exactly
the packed columns of its own reserved placement (``batch.placement``) via
``fill_packed``, the same columns its stripe produced.  Proactive ESP
scale-down therefore stays zero-copy — the scheduler reserves the shrunken
group's slots BEFORE the ring runs, the ring pass deposits each column at
its final home as a side effect of computation, and no post-hoc migration of
the dropped instances' shards is ever needed (their columns were simply
never assigned to them).

Under the mesh executor the mirror is additionally PINNED to the instance's
own data-shard device (``bind_device``): ownership is physical device
residency, and ``fill_packed``'s scatter runs where the stripe lives.
Checkpoints snapshot occupied-slot KV values from the host copy (forcing
the deferred stale-slot download exactly once); restore drops the mirror
and rebuilds it from host on the bound device.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.configs.base import ModelConfig


class OutOfSlots(RuntimeError):
    pass


_MIRROR_SCATTER = None


def _mirror_scatter():
    """Lazily-jitted (K, V, slot_pos) mirror scatter, shared by the dirty
    sync and the packed-prefill write-through.  Donation keeps it O(idx) and
    allocation-free on accelerators; CPU falls back to a copy."""
    global _MIRROR_SCATTER
    if _MIRROR_SCATTER is None:
        import jax

        donate = (0, 1, 2) if jax.default_backend() != "cpu" else ()
        _MIRROR_SCATTER = jax.jit(
            lambda kd, vd, pd, idx, kn, vn, pn: (
                kd.at[:, idx].set(kn), vd.at[:, idx].set(vn),
                pd.at[idx].set(pn),
            ),
            donate_argnums=donate,
        )
    return _MIRROR_SCATTER


def _pad_bucket(n: int) -> int:
    """Power-of-two bucket so the jitted scatter compiles O(log capacity)
    variants instead of one per distinct index count."""
    return 1 << max(n - 1, 0).bit_length()


@dataclass
class TokenRef:
    """Where one token's KV lives."""

    instance: int
    slot: int


class _ReqState:
    """Per-request paged bookkeeping: owned pages + global positions, both
    as amortized-growth numpy arrays indexed by local token order."""

    __slots__ = ("pages", "n_pages", "pos", "n_tok", "max_pos")

    def __init__(self):
        self.pages = np.empty(4, np.int32)
        self.n_pages = 0
        self.pos = np.empty(8, np.int64)
        self.n_tok = 0
        self.max_pos = -1  # O(1) is-new check for the append hot path

    def _grow(self, arr: np.ndarray, need: int) -> np.ndarray:
        if need <= len(arr):
            return arr
        new = np.empty(max(need, 2 * len(arr)), arr.dtype)
        new[: len(arr)] = arr
        return new

    def append_pages(self, new_pages: np.ndarray) -> None:
        self.pages = self._grow(self.pages, self.n_pages + len(new_pages))
        self.pages[self.n_pages : self.n_pages + len(new_pages)] = new_pages
        self.n_pages += len(new_pages)

    def append_pos(self, positions: np.ndarray) -> None:
        self.pos = self._grow(self.pos, self.n_tok + len(positions))
        self.pos[self.n_tok : self.n_tok + len(positions)] = positions
        self.n_tok += len(positions)
        if len(positions):
            self.max_pos = max(self.max_pos, int(positions.max()))


class KVPool:
    """Per-instance pool: token-granular slots on page-aligned storage."""

    def __init__(self, cfg: ModelConfig, capacity: int, instance_id: int = 0,
                 store_values: bool = True, page_size: int = 1):
        assert page_size >= 1 and capacity % page_size == 0, (
            capacity, page_size
        )
        self.cfg = cfg
        self.capacity = int(capacity)
        self.instance_id = instance_id
        self.store_values = store_values
        self.page_size = int(page_size)
        self.n_pages = self.capacity // self.page_size
        n_attn = max(cfg.n_attention_applications, 1)
        self.n_attn = n_attn
        # free page stack: pop from the end
        self._free_pages = np.arange(self.n_pages - 1, -1, -1, dtype=np.int32)
        self._n_free_pages = self.n_pages
        self._reqs: Dict[int, _ReqState] = {}
        self._used_tokens = 0
        # global position of the token stored in each slot (-1 = unoccupied)
        self.slot_pos = np.full(self.capacity, -1, np.int32)
        if store_values:
            shape = (n_attn, self.capacity, cfg.n_kv_heads, cfg.head_dim)
            self.k = np.zeros(shape, np.float32)
            self.v = np.zeros(shape, np.float32)
        # device-mirror dirty tracking + the mirror itself (compute plane)
        self._dirty_full = True
        self._dirty: List[np.ndarray] = []
        self._dirty_count = 0
        self._mirror = None  # (k_dev, v_dev, slot_pos_dev) jax arrays
        self.device = None  # mirror placement: None = process default device
        self.mirror_full_syncs = 0
        self.mirror_uploaded_slots = 0
        # lazy host copy: slots whose authoritative KV lives only in the
        # mirror (landed via `fill_packed`); synced down on demand by the
        # management plane (gather / SWA compaction / checkpoint)
        self._stale_host = np.zeros(self.capacity, bool)
        self._stale_count = 0
        self.host_syncs = 0

    # ------------------------------------------------------------- accounting
    @property
    def used(self) -> int:
        """Allocated *tokens* (not pages)."""
        return self._used_tokens

    @property
    def free_slots(self) -> int:
        """Tokens guaranteed allocatable by ANY request: whole free pages.
        (A request holding a partially-filled tail page can additionally
        extend into its own slack.)  Exact for page_size=1."""
        return self._n_free_pages * self.page_size

    @property
    def bytes_per_slot(self) -> int:
        return max(self.cfg.kv_bytes_per_token, 1)

    def requests(self) -> List[int]:
        return list(self._reqs)

    def slots_of(self, request_id: int) -> np.ndarray:
        """Slot ids in local (append) order — vectorized."""
        st = self._reqs.get(request_id)
        if st is None:
            return np.empty(0, np.int64)
        return self.slots_of_state(st)

    def tokens_of(self, request_id: int) -> Dict[int, int]:
        """Legacy mapping {global_pos: slot} (planning / tests)."""
        st = self._reqs.get(request_id)
        if st is None:
            return {}
        return dict(zip(st.pos[: st.n_tok].tolist(),
                        self.slots_of(request_id).tolist()))

    # ------------------------------------------------------------- alloc/free
    def _pop_pages(self, n: int) -> np.ndarray:
        pages = self._free_pages[self._n_free_pages - n : self._n_free_pages]
        self._n_free_pages -= n
        return pages.copy()

    def _push_pages(self, pages: np.ndarray) -> None:
        n = len(pages)
        self._free_pages[self._n_free_pages : self._n_free_pages + n] = pages
        self._n_free_pages += n

    def alloc(self, request_id: int, positions: Sequence[int]) -> List[int]:
        pos = np.asarray(positions, np.int64)
        n = len(pos)
        st = self._reqs.get(request_id)
        slack = (st.n_pages * self.page_size - st.n_tok) if st else 0
        need_pages = max(0, -(-(n - slack) // self.page_size)) if n > slack else 0
        if need_pages > self._n_free_pages:
            raise OutOfSlots(
                f"instance {self.instance_id}: need {n} tokens "
                f"({need_pages} pages), free {self.free_slots} tokens "
                f"({self._n_free_pages} pages)"
            )
        if st is None:
            st = self._reqs[request_id] = _ReqState()
        # duplicate guard: the decode hot path (single append past max_pos)
        # is O(1); the full scans only run for bulk/out-of-order allocs
        if n > 1:
            assert len(np.unique(pos)) == n, (request_id, positions)
        if n and st.n_tok and not (n == 1 and int(pos[0]) > st.max_pos):
            assert not np.isin(pos, st.pos[: st.n_tok]).any(), (
                request_id, positions
            )
        if need_pages:
            st.append_pages(self._pop_pages(need_pages))
        start = st.n_tok
        st.append_pos(pos)
        self._used_tokens += n
        slots = self._local_slots(st, start, n)
        self.slot_pos[slots] = pos
        return slots.tolist()

    def _local_slots(self, st: _ReqState, start: int, n: int) -> np.ndarray:
        """Slot ids for local indices [start, start+n)."""
        if n == 0:
            return np.empty(0, np.int64)
        j = np.arange(start, start + n)
        return st.pages[j // self.page_size].astype(np.int64) * self.page_size \
            + j % self.page_size

    def free_request(self, request_id: int) -> int:
        st = self._reqs.pop(request_id, None)
        if st is None:
            return 0
        self.slot_pos[self.slots_of_state(st)] = -1
        self._push_pages(st.pages[: st.n_pages])
        self._used_tokens -= st.n_tok
        return st.n_tok

    def slots_of_state(self, st: _ReqState) -> np.ndarray:
        return self._local_slots(st, 0, st.n_tok)

    def free_positions(self, request_id: int, positions: Sequence[int]) -> int:
        """Free specific token positions (SWA window eviction).  The request's
        surviving tokens are compacted so the packed-page layout invariant is
        preserved; emptied tail pages return to the free stack."""
        st = self._reqs.get(request_id)
        if st is None:
            return 0
        drop = np.isin(st.pos[: st.n_tok], np.asarray(positions, np.int64))
        n_drop = int(drop.sum())
        if n_drop == 0:
            return 0
        self._sync_host()  # compaction moves host KV between slots
        old_slots = self.slots_of_state(st)
        keep_slots = old_slots[~drop]
        keep_pos = st.pos[: st.n_tok][~drop]
        n_keep = st.n_tok - n_drop
        if n_keep == 0:
            self.free_request(request_id)
            return n_drop
        self.slot_pos[old_slots] = -1
        st.n_tok = 0  # rebuild the packed prefix
        st.pos[:n_keep] = keep_pos
        st.n_tok = n_keep
        st.max_pos = int(keep_pos.max())
        new_slots = self._local_slots(st, 0, n_keep)
        moved = new_slots != keep_slots
        if self.store_values and moved.any():
            # fancy-index gather materializes the RHS first, so overlapping
            # src/dst ranges are safe
            self.k[:, new_slots[moved]] = self.k[:, keep_slots[moved]]
            self.v[:, new_slots[moved]] = self.v[:, keep_slots[moved]]
            self._mark_dirty(new_slots[moved])
        self.slot_pos[new_slots] = keep_pos
        n_pages_keep = -(-n_keep // self.page_size)
        if n_pages_keep < st.n_pages:
            self._push_pages(st.pages[n_pages_keep: st.n_pages])
            st.n_pages = n_pages_keep
        self._used_tokens -= n_drop
        return n_drop

    def positions_of(self, request_id: int) -> np.ndarray:
        """Sorted global positions this pool holds for `request_id` (the
        instance's leg of a sparse coverage map; empty when absent)."""
        st = self._reqs.get(request_id)
        if st is None:
            return np.empty(0, np.int64)
        return np.sort(st.pos[: st.n_tok].copy())

    def insert_positions(self, request_id: int, positions: Sequence[int]) -> List[int]:
        """Reserve positions that may PRECEDE positions the request already
        holds here (fault salvage re-reserves a dead rank's stripe on the
        survivors, whose own stripes sit at higher positions).  `alloc`
        appends, which would break the position-ascending local order
        `prefix_block_table` relies on; this restores it by permuting the
        request's local indices — and the stored KV with them — after the
        append.  The inserted slots hold no KV yet: the recovery chain
        fills them through the usual `slots_for` + fill paths."""
        pos = np.sort(np.asarray(positions, np.int64))
        if len(pos) == 0:
            return []
        st = self._reqs.get(request_id)
        if st is None or st.n_tok == 0 or int(pos[0]) > st.max_pos:
            return self.alloc(request_id, pos)  # plain append stays sorted
        if self.store_values:
            self._sync_host()  # the permutation moves host KV between slots
        self.alloc(request_id, pos)
        st = self._reqs[request_id]
        cur = st.pos[: st.n_tok].copy()
        order = np.argsort(cur, kind="stable")
        slots = self.slots_of_state(st)
        moved = order != np.arange(st.n_tok)
        if self.store_values and moved.any():
            # fancy-index gather materializes the RHS first, so overlapping
            # src/dst slot sets are safe; local index j takes the KV that
            # lived at local index order[j]
            self.k[:, slots[moved]] = self.k[:, slots[order[moved]]]
            self.v[:, slots[moved]] = self.v[:, slots[order[moved]]]
            self._mark_dirty(slots[moved])
        st.pos[: st.n_tok] = cur[order]
        self.slot_pos[slots] = cur[order]
        return slots[np.searchsorted(cur[order], pos)].tolist()

    # ------------------------------------------------------------------ data
    def _mark_dirty(self, slots: np.ndarray) -> None:
        if self._dirty_full or len(slots) == 0:
            return
        self._dirty.append(np.asarray(slots, np.int64))
        self._dirty_count += len(slots)
        if self._dirty_count > self.capacity // 4:
            self._dirty_full = True
            self._dirty.clear()
            self._dirty_count = 0

    def _mark_stale_host(self, slots: np.ndarray) -> None:
        if len(slots):  # count updates are O(len(slots)), not O(capacity)
            self._stale_count += len(slots) - int(
                np.count_nonzero(self._stale_host[slots])
            )
            self._stale_host[slots] = True

    def _clear_stale_host(self, slots: np.ndarray) -> None:
        """Host-side writes (`write`/`fill`) make the host authoritative for
        their slots again (reused pages may carry a stale flag from a freed
        request) — drop the flag WITHOUT downloading."""
        if self._stale_count and len(slots):
            self._stale_count -= int(np.count_nonzero(self._stale_host[slots]))
            self._stale_host[slots] = False

    def stale_host_slot_count(self) -> int:
        """Slots whose host copy is behind the device mirror (the probe for
        the lazy-host-copy invariant: >0 right after a packed prefill, 0
        after any management-plane read forced a sync)."""
        return self._stale_count

    def _sync_host(self) -> None:
        """On-demand download of stale slots from the mirror to the host
        management copy (migration / gather / SWA compaction / checkpoints
        read it).  Off the prefill critical path by construction."""
        if self._stale_count == 0:
            return
        slots = np.nonzero(self._stale_host)[0]
        if self._mirror is not None:
            kd, vd, _ = self._mirror
            self.k[:, slots] = np.asarray(kd[:, slots], np.float32)
            self.v[:, slots] = np.asarray(vd[:, slots], np.float32)
            self.host_syncs += 1
        self._stale_host[:] = False
        self._stale_count = 0

    def dirty_slot_count(self) -> int:
        """Slots the next `device_kv()` sync would upload (capacity if a
        full resync is pending) — the public probe for the write-through
        invariant: 0 right after a packed prefill."""
        return self.capacity if self._dirty_full else self._dirty_count

    def consume_dirty(self) -> Tuple[bool, np.ndarray]:
        """(full_resync_needed, dirty slot ids) since the last call; resets.
        The engine's device mirror applies these incrementally instead of
        re-uploading the pool every iteration."""
        full, dirty = self._dirty_full, self._dirty
        self._dirty_full = False
        self._dirty = []
        self._dirty_count = 0
        if full:
            return True, np.empty(0, np.int64)
        if not dirty:
            return False, np.empty(0, np.int64)
        return False, np.unique(np.concatenate(dirty))

    def write(self, request_id: int, positions: Sequence[int],
              k: np.ndarray, v: np.ndarray) -> None:
        """k/v: [n_attn, n_tokens, KVH, D] for `positions` (allocates)."""
        slots = np.asarray(self.alloc(request_id, positions), np.int64)
        if self.store_values:
            self._clear_stale_host(slots)
            self.k[:, slots] = np.asarray(k, np.float32)
            self.v[:, slots] = np.asarray(v, np.float32)
            self._mark_dirty(slots)

    def slots_for(self, request_id: int, positions: Sequence[int]) -> np.ndarray:
        """Slot ids of ALREADY-ALLOCATED global positions (any order)."""
        st = self._reqs[request_id]
        pos = np.asarray(positions, np.int64)
        if len(pos) == 0:
            return np.empty(0, np.int64)
        cur = st.pos[: st.n_tok]
        sorter = np.argsort(cur, kind="stable")
        # clip so an unknown position reaches the diagnostic assert below
        # instead of an opaque IndexError
        ss = np.minimum(np.searchsorted(cur, pos, sorter=sorter), st.n_tok - 1)
        li = sorter[ss]
        assert (cur[li] == pos).all(), (request_id, positions)
        return self.slots_of_state(st)[li]

    def fill(self, request_id: int, positions: Sequence[int],
             k: np.ndarray, v: np.ndarray) -> None:
        """Write values into ALREADY-RESERVED slots (proactive scale-down:
        the scheduler reserves placement, the prefill ring fills it)."""
        if not self.store_values:
            return
        slots = self.slots_for(request_id, positions)
        if len(slots) == 0:
            return
        self._clear_stale_host(slots)
        self.k[:, slots] = np.asarray(k, np.float32)
        self.v[:, slots] = np.asarray(v, np.float32)
        self._mark_dirty(slots)

    # --------------------------------------------------------- device mirror
    def bind_device(self, device) -> None:
        """Pin this instance's compute-plane mirror to `device` — under the
        mesh executor, data-shard device i of the ("data", "model") mesh, so
        the instance PHYSICALLY owns its KV stripe: `fill_packed`
        write-through lands the ring pass's reserved placement columns on
        this device, and the paged decode partial over this pool runs here.
        Rebinding drops the mirror (next `device_kv()` rebuilds in place)."""
        if device is not self.device:
            if self._mirror is not None:
                self._sync_host()  # keep fill_packed KV across the rebind
            self.device = device
            self.drop_mirror()

    def _dev_put(self, x):
        """Upload to the bound device (process default when unbound)."""
        import jax
        import jax.numpy as jnp

        if self.device is None:
            return jnp.asarray(x)
        return jax.device_put(jnp.asarray(x), self.device)

    def device_kv(self):
        """Incrementally-synced device mirror of the (K, V, slot_pos)
        storage.  Steady-state decode uploads only the slots written since
        the last call (one per request per iteration), not the pool; slots
        landed through `fill_packed` were written device-side already and
        upload nothing."""
        import jax.numpy as jnp

        assert self.store_values, "device mirror needs value storage"
        full, dirty = self.consume_dirty()
        cur = self._mirror
        if cur is None or full:
            # a full resync uploads the HOST copy wholesale: pull any
            # stale-host slots (authoritative only in the mirror) down first
            # or their KV would be overwritten with never-synced host data
            self._sync_host()
            cur = (self._dev_put(self.k), self._dev_put(self.v),
                   self._dev_put(self.slot_pos))
            self.mirror_full_syncs += 1
            self.mirror_uploaded_slots += self.capacity
        elif len(dirty):
            n = len(dirty)
            bucket = _pad_bucket(n)
            idx = np.concatenate([dirty, np.full(bucket - n, dirty[-1])])
            cur = _mirror_scatter()(
                cur[0], cur[1], cur[2], self._dev_put(idx),
                self._dev_put(self.k[:, idx]), self._dev_put(self.v[:, idx]),
                self._dev_put(self.slot_pos[idx]),
            )
            self.mirror_uploaded_slots += n
        self._mirror = cur
        return cur

    def device_paged_kv(self):
        """Page-shaped view of the device mirror — the per-rank local shard
        of the SPMD decode manual region (and the per-instance launch operand
        of the per-shard loop): ``(k, v, pos)`` reshaped to
        ``[n_attn, n_pages, P, KVH, D]`` / ``[n_pages, P]`` on the bound
        device.  Runs the same incremental dirty sync as `device_kv()`; the
        reshape stays on the mirror's device, so assembling the mesh-wide
        sharded array from these views moves zero KV bytes."""
        kd, vd, pd = self.device_kv()
        paged = (self.n_attn, self.n_pages, self.page_size) + kd.shape[2:]
        return (
            kd.reshape(paged), vd.reshape(paged),
            pd.reshape(self.n_pages, self.page_size),
        )

    def drop_mirror(self) -> None:
        """Invalidate the device mirror (instance failure / state restore);
        the next `device_kv()` rebuilds it with one full upload.  Pending
        stale-host slots are dropped with it: both callers (failure, restore)
        discard the stored KV values anyway."""
        self._mirror = None
        self._dirty_full = True
        self._dirty = []
        self._dirty_count = 0
        self._stale_host[:] = False
        self._stale_count = 0

    def fill_packed(self, slots: np.ndarray, k_dev, v_dev) -> None:
        """Device-side write-through fill: scatter DEVICE-RESIDENT KV (e.g.
        the packed prefill step's per-layer output) straight into the mirror
        at `slots` (block-table rows) WITHOUT dirtying — the next
        `device_kv()` sync uploads nothing for these slots — and WITHOUT
        downloading to the host: the slots are marked stale and the host
        management copy pulls them from the mirror on demand (`_sync_host`),
        keeping the prefill critical path device-only.
        `k_dev`/`v_dev`: [n_attn, len(slots), KVH, D]."""
        if not self.store_values:
            return
        import jax.numpy as jnp

        slots = np.asarray(slots, np.int64)
        n = len(slots)
        if n == 0:
            return
        kd, vd, pd = self.device_kv()  # sync any stale dirty slots first
        bucket = _pad_bucket(n)
        idx = np.concatenate([slots, np.full(bucket - n, slots[-1])])
        # the packed step's output may live on another device (or be sharded
        # across the mesh): pull exactly this instance's columns to ITS
        # device so the scatter runs where the stripe lives
        kn = self._dev_put(jnp.asarray(k_dev, kd.dtype))
        vn = self._dev_put(jnp.asarray(v_dev, vd.dtype))
        if bucket > n:
            reps = (1, bucket - n) + (1,) * (kn.ndim - 2)
            kn = jnp.concatenate([kn, jnp.tile(kn[:, -1:], reps)], axis=1)
            vn = jnp.concatenate([vn, jnp.tile(vn[:, -1:], reps)], axis=1)
        self._mirror = _mirror_scatter()(
            kd, vd, pd, self._dev_put(idx), kn, vn,
            self._dev_put(self.slot_pos[idx]),
        )
        # lazy host copy: defer the device->host download to the first
        # management-plane read (migration / gather / SWA / checkpoint)
        self._mark_stale_host(slots)

    def gather(self, request_id: int) -> Tuple[np.ndarray, Optional[np.ndarray], Optional[np.ndarray]]:
        """Returns (positions sorted, k, v) for this instance's share.
        Off the hot path now (migration / debugging / legacy baselines);
        decode reads the pool in place via `block_table`."""
        st = self._reqs.get(request_id)
        if st is None:
            pos = np.empty(0, np.int64)
        else:
            pos = st.pos[: st.n_tok]
        order = np.argsort(pos, kind="stable")
        positions = pos[order]
        if not self.store_values:
            return positions, None, None
        self._sync_host()
        if len(positions) == 0:
            empty = np.zeros((self.n_attn, 0) + self.k.shape[2:], np.float32)
            return positions, empty, empty.copy()
        slots = self.slots_of_state(st)[order]
        return positions, self.k[:, slots], self.v[:, slots]

    # ------------------------------------------------------------ paged views
    def block_table(self, request_ids: Sequence[int]) -> Tuple[np.ndarray, np.ndarray]:
        """Per-request page tables over THIS pool's storage.

        Returns (table [B, max_pages] int32 — padded with page 0 — and
        lengths [B] int32 — the number of local valid tokens per request).
        Requests with no tokens here get length 0.  Feeding this straight to
        the paged decode kernel is the gather-free hot path.
        """
        states = [self._reqs.get(rid) for rid in request_ids]
        lengths = np.array([st.n_tok if st else 0 for st in states], np.int32)
        max_pages = max((st.n_pages for st in states if st), default=0)
        table = np.zeros((len(states), max_pages), np.int32)
        for b, st in enumerate(states):
            if st:
                table[b, : st.n_pages] = st.pages[: st.n_pages]
        return table, lengths

    def prefix_block_table(
        self, request_ids: Sequence[int], limits: Sequence[int]
    ) -> Tuple[np.ndarray, np.ndarray]:
        """`block_table` restricted to each request's FILLED prefix.

        ``block_table`` counts every allocated slot — including slots a
        mid-prefill request reserved up front but has not written yet.  The
        unified chunked step must attend only positions ``< limits[b]`` (the
        request's prefill cursor; ``seq_len - 1`` for decode rows), so this
        returns the same table with lengths clipped to the filled prefix.
        Valid because `alloc` appends slots in ascending position order (the
        striped placement plans are per-instance ascending), so the filled
        prefix occupies exactly the first ``eff`` slots of the table order —
        asserted below.
        """
        states = [self._reqs.get(rid) for rid in request_ids]
        lengths = np.zeros(len(states), np.int32)
        for b, st in enumerate(states):
            if st is None:
                continue
            pos = st.pos[: st.n_tok]
            lim = int(limits[b])
            eff = int((pos < lim).sum())
            assert (pos[:eff] < lim).all() and (pos[eff:] >= lim).all(), (
                "prefix_block_table: allocation order is not position-sorted",
                request_ids[b], lim, pos,
            )
            lengths[b] = eff
        max_pages = max((st.n_pages for st in states if st), default=0)
        table = np.zeros((len(states), max_pages), np.int32)
        for b, st in enumerate(states):
            if st:
                table[b, : st.n_pages] = st.pages[: st.n_pages]
        return table, lengths

    @property
    def k_pages(self) -> np.ndarray:
        """[n_attn, n_pages, page_size, KVH, D] view of the K storage."""
        self._sync_host()
        return self.k.reshape(self.n_attn, self.n_pages, self.page_size,
                              *self.k.shape[2:])

    @property
    def v_pages(self) -> np.ndarray:
        self._sync_host()
        return self.v.reshape(self.n_attn, self.n_pages, self.page_size,
                              *self.v.shape[2:])

    @property
    def pos_pages(self) -> np.ndarray:
        """[n_pages, page_size] global position per slot (-1 = unoccupied)."""
        return self.slot_pos.reshape(self.n_pages, self.page_size)

    # ------------------------------------------------------- checkpointing
    def state_dict(self) -> Dict[str, object]:
        state: Dict[str, object] = {
            "free_pages": self._free_pages.copy(),
            "n_free_pages": self._n_free_pages,
            "used_tokens": self._used_tokens,
            "slot_pos": self.slot_pos.copy(),
            "reqs": {
                rid: (st.pages[: st.n_pages].copy(), st.pos[: st.n_tok].copy())
                for rid, st in self._reqs.items()
            },
        }
        if self.store_values:
            # checkpoints snapshot the host copy: force the deferred
            # device->host download of fill_packed slots (counted in
            # `host_syncs`; at most once — a second snapshot with nothing
            # stale downloads nothing), then persist only OCCUPIED slots so
            # the checkpoint scales with live KV, not pool capacity.
            self._sync_host()
            occ = np.nonzero(self.slot_pos >= 0)[0]
            state["kv_slots"] = occ
            state["k"] = self.k[:, occ].copy()
            state["v"] = self.v[:, occ].copy()
        return state

    def load_state_dict(self, state: Dict[str, object]) -> None:
        self._free_pages = state["free_pages"].copy()
        self._n_free_pages = state["n_free_pages"]
        self._used_tokens = state["used_tokens"]
        self.slot_pos = state["slot_pos"].copy()
        self._reqs = {}
        for rid, (pages, pos) in state["reqs"].items():
            st = _ReqState()
            st.append_pages(np.asarray(pages, np.int32))
            st.append_pos(np.asarray(pos, np.int64))
            self._reqs[rid] = st
        if self.store_values and "kv_slots" in state:
            # real-mode restore reproduces the oracle sequence without a
            # recompute pass: the host copy is authoritative again and the
            # dropped (per-shard) mirror rebuilds from it on first use
            self.k[:] = 0.0
            self.v[:] = 0.0
            occ = state["kv_slots"]
            self.k[:, occ] = state["k"]
            self.v[:, occ] = state["v"]
        self.drop_mirror()

    def evict(self, request_id: int) -> int:
        """Evict a request entirely (recompute later). Returns freed tokens."""
        return self.free_request(request_id)

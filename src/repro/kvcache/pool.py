"""Token-granularity paged KV pool for one elastic instance.

LoongServe manages KV "at the granularity of a single token across instances
without any locality constraints" (§1, §4). Page size == 1 token: a slot holds
the KV vectors of one token across all attention applications of the model.

Storage is host-side numpy (the management plane); the engine gathers dense
per-request views to feed jitted compute. `bytes_per_slot` reflects the real
bf16 KV footprint so pool capacities model HBM honestly.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.configs.base import ModelConfig


class OutOfSlots(RuntimeError):
    pass


@dataclass
class TokenRef:
    """Where one token's KV lives."""

    instance: int
    slot: int


class KVPool:
    """Per-instance pool. Slots are single tokens."""

    def __init__(self, cfg: ModelConfig, capacity: int, instance_id: int = 0,
                 store_values: bool = True):
        self.cfg = cfg
        self.capacity = int(capacity)
        self.instance_id = instance_id
        self.store_values = store_values
        n_attn = max(cfg.n_attention_applications, 1)
        self._free: List[int] = list(range(self.capacity - 1, -1, -1))
        # request_id -> {global_pos: slot}
        self._slots: Dict[int, Dict[int, int]] = {}
        if store_values:
            shape = (n_attn, self.capacity, cfg.n_kv_heads, cfg.head_dim)
            self.k = np.zeros(shape, np.float32)
            self.v = np.zeros(shape, np.float32)

    # ------------------------------------------------------------- accounting
    @property
    def used(self) -> int:
        return self.capacity - len(self._free)

    @property
    def free_slots(self) -> int:
        return len(self._free)

    @property
    def bytes_per_slot(self) -> int:
        return max(self.cfg.kv_bytes_per_token, 1)

    def requests(self) -> List[int]:
        return list(self._slots)

    def tokens_of(self, request_id: int) -> Dict[int, int]:
        return dict(self._slots.get(request_id, {}))

    # ------------------------------------------------------------- alloc/free
    def alloc(self, request_id: int, positions: Sequence[int]) -> List[int]:
        if len(positions) > len(self._free):
            raise OutOfSlots(
                f"instance {self.instance_id}: need {len(positions)}, "
                f"free {len(self._free)}"
            )
        slots = [self._free.pop() for _ in positions]
        mp = self._slots.setdefault(request_id, {})
        for pos, slot in zip(positions, slots):
            assert pos not in mp, (request_id, pos)
            mp[pos] = slot
        return slots

    def free_request(self, request_id: int) -> int:
        mp = self._slots.pop(request_id, {})
        self._free.extend(mp.values())
        return len(mp)

    def free_positions(self, request_id: int, positions: Sequence[int]) -> int:
        """Free specific token positions (SWA window eviction)."""
        mp = self._slots.get(request_id, {})
        n = 0
        for pos in positions:
            slot = mp.pop(pos, None)
            if slot is not None:
                self._free.append(slot)
                n += 1
        if not mp:
            self._slots.pop(request_id, None)
        return n

    # ------------------------------------------------------------------ data
    def write(self, request_id: int, positions: Sequence[int],
              k: np.ndarray, v: np.ndarray) -> None:
        """k/v: [n_attn, n_tokens, KVH, D] for `positions` (allocates)."""
        slots = self.alloc(request_id, positions)
        if self.store_values:
            idx = np.asarray(slots)
            self.k[:, idx] = np.asarray(k, np.float32)
            self.v[:, idx] = np.asarray(v, np.float32)

    def fill(self, request_id: int, positions: Sequence[int],
             k: np.ndarray, v: np.ndarray) -> None:
        """Write values into ALREADY-RESERVED slots (proactive scale-down:
        the scheduler reserves placement, the prefill ring fills it)."""
        if not self.store_values:
            return
        mp = self._slots[request_id]
        idx = np.array([mp[p] for p in positions], np.int64)
        if len(idx):
            self.k[:, idx] = np.asarray(k, np.float32)
            self.v[:, idx] = np.asarray(v, np.float32)

    def gather(self, request_id: int) -> Tuple[np.ndarray, Optional[np.ndarray], Optional[np.ndarray]]:
        """Returns (positions sorted, k, v) for this instance's share."""
        mp = self._slots.get(request_id, {})
        positions = np.array(sorted(mp), np.int64)
        if not self.store_values:
            return positions, None, None
        idx = np.array([mp[p] for p in positions], np.int64)
        if len(idx) == 0:
            n_attn = self.k.shape[0]
            empty = np.zeros((n_attn, 0) + self.k.shape[2:], np.float32)
            return positions, empty, empty.copy()
        return positions, self.k[:, idx], self.v[:, idx]

    def evict(self, request_id: int) -> int:
        """Evict a request entirely (recompute later). Returns freed tokens."""
        return self.free_request(request_id)

"""Unified distributed KV cache pool (LoongServe §3/§4).

The per-instance pools together form one logical pool; tokens of one request
may live on any subset of instances at single-token granularity. This module
owns placement planning (used by proactive scale-down and multi-master
appends), migration accounting (used by the *baselines* and by the allocation
step's preemption path — ESP's own transitions are zero-copy), and
fragmentation metrics (paper Fig. 4's failure mode, which token granularity
eliminates).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.configs.base import ModelConfig
from repro.kvcache.pool import KVPool, OutOfSlots


@dataclass
class PlacementPlan:
    """Token-level placement: instance -> sorted list of global positions."""

    request_id: int
    assignment: Dict[int, List[int]]

    @property
    def n_tokens(self) -> int:
        return sum(len(v) for v in self.assignment.values())

    def instances(self) -> List[int]:
        return [i for i, toks in self.assignment.items() if toks]


@dataclass
class SalvagePlan:
    """Fault-salvage inventory for one request after an instance died.

    ``coverage`` is the sparse per-request coverage map over SURVIVING
    instances (instance -> sorted positions still resident); ``lost_spans``
    are the maximal contiguous runs of ``[0, expected)`` no survivor holds —
    exactly the spans the recovery chain must re-prefill (the dead rank's
    stripe), everything else is salvaged in place."""

    request_id: int
    expected: int
    coverage: Dict[int, np.ndarray]
    lost_spans: List[Tuple[int, int]]

    @property
    def n_salvaged(self) -> int:
        return sum(len(p) for p in self.coverage.values())

    @property
    def n_lost(self) -> int:
        return sum(e - s for s, e in self.lost_spans)


class DistributedKVPool:
    def __init__(self, cfg: ModelConfig, n_instances: int,
                 capacity_per_instance: int, store_values: bool = True,
                 page_size: int = 1):
        self.cfg = cfg
        self.page_size = page_size
        self.pools: List[KVPool] = [
            KVPool(cfg, capacity_per_instance, i, store_values, page_size)
            for i in range(n_instances)
        ]
        self.migrated_bytes = 0  # reactive-migration traffic (baselines)

    # ------------------------------------------------------------- accounting
    @property
    def total_free(self) -> int:
        return sum(p.free_slots for p in self.pools)

    @property
    def total_used(self) -> int:
        return sum(p.used for p in self.pools)

    def free_map(self) -> Dict[int, int]:
        return {p.instance_id: p.free_slots for p in self.pools}

    def max_contiguous_request(self) -> int:
        """Largest request a *locality-constrained* system could admit
        (paper Fig. 4): bounded by the single largest per-instance free space.
        The unified pool instead admits up to `total_free`."""
        return max((p.free_slots for p in self.pools), default=0)

    def fragmentation_waste(self) -> int:
        """Tokens admissible by the unified pool but NOT by a locality-
        constrained one."""
        return self.total_free - self.max_contiguous_request()

    def request_instances(self, request_id: int) -> List[int]:
        return [p.instance_id for p in self.pools if p.tokens_of(request_id)]

    def request_tokens(self, request_id: int) -> int:
        return sum(len(p.tokens_of(request_id)) for p in self.pools)

    # -------------------------------------------------------------- placement
    def plan_placement(
        self,
        request_id: int,
        positions: Sequence[int],
        target_instances: Sequence[int],
        *,
        proportional: bool = True,
    ) -> PlacementPlan:
        """Split `positions` across `target_instances` at token granularity.

        proportional=True splits by free capacity (LoongServe: "any token-level
        KV cache allocation plan according to the memory availability of each
        instance without computational load imbalance", §4.1); otherwise an
        even round-robin split.
        """
        positions = list(positions)
        n = len(positions)
        # dedupe targets (order-preserving): duplicates would share one
        # assignment key but take two cursor passes below — the second pass
        # OVERWRITES the first instance's token range and silently drops it
        target_instances = list(dict.fromkeys(target_instances))
        free = {i: self.pools[i].free_slots for i in target_instances}
        if sum(free.values()) < n:
            raise OutOfSlots(
                f"request {request_id}: need {n} tokens, "
                f"free {sum(free.values())} on {list(target_instances)}"
            )
        assignment: Dict[int, List[int]] = {i: [] for i in target_instances}
        if proportional:
            total_free = sum(free.values())
            quota = {
                i: int(np.floor(n * free[i] / total_free)) for i in target_instances
            }
            # distribute the remainder to the freest instances
            rem = n - sum(quota.values())
            for i in sorted(target_instances, key=lambda j: -free[j]):
                if rem == 0:
                    break
                if quota[i] < free[i]:
                    quota[i] += 1
                    rem -= 1
            # cap by actual free space, spill remainder
            spill = 0
            for i in target_instances:
                if quota[i] > free[i]:
                    spill += quota[i] - free[i]
                    quota[i] = free[i]
            for i in target_instances:
                take = min(spill, free[i] - quota[i])
                quota[i] += take
                spill -= take
            cursor = 0
            for i in target_instances:
                assignment[i] = positions[cursor : cursor + quota[i]]
                cursor += quota[i]
        else:
            for j, pos in enumerate(positions):
                assignment[target_instances[j % len(target_instances)]].append(pos)
        return PlacementPlan(request_id, assignment)

    def place(
        self,
        plan: PlacementPlan,
        k: Optional[np.ndarray] = None,  # [n_attn, n_tokens, KVH, D] by position order
        v: Optional[np.ndarray] = None,
        position_index: Optional[Dict[int, int]] = None,
    ) -> None:
        """Write tokens per `plan`. With values, `position_index` maps global
        position -> column of k/v (default: enumerate sorted positions)."""
        if k is not None and position_index is None:
            all_pos = sorted(
                pos for toks in plan.assignment.values() for pos in toks
            )
            position_index = {p: i for i, p in enumerate(all_pos)}
        for inst, toks in plan.assignment.items():
            if not toks:
                continue
            if k is None:
                self.pools[inst].alloc(plan.request_id, toks)
            else:
                cols = [position_index[p] for p in toks]
                self.pools[inst].write(
                    plan.request_id, toks, k[:, cols], v[:, cols]
                )

    # ---------------------------------------------------------------- salvage
    def coverage_map(
        self, request_id: int, failed: Sequence[int] = ()
    ) -> Dict[int, np.ndarray]:
        """Sparse per-request coverage over surviving instances: instance ->
        sorted global positions resident there (empty legs omitted)."""
        out: Dict[int, np.ndarray] = {}
        for p in self.pools:
            if p.instance_id in failed:
                continue
            pos = p.positions_of(request_id)
            if len(pos):
                out[p.instance_id] = pos
        return out

    def salvage_placement(
        self, request_id: int, expected: int, failed: Sequence[int]
    ) -> SalvagePlan:
        """Plan elastic fault recovery for one request: what the survivors
        still hold of positions ``[0, expected)`` and which contiguous spans
        died with the failed instance(s).  Pure inventory — re-reserving the
        lost spans is `place_salvage`, recomputing them is the engine's
        recovery chain."""
        cov = self.coverage_map(request_id, failed)
        mask = np.ones(max(expected, 0), bool)
        for pos in cov.values():
            held = pos[pos < expected]
            mask[held] = False
        missing = np.nonzero(mask)[0]
        spans: List[Tuple[int, int]] = []
        if len(missing):
            brk = np.nonzero(np.diff(missing) > 1)[0]
            starts = np.concatenate([missing[:1], missing[brk + 1]])
            ends = np.concatenate([missing[brk], missing[-1:]]) + 1
            spans = [(int(s), int(e)) for s, e in zip(starts, ends)]
        return SalvagePlan(request_id, expected, cov, spans)

    def place_salvage(self, plan: PlacementPlan) -> None:
        """Re-reserve a dead rank's positions on the survivors.  Unlike
        `place`, the targets may already hold HIGHER positions of the same
        request, so each leg goes through `insert_positions` (which restores
        the pool's position-ascending local order)."""
        for inst, toks in plan.assignment.items():
            if toks:
                self.pools[inst].insert_positions(plan.request_id, toks)

    # -------------------------------------------------------------- migration
    def migrate_request(
        self, request_id: int, src: int, dst_candidates: Sequence[int]
    ) -> int:
        """Move a request's tokens off instance `src` (reactive migration /
        preemption-avoidance path, §5.2). Returns bytes moved and accounts
        them in `migrated_bytes`."""
        pool = self.pools[src]
        toks = pool.tokens_of(request_id)
        if not toks:
            return 0
        positions = sorted(toks)
        _, k, v = pool.gather(request_id)
        plan = self.plan_placement(
            request_id, positions, [d for d in dst_candidates if d != src]
        )
        # transactional: land every destination BEFORE freeing the source
        # copy, rolling fresh destinations back on a mid-place failure — a
        # refused migration must never lose tokens (the engine drops it and
        # keeps serving from src)
        fresh = [
            i for i in plan.instances() if not self.pools[i].tokens_of(request_id)
        ]
        try:
            if k is not None and pool.store_values:
                pos_idx = {p: i for i, p in enumerate(positions)}
                self.place(plan, k, v, pos_idx)
            else:
                self.place(plan)
        except Exception:
            for i in fresh:
                self.pools[i].free_request(request_id)
            raise
        pool.free_request(request_id)
        moved = len(positions) * pool.bytes_per_slot
        self.migrated_bytes += moved
        return moved

    def free_request(self, request_id: int) -> int:
        return sum(p.free_request(request_id) for p in self.pools)

    # ---------------------------------------------------------------- gather
    def gather_request(
        self, request_id: int
    ) -> Tuple[np.ndarray, Optional[np.ndarray], Optional[np.ndarray]]:
        """Dense (positions, k, v) across all instances, position-sorted."""
        parts = [p.gather(request_id) for p in self.pools]
        positions = np.concatenate([pp[0] for pp in parts])
        order = np.argsort(positions)
        positions = positions[order]
        if not self.pools[0].store_values:
            return positions, None, None
        k = np.concatenate([pp[1] for pp in parts], axis=1)[:, order]
        v = np.concatenate([pp[2] for pp in parts], axis=1)[:, order]
        return positions, k, v

"""LoongServe-on-JAX: elastic sequence parallelism for long-context LLM
serving, reproduced as a production-grade TPU framework.

Paper: Wu et al., "LoongServe: Efficiently Serving Long-Context Large
Language Models with Elastic Sequence Parallelism" (2024).
"""

__version__ = "1.0.0"

"""Seeded chaos harness: deterministic fault injection for the serving loop.

`ChaosMonkey` drives the engine's failure machinery the way a hostile
cluster would — but reproducibly: every decision comes from one
`np.random.default_rng(seed)` stream, so the same (seed, workload, rates)
triple replays the exact same event trace, making failure semantics
regression-testable (tests/test_chaos.py asserts trace + metrics equality
across runs).

Injectors (each armed by a nonzero rate in `ChaosConfig`):

  * fail / rejoin storms — `fail_instance` on a random alive instance
    (never below `min_alive`), `join_instance` on a random failed one.
    Every observed failure is audited post-hoc (salvage-aware): the dead
    pool must be drained and each request in the engine's salvage-recovery
    window must hold exactly its declared coverage on the survivors;
    `salvage_ratio()` reports salvaged/(salvaged+recomputed) over the soak;
  * stragglers — stretch a busy instance's remaining `busy_until` interval
    by a random multiplier (the scheduler routes around it), optionally
    degrading its persistent SIB speed;
  * memory pressure — allocate "ballast" pages on a random pool under a
    reserved NEGATIVE rid (chaos-owned: the invariant sanitizer recognises
    rid < 0), shrinking effective capacity; released randomly and fully at
    `disarm()`;
  * transient dispatch faults — a raising hook installed into
    `kernels/ops.set_fault_hook`: each guarded dispatch point may raise
    `TransientDispatchError` (never more than `fault_burst` in a row, so
    faults stay transient and bounded retry can always succeed);
  * NaN-poisoned logits — mark a random in-flight DECODE request's next
    emission poisoned (`engine._logit_poison`): the real-mode executor
    overwrites that request's logits row with NaN before the value guard
    sees it, sim mode short-circuits to the same quarantine path.

Arming appends an event hook (`engine.event_hooks`) that fires after every
handled event; injections push ordinary engine events or mutate documented
engine state, so the serving loop under chaos is the SAME loop as
production — no special-cased control flow.

`disarm()` heals the cluster for quiescence: clears the fault hook, stops
injecting, releases all ballast, rejoins every failed instance and clears
pending poison, so a post-chaos `run()` can drain to completion ("all
submitted requests eventually complete").
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.engine.request import Phase
from repro.kernels import ops
from repro.kvcache.pool import OutOfSlots

# chaos ballast rids are negative and engine request rids are
# itertools.count() >= 0 — the two namespaces never collide
_ballast_rid = itertools.count(start=-1, step=-1)


@dataclass
class ChaosConfig:
    """Per-event injection rates (probabilities drawn once per handled
    engine event) + bounds.  All rates default to 0 (injector disarmed)."""

    fail_rate: float = 0.0
    rejoin_rate: float = 0.0
    straggler_rate: float = 0.0
    straggler_mult: Tuple[float, float] = (1.5, 8.0)
    slowdown_rate: float = 0.0  # persistent SIB speed degradation
    pressure_rate: float = 0.0  # ballast alloc
    release_rate: float = 0.0  # ballast free
    ballast_frac: float = 0.25  # max fraction of one pool per ballast grab
    dispatch_fault_rate: float = 0.0  # per guarded dispatch point
    fault_burst: int = 2  # max consecutive faults (keeps them transient)
    nan_rate: float = 0.0
    min_alive: int = 1
    max_injections: Optional[int] = None  # stop injecting after N actions


class ChaosMonkey:
    """Deterministic, seeded fault injector for one engine."""

    def __init__(self, engine, config: ChaosConfig, seed: int = 0):
        self.eng = engine
        self.cfg = config
        self.seed = seed
        self.rng = np.random.default_rng(seed)
        self.trace: List[Tuple[Any, ...]] = []  # (event#, action, *args)
        self.n_events = 0
        self.n_injections = 0
        self._armed = False
        self._fault_streak = 0
        self._ballast: Dict[int, Tuple[int, int]] = {}  # rid -> (inst, n)

    # ------------------------------------------------------------- lifecycle
    def arm(self) -> None:
        assert not self._armed
        self._armed = True
        self.eng.event_hooks.append(self._on_event)
        if self.cfg.dispatch_fault_rate > 0:
            ops.set_fault_hook(self._fault_hook)

    def disarm(self) -> None:
        """Stop injecting and heal the cluster so the loop can drain."""
        if self._on_event in self.eng.event_hooks:
            self.eng.event_hooks.remove(self._on_event)
        ops.set_fault_hook(None)
        self._armed = False
        for rid in list(self._ballast):
            # fleet-wide free: defense in depth should anything have moved
            # ballast off its recorded instance
            self.eng.pool.free_request(rid)
        self._ballast.clear()
        eng = self.eng
        if hasattr(eng, "_logit_poison"):
            eng._logit_poison.clear()
        for inst in sorted(eng.failed):
            eng.join_instance(inst, at=eng.clock)
            self.trace.append((self.n_events, "heal_join", inst))

    # ------------------------------------------------------------- injectors
    def _alive(self) -> List[int]:
        return [i for i in range(self.eng.n) if i not in self.eng.failed]

    def _log(self, action: str, *args) -> None:
        self.n_injections += 1
        self.trace.append((self.n_events, action) + args)

    def _on_event(self, eng, kind, payload) -> None:
        self.n_events += 1
        # salvage-aware failure audit: hooks fire AFTER the event is
        # handled, so a "fail" event is observed post-`_apply_failure` —
        # the dead pool must be empty (shards either salvaged off it or
        # freed for recompute) and every request inside the recovery
        # window must hold exactly its declared coverage on survivors.
        # Pure asserts: no rng draws, so the trace stays seed-aligned.
        if kind == "fail" and payload in eng.failed:
            inst = payload
            leftover = list(eng.pool.pools[inst].requests())
            assert not leftover, (
                f"chaos: failed instance {inst} still holds rids {leftover}"
            )
            for rid, rec in getattr(eng, "_recovering", {}).items():
                held = eng.pool.request_tokens(rid)
                assert held == rec.expected, (
                    f"chaos: recovering rid {rid} holds {held} tokens "
                    f"fleet-wide, declared coverage {rec.expected}"
                )
        cfg = self.cfg
        if (
            cfg.max_injections is not None
            and self.n_injections >= cfg.max_injections
        ):
            return
        rng = self.rng
        # one draw per injector per event keeps the stream alignment
        # independent of which branches fire
        draws = rng.random(6)

        alive = self._alive()
        if draws[0] < cfg.fail_rate and len(alive) > cfg.min_alive:
            inst = int(rng.choice(alive))
            eng.fail_instance(inst, at=eng.clock)
            self._log("fail", inst)

        if draws[1] < cfg.rejoin_rate and eng.failed:
            inst = int(rng.choice(sorted(eng.failed)))
            eng.join_instance(inst, at=eng.clock)
            self._log("rejoin", inst)

        if draws[2] < cfg.straggler_rate:
            busy = [
                i for i in self._alive()
                if eng.busy_until[i] > eng.clock
            ]
            if busy:
                inst = int(rng.choice(busy))
                lo, hi = cfg.straggler_mult
                mult = float(rng.uniform(lo, hi))
                eng.busy_until[inst] = eng.clock + (
                    eng.busy_until[inst] - eng.clock
                ) * mult
                self._log("straggle", inst, round(mult, 3))

        if draws[3] < cfg.slowdown_rate:
            alive = self._alive()
            if alive:
                inst = int(rng.choice(alive))
                speed = float(rng.uniform(0.25, 1.0))
                eng.sib.set_instance_speed(inst, speed)
                self._log("slowdown", inst, round(speed, 3))

        if draws[4] < cfg.pressure_rate:
            self._grab_ballast()
        elif draws[4] < cfg.pressure_rate + cfg.release_rate and self._ballast:
            rid = sorted(self._ballast)[-1]
            inst, n = self._ballast.pop(rid)
            eng.pool.free_request(rid)  # fleet-wide (see disarm)
            self._log("release", inst, n)

        if draws[5] < cfg.nan_rate:
            decoding = sorted(
                rid for rid, r in eng._req_index.items()
                if r.phase is Phase.DECODE
            )
            if decoding and hasattr(eng, "_logit_poison"):
                rid = int(rng.choice(decoding))
                eng._logit_poison.add(rid)
                # log the victim's run-relative index, not its absolute rid:
                # rids come from a process-global counter, so two identical
                # runs in one process disagree on them — the fingerprint
                # must depend only on seeded decisions
                self._log("poison", sorted(eng._req_index).index(rid))

    def _grab_ballast(self) -> None:
        eng = self.eng
        alive = self._alive()
        if not alive:
            return
        inst = int(self.rng.choice(alive))
        pool = eng.pool.pools[inst]
        cap = max(int(self.cfg.ballast_frac * pool.capacity), pool.page_size)
        n = int(self.rng.integers(pool.page_size, cap + 1))
        rid = next(_ballast_rid)
        try:
            pool.alloc(rid, list(range(n)))
        except OutOfSlots:
            self._log("pressure_oom", inst, n)
            return
        self._ballast[rid] = (inst, n)
        self._log("pressure", inst, n)

    # ------------------------------------------------------------ fault hook
    def _fault_hook(self, point: str) -> None:
        """Installed into ops.set_fault_hook: raise at the executors'
        per-batch dispatch guards ("prefill_dispatch"/"decode_dispatch" —
        side-effect-free raise points), never more than `fault_burst` in a
        row so the engine's bounded retry can always make progress."""
        if not point.endswith("_dispatch"):
            return
        if self._fault_streak >= self.cfg.fault_burst:
            self._fault_streak = 0
            return
        if self.rng.random() < self.cfg.dispatch_fault_rate:
            self._fault_streak += 1
            self.trace.append((self.n_events, "dispatch_fault", point))
            raise ops.TransientDispatchError(f"chaos: {point}")
        self._fault_streak = 0

    # --------------------------------------------------------------- queries
    def trace_fingerprint(self) -> Tuple[Tuple[Any, ...], ...]:
        """Hashable trace for equality assertions across runs."""
        return tuple(self.trace)

    def salvage_ratio(self) -> float:
        """Fraction of fault-affected computed tokens retained in place by
        salvage (vs recomputed) over the soak so far — the headline
        recovery-efficiency metric (1.0 = every failure fully salvaged,
        0.0 = every failure fell back to full recompute)."""
        return self.eng.metrics.snapshot()["salvage_ratio"]

"""Synthetic workload generators matching the paper's datasets (§7.1).

No network access in this container, so the request length distributions are
parameterized to the ranges the paper reports:
  * ShareGPT: 4 – 2.3K tokens (short conversational; lognormal body)
  * L-Eval:   2.7K – 210.5K  (long-doc QA/summarization)
  * LV-Eval:  15.1K – 497.3K (longest; long-context QA)
  * Mixed:    uniform mixture of the three
Arrivals are Poisson (exponential inter-arrival at the given rate), and the
Zipf resampling used by the paper's Fig. 12 ablation is provided.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Iterator, List, Optional, Tuple

import numpy as np

from repro.engine.request import Request


@dataclass
class LengthDist:
    lo: int
    hi: int
    log_mu: float
    log_sigma: float
    out_lo: int
    out_hi: int

    def sample(self, rng) -> Tuple[int, int]:
        ln = int(np.clip(rng.lognormal(self.log_mu, self.log_sigma), self.lo, self.hi))
        out = int(rng.integers(self.out_lo, self.out_hi + 1))
        return ln, out


DATASETS = {
    "sharegpt": LengthDist(4, 2300, math.log(320), 1.0, 16, 512),
    "leval": LengthDist(2700, 210_500, math.log(18_000), 1.0, 16, 512),
    "lveval": LengthDist(15_100, 497_300, math.log(80_000), 0.9, 8, 256),
}


def sample_lengths(dataset: str, n: int, seed: int = 0) -> List[Tuple[int, int]]:
    rng = np.random.default_rng(seed)
    if dataset == "mixed":
        names = list(DATASETS)
        return [
            DATASETS[names[int(rng.integers(len(names)))]].sample(rng)
            for _ in range(n)
        ]
    return [DATASETS[dataset].sample(rng) for _ in range(n)]


def poisson_workload(
    dataset: str,
    n: int,
    rate: float,
    seed: int = 0,
    max_len: Optional[int] = None,
) -> List[Request]:
    """Requests with Poisson arrivals at `rate` req/s."""
    rng = np.random.default_rng(seed)
    lens = sample_lengths(dataset, n, seed + 1)
    t = 0.0
    reqs = []
    for ln, out in lens:
        t += rng.exponential(1.0 / rate)
        if max_len:
            ln = min(ln, max_len)
        reqs.append(Request(input_len=ln, max_new_tokens=out, arrival=t))
    return reqs


def zipf_workload(
    n: int,
    zipf_a: float,
    rate: float,
    seed: int = 0,
    max_len: int = 200_000,
) -> List[Request]:
    """Fig. 12: lengths sampled from the Mixed pool reweighted by a Zipf law
    (small `a` -> heavier tail of long requests)."""
    rng = np.random.default_rng(seed)
    pool = sorted(l for l, _ in sample_lengths("mixed", 4096, seed + 1))
    ranks = np.arange(1, len(pool) + 1, dtype=np.float64)
    w = ranks ** (-zipf_a)
    w /= w.sum()
    t = 0.0
    reqs = []
    for _ in range(n):
        t += rng.exponential(1.0 / rate)
        ln = int(min(pool[int(rng.choice(len(pool), p=w))], max_len))
        out = int(rng.integers(16, 513))
        reqs.append(Request(input_len=max(ln, 4), max_new_tokens=out, arrival=t))
    return reqs


def with_prompts(reqs: List[Request], vocab: int, seed: int = 0) -> List[Request]:
    rng = np.random.default_rng(seed)
    for r in reqs:
        r.prompt = rng.integers(0, vocab, r.input_len).tolist()
    return reqs

"""Synthetic workload generation (paper §7.1 datasets + Poisson arrivals)."""
from repro.data.workload import poisson_workload, zipf_workload, sample_lengths, with_prompts  # noqa: F401

"""mixtral-8x7b [moe] — 32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000,
8 experts top-2, sliding-window attention. [arXiv:2401.04088; hf]

EP sharding: 8 experts < 16 TP shards, so experts keep their identity and each
expert is TP-sharded over `model` (w1/w3 column-, w2 row-split).
SWA => long_500k runs with a bounded 4K decode window.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_head=128,
    d_ff=14336,
    vocab_size=32000,
    n_experts=8,
    moe_top_k=2,
    rope_theta=1e6,
    sliding_window=4096,
    ffn_kind="swiglu",
    norm_kind="rmsnorm",
    max_seq_len=32768,
)

"""whisper-tiny [audio] — enc-dec, 4L encoder + 4L decoder, d_model=384 6H
d_ff=1536 vocab=51865, conv frontend STUB. [arXiv:2212.04356; unverified]

input_specs() provides precomputed frame embeddings (batch, 1500, 384); the
assigned decode shapes exercise the *decoder* (self-attn KV at the given
lengths + static cross-attn KV) — real Whisper caps the decoder at 448 tokens,
we honor the assigned shapes as a sharding/roofline exercise (DESIGN.md §4).
6 heads don't divide 16 -> batch-over-model attention sharding.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="audio",
    n_layers=4,  # decoder layers
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    d_head=64,
    d_ff=1536,
    vocab_size=51865,
    ffn_kind="gelu",
    norm_kind="layernorm",
    rope_theta=0.0,  # whisper uses learned/sinusoidal positions, not RoPE
    is_encoder_decoder=True,
    n_encoder_layers=4,
    encoder_seq=1500,
    frontend="audio_stub",
    n_frontend_tokens=1500,
    max_seq_len=32768,
)

"""glm4-9b [dense] — 40L d_model=4096 32H (GQA kv=2) d_ff=13696 vocab=151552.

RoPE + extreme GQA (kv=2). [hf:THUDM/glm-4-9b; hf]
kv=2 -> KV heads replicated across TP; decode shards the KV *sequence* over
(data×model) instead (multi-master decode), which is exactly where LoongServe's
token-granularity KV placement shines.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="glm4-9b",
    family="dense",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=2,
    d_head=128,
    d_ff=13696,
    vocab_size=151552,
    rope_theta=5e6,
    rope_fraction=0.5,  # GLM applies rotary to half the head dim
    ffn_kind="swiglu",
    norm_kind="rmsnorm",
    max_seq_len=131072,
)

"""qwen1.5-4b [dense] — 40L d_model=2560 20H (GQA kv=20) d_ff=6912 vocab=151936.

QKV bias per the Qwen1.5 family. [hf:Qwen/Qwen1.5-0.5B; hf]
20 heads do not divide the 16-wide TP axis -> attention uses batch-over-model
sharding (see DESIGN.md §mesh mapping); FFN TP is standard (6912 = 16·432).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-4b",
    family="dense",
    n_layers=40,
    d_model=2560,
    n_heads=20,
    n_kv_heads=20,
    d_head=128,
    d_ff=6912,
    vocab_size=151936,
    qkv_bias=True,
    rope_theta=5e6,
    ffn_kind="swiglu",
    norm_kind="rmsnorm",
    max_seq_len=32768,
)

"""pixtral-12b [vlm] — 40L d_model=5120 32H (GQA kv=8) d_ff=14336 vocab=131072
— pixtral-ViT frontend + mistral-nemo backbone.
[hf:mistralai/Pixtral-12B-2409; unverified]

Per the brief the ViT frontend is a STUB: input_specs() provides precomputed
patch embeddings (batch, n_patches, d_model) which are concatenated with the
text token embeddings; the assigned seq_len counts text+image tokens.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="pixtral-12b",
    family="vlm",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    d_head=128,
    d_ff=14336,
    vocab_size=131072,
    rope_theta=1e9,  # mistral-nemo long-context base
    ffn_kind="swiglu",
    norm_kind="rmsnorm",
    frontend="patch_stub",
    n_frontend_tokens=1024,  # 1024 image patches pre-embedded
    max_seq_len=131072,
)

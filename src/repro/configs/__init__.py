"""Architecture registry: ``--arch <id>`` resolves through here."""
from __future__ import annotations

from repro.configs.base import ModelConfig, ShapeSpec, SHAPES, reduced

from repro.configs import (  # noqa: F401
    qwen1_5_4b,
    glm4_9b,
    nemotron_4_15b,
    h2o_danube_1_8b,
    zamba2_2_7b,
    xlstm_350m,
    mixtral_8x7b,
    arctic_480b,
    pixtral_12b,
    whisper_tiny,
    lwm_7b,
)

REGISTRY: dict[str, ModelConfig] = {
    m.CONFIG.name: m.CONFIG
    for m in (
        qwen1_5_4b,
        glm4_9b,
        nemotron_4_15b,
        h2o_danube_1_8b,
        zamba2_2_7b,
        xlstm_350m,
        mixtral_8x7b,
        arctic_480b,
        pixtral_12b,
        whisper_tiny,
        lwm_7b,
    )
}

# The ten *assigned* architectures (lwm-7b is the paper's own model, extra).
ASSIGNED = [
    "qwen1.5-4b",
    "glm4-9b",
    "nemotron-4-15b",
    "h2o-danube-1.8b",
    "zamba2-2.7b",
    "xlstm-350m",
    "mixtral-8x7b",
    "arctic-480b",
    "pixtral-12b",
    "whisper-tiny",
]


def get_config(name: str) -> ModelConfig:
    if name not in REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(REGISTRY)}")
    return REGISTRY[name]


def shape_applicable(cfg: ModelConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """(runs?, reason) for an (arch x shape) cell, per DESIGN.md §4."""
    if shape.name == "long_500k" and cfg.has_full_attention:
        return False, "pure full-attention arch: long_500k needs sub-quadratic attention (DESIGN.md §4)"
    return True, ""


__all__ = [
    "ModelConfig",
    "ShapeSpec",
    "SHAPES",
    "REGISTRY",
    "ASSIGNED",
    "get_config",
    "reduced",
    "shape_applicable",
]

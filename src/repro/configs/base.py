"""Model / shape configuration dataclasses shared by the whole framework.

Every assigned architecture is described by a single `ModelConfig`. The model
builders in `repro.models` consume nothing but this dataclass, so adding an
architecture == adding a config file.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | hybrid | ssm | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: int = 0  # 0 -> derived d_model // n_heads

    # --- attention ---
    qkv_bias: bool = False
    rope_theta: float = 1e6
    rope_fraction: float = 1.0  # fraction of d_head with rotary applied
    sliding_window: Optional[int] = None  # SWA window (tokens), None = full
    attn_logit_softcap: Optional[float] = None

    # --- ffn ---
    ffn_kind: str = "swiglu"  # swiglu | relu2 | gelu
    norm_kind: str = "rmsnorm"  # rmsnorm | layernorm
    norm_eps: float = 1e-5

    # --- moe ---
    n_experts: int = 0
    moe_top_k: int = 0
    moe_capacity_factor: float = 1.25
    dense_ff: int = 0  # arctic-style parallel dense residual FFN (0 = none)

    # --- ssm (mamba2) / hybrid ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv_width: int = 4
    ssm_chunk: int = 256
    # hybrid (zamba2-style): layers grouped into superblocks of
    # `hybrid_mamba_per_block` mamba layers followed by ONE application of a
    # single *shared* attention+FFN block (weights shared across superblocks).
    hybrid_mamba_per_block: int = 0

    # --- xlstm ---
    xlstm_slstm_every: int = 0  # every k-th block is an sLSTM block (0 = none)
    xlstm_proj_factor: float = 2.0

    # --- encoder-decoder (whisper) ---
    is_encoder_decoder: bool = False
    n_encoder_layers: int = 0
    encoder_seq: int = 0  # fixed encoder length (whisper: 1500 frames)

    # --- modality frontend stubs ---
    frontend: Optional[str] = None  # None | "patch_stub" | "audio_stub"
    n_frontend_tokens: int = 0  # patches / frames provided pre-embedded

    # --- misc ---
    max_seq_len: int = 32768
    tie_embeddings: bool = False
    dtype: str = "bfloat16"

    # ---------------- derived ----------------
    @property
    def head_dim(self) -> int:
        return self.d_head if self.d_head else self.d_model // self.n_heads

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // max(self.n_kv_heads, 1)

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm" and self.ssm_state == 0  # xlstm

    @property
    def has_full_attention(self) -> bool:
        """True if the arch has *unbounded-window full* attention anywhere.

        Used to decide long_500k applicability: SWA / SSM / hybrid / xlstm are
        sub-quadratic; pure full-attention archs skip long_500k.
        """
        if self.family in ("ssm",):
            return self.ssm_state == 0 and False  # neither mamba nor xlstm
        if self.family == "hybrid":
            # zamba2 shared-attn keeps full KV but over a bounded set of
            # attention applications; the paper brief classifies hybrids as
            # long_500k-runnable.
            return False
        return self.sliding_window is None

    @property
    def kv_bytes_per_token(self) -> int:
        """bf16 KV bytes per token (all layers) - used by the KV pool."""
        if self.family == "ssm":
            return 0
        n_attn = self.n_attention_applications
        return n_attn * 2 * self.n_kv_heads * self.head_dim * 2

    @property
    def n_attention_applications(self) -> int:
        if self.family == "hybrid" and self.hybrid_mamba_per_block:
            return self.n_layers // self.hybrid_mamba_per_block
        if self.family == "ssm":
            return 0
        if self.is_encoder_decoder:
            return self.n_layers  # decoder self-attn layers
        return self.n_layers

    # approximate parameter count (used for roofline MODEL_FLOPS = 6·N·D)
    def param_count(self, active_only: bool = False) -> int:
        d, f, hd = self.d_model, self.d_ff, self.head_dim
        nh, nkv = self.n_heads, self.n_kv_heads
        attn = d * nh * hd + 2 * d * nkv * hd + nh * hd * d
        if self.qkv_bias:
            attn += (nh + 2 * nkv) * hd
        if self.ffn_kind == "swiglu":
            ffn = 3 * d * f
        else:
            ffn = 2 * d * f
        if self.family == "moe":
            n_e = self.moe_top_k if active_only else self.n_experts
            moe = n_e * ffn + d * self.n_experts
            dense = 3 * d * self.dense_ff if self.dense_ff else 0
            per_layer = attn + moe + dense
            total = self.n_layers * per_layer
        elif self.family == "hybrid":
            d_in = self.ssm_expand * d
            nh_ssm = d_in // self.ssm_head_dim
            ssm = (
                d * (2 * d_in + 2 * self.ssm_state + nh_ssm)
                + d_in * d
                + (d_in + 2 * self.ssm_state) * self.ssm_conv_width
            )
            shared = attn + ffn  # one shared block, counted once
            total = self.n_layers * ssm + shared
        elif self.family == "ssm":  # xlstm
            d_in = int(self.xlstm_proj_factor * d)
            per = 2 * d * d_in + 3 * d_in * (nh * 3) + d_in * d + 4 * d * d_in
            total = self.n_layers * per
        else:
            total = self.n_layers * (attn + ffn)
        total += self.vocab_size * d * (1 if self.tie_embeddings else 2)
        if self.is_encoder_decoder:
            enc = self.n_encoder_layers * (attn + ffn)
            cross = self.n_layers * attn
            total += enc + cross
        return int(total)


@dataclass(frozen=True)
class ShapeSpec:
    """One assigned input-shape cell."""

    name: str
    kind: str  # train | prefill | decode
    seq_len: int
    global_batch: int

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """A tiny same-family variant of `cfg` for CPU smoke tests."""
    small = dict(
        n_layers=min(cfg.n_layers, 4),
        d_model=128,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 4) if cfg.n_kv_heads else cfg.n_kv_heads,
        d_head=32,
        d_ff=256 if cfg.d_ff else 0,
        vocab_size=256,
        max_seq_len=512,
        dtype="float32",
    )
    if cfg.family == "moe":
        # generous capacity so smoke tests see no token dropping
        small.update(n_experts=4, moe_top_k=2, dense_ff=64 if cfg.dense_ff else 0,
                     moe_capacity_factor=4.0)
    if cfg.family == "hybrid":
        small.update(
            n_layers=4, hybrid_mamba_per_block=2, ssm_state=16, ssm_head_dim=16,
            ssm_chunk=32, n_kv_heads=4,
        )
    if cfg.family == "ssm" and cfg.ssm_state:  # pure mamba
        small.update(ssm_state=16, ssm_head_dim=16, ssm_chunk=32)
    if cfg.xlstm_slstm_every:
        small.update(xlstm_slstm_every=2)
    if cfg.is_encoder_decoder:
        small.update(n_encoder_layers=2, n_layers=2, encoder_seq=16,
                     n_frontend_tokens=16)
    if cfg.frontend == "patch_stub":
        small.update(n_frontend_tokens=16)
    if cfg.sliding_window:
        small.update(sliding_window=128)
    small.update(overrides)
    return dataclasses.replace(cfg, **small)

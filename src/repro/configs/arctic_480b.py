"""arctic-480b [moe] — 35L d_model=7168 56H (GQA kv=8) d_ff=4864 vocab=32000,
MoE 128 experts top-2 + parallel dense residual FFN.
[hf:Snowflake/snowflake-arctic-base; hf]

938 GB of bf16 expert weights cannot replicate across the data axis: experts
shard over `model` (128/16 = 8 per shard) and the expert FFN hidden dim shards
over `data` (expert-TP), giving ~3.7 GB/chip. 56 heads don't divide 16 ->
attention uses batch-over-model sharding.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    family="moe",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_head=128,
    d_ff=4864,
    vocab_size=32000,
    n_experts=128,
    moe_top_k=2,
    dense_ff=14336,  # parallel dense residual MLP
    rope_theta=1e6,
    ffn_kind="swiglu",
    norm_kind="rmsnorm",
    max_seq_len=4096,
)

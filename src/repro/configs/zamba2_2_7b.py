"""zamba2-2.7b [hybrid] — 54L d_model=2560, Mamba2 backbone + ONE shared
attention block (32H kv=32, d_ff=10240) applied every 6 mamba layers,
ssm_state=64, vocab=32000. [arXiv:2411.15242; hf]

ESP applicability: the shared-attention applications keep full KV (sharded
with multi-master decode / striped-ring prefill); the Mamba2 layers are
recurrent over the sequence so the striped ring is inapplicable to them —
they run chunked-SSD locally per sequence shard with a chunk-state handoff
(linear ppermute chain), see DESIGN.md §Arch-applicability.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_head=80,
    d_ff=10240,
    vocab_size=32000,
    ssm_state=64,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_chunk=64,  # keeps the [L,L,H] intra-chunk decay tensors VMEM-sized
    hybrid_mamba_per_block=6,  # 9 superblocks x (6 mamba + shared attn)
    ffn_kind="swiglu",
    norm_kind="rmsnorm",
    max_seq_len=1048576,
)

"""lwm-7b — the paper's own evaluation model (LWM-1M-Text = Llama-2-7B
architecture with a 1M context window). [arXiv:2402.08268 / Llama-2-7B]

This is the config used by the serving examples / benchmarks to mirror the
paper's testbed.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="lwm-7b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    d_head=128,
    d_ff=11008,
    vocab_size=32000,
    rope_theta=1e7,  # LWM's scaled theta for the 1M window
    ffn_kind="swiglu",
    norm_kind="rmsnorm",
    max_seq_len=1048576,
)

"""h2o-danube-1.8b [dense] — 24L d_model=2560 32H (GQA kv=8) d_ff=6912
vocab=32000 — llama+mistral mix with sliding-window attention.
[arXiv:2401.16818; hf]

SWA => bounded decode KV window => long_500k runs for this arch.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-1.8b",
    family="dense",
    n_layers=24,
    d_model=2560,
    n_heads=32,
    n_kv_heads=8,
    d_head=80,
    d_ff=6912,
    vocab_size=32000,
    rope_theta=1e4,
    sliding_window=4096,
    ffn_kind="swiglu",
    norm_kind="rmsnorm",
    max_seq_len=16384,
)

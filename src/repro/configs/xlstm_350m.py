"""xlstm-350m [ssm] — 24L d_model=1024 4H vocab=50304, d_ff=0 (blocks carry
their own up/down projections) — sLSTM + mLSTM blocks. [arXiv:2405.04517;
unverified]

Attention-free: ESP's striped KV ring is inapplicable (no KV); the analogue is
chunkwise mLSTM with a single chunk-state handoff between sequence shards.
Decode state is O(1)/request => long_500k runs.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-350m",
    family="ssm",
    n_layers=24,
    d_model=1024,
    n_heads=4,
    n_kv_heads=4,
    d_head=256,
    d_ff=0,
    vocab_size=50304,
    ssm_state=0,  # marks xlstm (matrix-memory, not mamba SSD)
    xlstm_slstm_every=8,  # blocks 7, 15, 23 are sLSTM; rest mLSTM
    xlstm_proj_factor=2.0,
    norm_kind="layernorm",
    max_seq_len=1048576,
)

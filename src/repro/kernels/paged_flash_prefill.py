"""Pallas TPU kernel: packed ragged causal flash prefill over one token axis.

One launch serves EVERY prefill request of a batch: the prompts are
concatenated ("packed") along a single token axis of bucketed length T, and
the grid runs over ``(kv_head_group, q_block, k_block)``.  Per-sequence
boundaries ride in through a scalar-prefetched offsets array — available in
SMEM before the kernel body runs — so each program derives segment ids for
its q/k tiles and (a) skips tiles whose segment ranges cannot interact and
(b) masks cross-request attention inside mixed tiles.  This replaces
O(batch) per-request `model.prefill` launches (one XLA program per distinct
prompt length) with ONE program per bucket.

Contract:
  * ``q``: [T, H, D]; ``k``/``v``: [T, KVH, D] — the packed batch, padded to
    a bucketed T (the engine buckets to powers of two so O(log max_tokens)
    programs cover every batch);
  * ``seq_offsets``: [B+1] int32 — request b occupies packed positions
    ``[seq_offsets[b], seq_offsets[b+1])``.  Trailing entries may repeat the
    total (empty segments from batch-count bucketing); padding tokens past
    ``seq_offsets[-1]`` form their own segment and never reach real rows.
  * causality is evaluated in PACKED coordinates: within one segment the
    packed order equals the local order, so ``tq >= tk`` (and the window
    predicate ``tq - tk < window`` — repo convention, self-inclusive) need
    no per-token local positions.  RoPE uses local positions outside the
    kernel, so the striped/packed layout stays transparent to the model.

Emits the NORMALIZED output (prefill is local to the packed batch — no
cross-instance combine is needed).

Ring fusion (DoP>1 ESP prefill)
-------------------------------
``packed_flash_prefill_ring_chunk`` is the online-softmax accumulator variant
of the same kernel for the striped ESP ring: the packed token axis is striped
across the n instances of an elastic group (global packed index ``g`` lives
on shard ``g % n`` at local slot ``g // n``), and at every ring step each
instance runs ONE launch of this kernel over (its local query shard) x (the
remote KV chunk it currently holds), carrying the unnormalized
``(acc, m, l)`` flash state across steps.  Segment ids come from
scalar-prefetched PER-SHARD offsets (``striped.shard_offsets``), while the
causal/window predicates are evaluated on GLOBAL striped positions
reconstructed as ``j * n + shard`` — so tile skipping still works: a q/k tile
pair is skipped when its global causal reach, segment ranges, or window reach
cannot interact.  After n steps the carried state finalizes to exactly the
single-launch packed result (same math, chunked).

Deployment note: the in-process replay (LocalExecutor) passes static shard
ids, so this Pallas kernel applies directly.  The shard_map mesh path
(`core.esp.ring_packed_prefill_spmd`) has TRACED shard ids
(lax.axis_index); it recovers static ids with the same ``lax.switch``
static-branch trick the SPMD decode path uses: `esp.switched_ring_chunk`
enumerates one branch per rank (the ring step is a python loop constant),
each baking ``q_shard=rank, k_shard=(rank-step) % n`` as the compile-time
constants the tile-skip predicates need.  Under ``impl="xla"`` the banded
variant (`ref.packed_prefill_ring_chunk_banded`, shard ids as jnp values)
still dispatches directly with no switch.  The switch path is validated
under ``impl="interpret"`` in the mesh suite; running it compiled on real
TPU hardware (each branch lowering to this Pallas kernel) is the remaining
ROADMAP item — hardware validation only, the program structure is in.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(
    off_ref, q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
    scale: float,
    window: Optional[int],
    softcap: Optional[float],
    block_q: int,
    block_k: int,
    n_seqs: int,
    n_k_blocks: int,
):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)

    # packed token indices of this tile pair
    tq = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, 1), 0)
    tk = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, (1, block_k), 1)

    def seg_ids(t):
        """Segment id per packed index: #offsets[1..B] <= t (monotone)."""

        def body(b, acc):
            return acc + jnp.where(t >= off_ref[b + 1], 1, 0)

        return jax.lax.fori_loop(0, n_seqs, body, jnp.zeros_like(t))

    seg_q = seg_ids(tq)  # [block_q, 1]
    seg_k = seg_ids(tk)  # [1, block_k]

    # tile-level skip: causal reach, segment-range overlap (seg ids are
    # monotone in t, so ranges are the tile corners), window reach
    run = ik * block_k <= iq * block_q + block_q - 1
    run &= (seg_k[0, 0] <= seg_q[block_q - 1, 0]) & (
        seg_q[0, 0] <= seg_k[0, block_k - 1]
    )
    if window is not None:
        run &= (iq * block_q - (ik * block_k + block_k - 1)) < window

    @pl.when(run)
    def _update():
        qpk = q_ref.shape[1]
        qb = q_ref[...].astype(jnp.float32).reshape(block_q * qpk, -1)
        kb = k_ref[:, 0, :].astype(jnp.float32)  # [block_k, D]
        vb = v_ref[:, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(
            qb, kb, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale  # [block_q * qpk, block_k]
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        mask = (seg_q == seg_k) & (tq >= tk)
        if window is not None:
            mask &= (tq - tk) < window
        mask = jnp.broadcast_to(
            mask[:, None, :], (block_q, qpk, block_k)
        ).reshape(block_q * qpk, block_k)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[:, 0]
        l_prev = l_ref[:, 0]
        m_blk = jnp.max(s, axis=1)
        m_new = jnp.maximum(m_prev, m_blk)
        m_safe = jnp.maximum(m_new, -1e29)  # fully-masked-row guard
        p = jnp.exp(s - m_safe[:, None])
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.where(m_prev <= NEG_INF / 2, 0.0, jnp.exp(m_prev - m_safe))
        l_new = alpha * l_prev + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, vb, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_ref[:, 0] = jnp.where(m_blk <= NEG_INF / 2, m_prev, m_new)
        l_ref[:, 0] = l_new

    @pl.when(ik == n_k_blocks - 1)
    def _emit():
        l = l_ref[:, 0]
        denom = jnp.where(l == 0.0, 1.0, l)
        o_ref[...] = (acc_ref[...] / denom[:, None]).reshape(o_ref.shape)


def packed_flash_prefill(
    q: jnp.ndarray,  # [T, H, D] packed batch
    k: jnp.ndarray,  # [T, KVH, D]
    v: jnp.ndarray,
    seq_offsets: jnp.ndarray,  # [B+1] int32 segment boundaries
    *,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    """One ragged batched launch over the packed token axis; returns the
    normalized attention output [T, H, D] (f32)."""
    t, h, d = q.shape
    kvh = k.shape[1]
    q_per_kv = h // kvh
    block_q = min(block_q, t)
    block_k = min(block_k, t)
    while t % block_q:  # 3/4-point buckets (e.g. 3*2^j): halve to a divisor
        block_q //= 2
    while t % block_k:
        block_k //= 2
    assert block_q >= 1 and block_k >= 1, (t, block_q, block_k)
    n_seqs = int(seq_offsets.shape[0]) - 1
    nq, nk = t // block_q, t // block_k
    scale = 1.0 / math.sqrt(d)

    kernel = functools.partial(
        _kernel, scale=scale, window=window, softcap=softcap,
        block_q=block_q, block_k=block_k, n_seqs=n_seqs, n_k_blocks=nk,
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,  # seq_offsets
        grid=(kvh, nq, nk),
        in_specs=[
            # q heads for this kv group: [block_q, q_per_kv, D]
            pl.BlockSpec(
                (block_q, q_per_kv, d), lambda g, iq, ik, off: (iq, g, 0)
            ),
            pl.BlockSpec((block_k, 1, d), lambda g, iq, ik, off: (ik, g, 0)),
            pl.BlockSpec((block_k, 1, d), lambda g, iq, ik, off: (ik, g, 0)),
        ],
        out_specs=pl.BlockSpec(
            (block_q, q_per_kv, d), lambda g, iq, ik, off: (iq, g, 0)
        ),
        scratch_shapes=[
            pltpu.VMEM((block_q * q_per_kv, d), jnp.float32),
            pltpu.VMEM((block_q * q_per_kv, 1), jnp.float32),
            pltpu.VMEM((block_q * q_per_kv, 1), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((t, h, d), jnp.float32),
        interpret=interpret,
    )(jnp.asarray(seq_offsets, jnp.int32), q, k, v)


# ===================================================== ring-fused chunk step


def _ring_kernel(
    qoff_ref, koff_ref, q_ref, k_ref, v_ref, o_in_ref, m_in_ref, l_in_ref,
    o_ref, m_out_ref, l_out_ref, acc_ref, m_ref, l_ref, *,
    scale: float,
    window: Optional[int],
    softcap: Optional[float],
    q_shard: int,
    k_shard: int,
    n_shards: int,
    block_q: int,
    block_k: int,
    n_seqs: int,
    n_k_blocks: int,
):
    iq = pl.program_id(1)
    ik = pl.program_id(2)
    n = n_shards

    @pl.when(ik == 0)
    def _init():  # resume the carried flash state (m=-inf empty on step 0)
        acc_ref[...] = o_in_ref[...].reshape(acc_ref.shape)
        m_ref[:, 0] = m_in_ref[...].reshape(-1)
        l_ref[:, 0] = l_in_ref[...].reshape(-1)

    # local (shard) token indices of this tile pair
    jq = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, 1), 0)
    jk = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, (1, block_k), 1)
    # global striped positions: shard r's local slot j is packed index j*n+r
    gq = jq * n + q_shard
    gk = jk * n + k_shard

    def seg_ids(j, off_ref):
        """Segment id per LOCAL index from the per-shard offsets."""

        def body(b, acc):
            return acc + jnp.where(j >= off_ref[b + 1], 1, 0)

        return jax.lax.fori_loop(0, n_seqs, body, jnp.zeros_like(j))

    seg_q = seg_ids(jq, qoff_ref)  # [block_q, 1]
    seg_k = seg_ids(jk, koff_ref)  # [1, block_k]

    # tile-level skip in GLOBAL coordinates: causal reach, segment-range
    # overlap (per-shard seg ids stay monotone in the local index), window
    run = (ik * block_k) * n + k_shard <= (iq * block_q + block_q - 1) * n + q_shard
    run &= (seg_k[0, 0] <= seg_q[block_q - 1, 0]) & (
        seg_q[0, 0] <= seg_k[0, block_k - 1]
    )
    if window is not None:
        run &= (
            (iq * block_q) * n + q_shard
            - ((ik * block_k + block_k - 1) * n + k_shard)
        ) < window

    @pl.when(run)
    def _update():
        qpk = q_ref.shape[1]
        qb = q_ref[...].astype(jnp.float32).reshape(block_q * qpk, -1)
        kb = k_ref[:, 0, :].astype(jnp.float32)  # [block_k, D]
        vb = v_ref[:, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(
            qb, kb, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale  # [block_q * qpk, block_k]
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        mask = (seg_q == seg_k) & (gq >= gk)
        if window is not None:
            mask &= (gq - gk) < window
        mask = jnp.broadcast_to(
            mask[:, None, :], (block_q, qpk, block_k)
        ).reshape(block_q * qpk, block_k)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[:, 0]
        l_prev = l_ref[:, 0]
        m_blk = jnp.max(s, axis=1)
        m_new = jnp.maximum(m_prev, m_blk)
        m_safe = jnp.maximum(m_new, -1e29)  # fully-masked-row guard
        p = jnp.exp(s - m_safe[:, None])
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.where(m_prev <= NEG_INF / 2, 0.0, jnp.exp(m_prev - m_safe))
        l_new = alpha * l_prev + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, vb, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_ref[:, 0] = jnp.where(m_blk <= NEG_INF / 2, m_prev, m_new)
        l_ref[:, 0] = l_new

    @pl.when(ik == n_k_blocks - 1)
    def _emit():  # UNNORMALIZED: the carried state continues to the next step
        o_ref[...] = acc_ref[...].reshape(o_ref.shape)
        m_out_ref[...] = m_ref[:, 0].reshape(m_out_ref.shape)
        l_out_ref[...] = l_ref[:, 0].reshape(l_out_ref.shape)


def packed_flash_prefill_ring_chunk(
    q: jnp.ndarray,  # [Tl, H, D] striped local query shard (shard q_shard)
    k: jnp.ndarray,  # [Tl, KVH, D] the KV chunk held this ring step
    v: jnp.ndarray,
    q_offsets: jnp.ndarray,  # [B+1] int32 per-shard offsets of the q shard
    k_offsets: jnp.ndarray,  # [B+1] int32 per-shard offsets of the KV chunk
    carry,  # (o [Tl,H,D], m [Tl,H], l [Tl,H]) f32 flash state, NEG_INF-empty
    *,
    q_shard: int,
    k_shard: int,
    n_shards: int,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
):
    """One ring step: fold one striped KV chunk into the carried flash state
    with a single ragged launch.  Returns the updated (o, m, l) — finalize
    with ``o / l`` after the last step (empty rows keep m=-inf, l=0)."""
    tl, h, d = q.shape
    kvh = k.shape[1]
    q_per_kv = h // kvh
    block_q = min(block_q, tl)
    block_k = min(block_k, tl)
    while tl % block_q:  # 3/4-point buckets (e.g. 3*2^j): halve to a divisor
        block_q //= 2
    while tl % block_k:
        block_k //= 2
    assert block_q >= 1 and block_k >= 1, (tl, block_q, block_k)
    n_seqs = int(q_offsets.shape[0]) - 1
    nq, nk = tl // block_q, tl // block_k
    scale = 1.0 / math.sqrt(d)
    o_c, m_c, l_c = carry

    kernel = functools.partial(
        _ring_kernel, scale=scale, window=window, softcap=softcap,
        q_shard=q_shard, k_shard=k_shard, n_shards=n_shards,
        block_q=block_q, block_k=block_k, n_seqs=n_seqs, n_k_blocks=nk,
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,  # q_offsets, k_offsets
        grid=(kvh, nq, nk),
        in_specs=[
            pl.BlockSpec(
                (block_q, q_per_kv, d), lambda g, iq, ik, qo, ko: (iq, g, 0)
            ),
            pl.BlockSpec((block_k, 1, d), lambda g, iq, ik, qo, ko: (ik, g, 0)),
            pl.BlockSpec((block_k, 1, d), lambda g, iq, ik, qo, ko: (ik, g, 0)),
            # carried flash state, blocked like q / its per-head stats
            pl.BlockSpec(
                (block_q, q_per_kv, d), lambda g, iq, ik, qo, ko: (iq, g, 0)
            ),
            pl.BlockSpec((block_q, q_per_kv), lambda g, iq, ik, qo, ko: (iq, g)),
            pl.BlockSpec((block_q, q_per_kv), lambda g, iq, ik, qo, ko: (iq, g)),
        ],
        out_specs=[
            pl.BlockSpec(
                (block_q, q_per_kv, d), lambda g, iq, ik, qo, ko: (iq, g, 0)
            ),
            pl.BlockSpec((block_q, q_per_kv), lambda g, iq, ik, qo, ko: (iq, g)),
            pl.BlockSpec((block_q, q_per_kv), lambda g, iq, ik, qo, ko: (iq, g)),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_q * q_per_kv, d), jnp.float32),
            pltpu.VMEM((block_q * q_per_kv, 1), jnp.float32),
            pltpu.VMEM((block_q * q_per_kv, 1), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((tl, h, d), jnp.float32),
            jax.ShapeDtypeStruct((tl, h), jnp.float32),
            jax.ShapeDtypeStruct((tl, h), jnp.float32),
        ],
        interpret=interpret,
    )(
        jnp.asarray(q_offsets, jnp.int32), jnp.asarray(k_offsets, jnp.int32),
        q, k, v,
        jnp.asarray(o_c, jnp.float32), jnp.asarray(m_c, jnp.float32),
        jnp.asarray(l_c, jnp.float32),
    )

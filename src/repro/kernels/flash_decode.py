"""Pallas TPU kernel: flash-decode partial over one KV shard.

LoongServe §6 implements "a customized version of Flash-Decoding with extra
parameters to support ESP": a master's query attends to the KV shard held by
*this* instance, emitting an UNNORMALIZED partial (o, m, l) that the
multi-master combine (LSE-weighted reduce) merges across instances. The extra
ESP parameters here are `k_pos_offset` (the shard's global token offset) and
the per-request valid length.

Tiling: one q vector per (b, h) stays in VMEM; the KV shard streams in BK
blocks over the sequential last grid dim with f32 accumulators in scratch.

Sliding-window convention (shared across ALL kernels in this package, see
striped_attention.py): a query at global position ``qp`` attends keys with
``0 <= qp - kp < window``, self-inclusive.  Here the query sits at global
position ``lengths`` — its own KV is NOT in the shard (it rides separately
through the multi-master combine) — so the window test
``kpos > cache_len - window`` is exactly ``qp - kpos < window``.  Together
with the query's own token the attended set has ``window`` elements, matching
the striped prefill kernel at the boundary
(tests/test_kernels.py::test_window_convention_parity).
"""
from __future__ import annotations

import functools
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.models.attention import Partial

NEG_INF = -1e30


def _kernel(
    q_ref, k_ref, v_ref, len_ref,
    o_ref, m_out_ref, l_out_ref,
    acc_ref, m_ref, l_ref,
    *,
    scale: float,
    window: Optional[int],
    softcap: Optional[float],
    offset: int,
    block_k: int,
    n_k_blocks: int,
):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)

    qb = q_ref[0, 0, :, :].astype(jnp.float32)  # [H_blk, D] (q heads block)
    kb = k_ref[0, :, 0, :].astype(jnp.float32)  # [BK, D]
    vb = v_ref[0, :, 0, :].astype(jnp.float32)
    s = jax.lax.dot_general(
        qb, kb, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale  # [H_blk, BK]
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)

    cache_len = len_ref[0]  # this request's valid length
    kpos = offset + ik * block_k + jax.lax.broadcasted_iota(jnp.int32, (s.shape[0], block_k), 1)
    mask = kpos < cache_len
    if window is not None:
        mask &= kpos > cache_len - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[:, 0]
    l_prev = l_ref[:, 0]
    m_blk = jnp.max(s, axis=1)
    m_new = jnp.maximum(m_prev, m_blk)
    m_safe = jnp.maximum(m_new, -1e29)
    p = jnp.exp(s - m_safe[:, None])
    p = jnp.where(mask, p, 0.0)
    alpha = jnp.where(m_prev <= NEG_INF / 2, 0.0, jnp.exp(m_prev - m_safe))
    l_new = alpha * l_prev + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p, vb, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_ref[:, 0] = jnp.where(m_blk <= NEG_INF / 2, m_prev, m_new)
    l_ref[:, 0] = l_new

    @pl.when(ik == n_k_blocks - 1)
    def _emit():
        o_ref[0, 0, :, :] = acc_ref[...]
        mm = m_ref[:, 0]
        m_out_ref[0, 0, :] = jnp.where(mm <= NEG_INF / 2, -jnp.inf, mm)
        l_out_ref[0, 0, :] = l_ref[:, 0]


def flash_decode_partial(
    q: jnp.ndarray,  # [B, 1, H, D]
    k: jnp.ndarray,  # [B, S_shard, KVH, D] local KV shard
    v: jnp.ndarray,
    lengths: jnp.ndarray,  # [B] int32 global valid cache length per request
    *,
    k_pos_offset: int = 0,  # global position of this shard's first token
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    block_k: int = 128,
    interpret: bool = False,
) -> Partial:
    """Returns the unnormalized Partial over this KV shard (to be merged with
    other shards' partials via attention.merge_partial / the ESP combine)."""
    b, _, h, d = q.shape
    s, kvh = k.shape[1], k.shape[2]
    q_per_kv = h // kvh
    block_k = min(block_k, s)
    assert s % block_k == 0
    n_k = s // block_k
    grid = (b, kvh, n_k)  # one program per (request, kv head group)
    scale = 1.0 / math.sqrt(d)

    kernel = functools.partial(
        _kernel, scale=scale, window=window, softcap=softcap,
        offset=k_pos_offset, block_k=block_k, n_k_blocks=n_k,
    )
    o, m, l = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            # q heads for this kv group: [1, 1, q_per_kv, D]
            pl.BlockSpec((1, 1, q_per_kv, d), lambda b_, g, ik: (b_, 0, g, 0)),
            pl.BlockSpec((1, block_k, 1, d), lambda b_, g, ik: (b_, ik, g, 0)),
            pl.BlockSpec((1, block_k, 1, d), lambda b_, g, ik: (b_, ik, g, 0)),
            pl.BlockSpec((1,), lambda b_, g, ik: (b_,)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, q_per_kv, d), lambda b_, g, ik: (b_, 0, g, 0)),
            pl.BlockSpec((1, 1, q_per_kv), lambda b_, g, ik: (b_, 0, g)),
            pl.BlockSpec((1, 1, q_per_kv), lambda b_, g, ik: (b_, 0, g)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, 1, h, d), jnp.float32),
            jax.ShapeDtypeStruct((b, 1, h), jnp.float32),
            jax.ShapeDtypeStruct((b, 1, h), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((q_per_kv, d), jnp.float32),
            pltpu.VMEM((q_per_kv, 1), jnp.float32),
            pltpu.VMEM((q_per_kv, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, lengths.astype(jnp.int32))
    return Partial(o=o, m=m, l=l)

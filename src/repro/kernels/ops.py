"""jit'd dispatch wrappers for the Pallas kernels.

impl:
  * "xla"        — pure-jnp reference math (the dry-run / SPMD path; XLA fuses
                   it well enough on CPU and is the portable fallback on TPU);
  * "pallas"     — the Pallas TPU kernel (compiled for TPU);
  * "interpret"  — the Pallas kernel body executed in interpret mode (CPU
                   validation of the TPU kernel).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.flash_decode import flash_decode_partial as _fd_kernel
from repro.kernels.striped_attention import striped_flash_attention as _sa_kernel
from repro.models.attention import Partial

_DEFAULT_IMPL = "xla"


def set_default_impl(impl: str) -> None:
    global _DEFAULT_IMPL
    assert impl in ("xla", "pallas", "interpret")
    _DEFAULT_IMPL = impl


def get_default_impl() -> str:
    return _DEFAULT_IMPL


def attention(
    q, k, v, q_pos, k_pos, *, causal=True, window=None, softcap=None,
    impl: Optional[str] = None, block_q: int = 128, block_k: int = 128,
):
    impl = impl or _DEFAULT_IMPL
    if impl == "xla":
        return ref.striped_flash_attention_ref(
            q, k, v, q_pos, k_pos, causal=causal, window=window, softcap=softcap
        )
    return _sa_kernel(
        q, k, v, jnp.asarray(q_pos), jnp.asarray(k_pos), causal=causal,
        window=window, softcap=softcap, block_q=block_q, block_k=block_k,
        interpret=(impl == "interpret"),
    )


def decode_partial(
    q, k, v, lengths, *, k_pos_offset=0, window=None, softcap=None,
    impl: Optional[str] = None, block_k: int = 128,
) -> Partial:
    impl = impl or _DEFAULT_IMPL
    if impl == "xla":
        return ref.flash_decode_partial_ref(
            q, k, v, lengths, k_pos_offset=k_pos_offset, window=window,
            softcap=softcap,
        )
    return _fd_kernel(
        q, k, v, lengths, k_pos_offset=k_pos_offset, window=window,
        softcap=softcap, block_k=block_k, interpret=(impl == "interpret"),
    )

"""jit'd dispatch wrappers for the Pallas kernels.

impl:
  * "xla"        — pure-jnp reference math (the dry-run / SPMD path; XLA fuses
                   it well enough on CPU and is the portable fallback on TPU);
  * "pallas"     — the Pallas TPU kernel (compiled for TPU);
  * "interpret"  — the Pallas kernel body executed in interpret mode (CPU
                   validation of the TPU kernel).

The default impl can be selected without code edits via the
``REPRO_KERNEL_IMPL`` environment variable (benchmarks / CI), and overridden
programmatically with `set_default_impl`.

`dispatch_counts` tracks kernel/dispatch call volume per entry point so tests
and benchmarks can assert launch-count invariants (e.g. one paged decode
launch per instance per layer, independent of batch size).
"""
from __future__ import annotations

import os
from collections import Counter
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.flash_decode import flash_decode_partial as _fd_kernel
from repro.kernels.paged_flash_decode import (
    paged_flash_decode_partial as _pfd_kernel,
)
from repro.kernels.paged_flash_prefill import (
    packed_flash_prefill as _pfp_kernel,
    packed_flash_prefill_ring_chunk as _pfp_ring_kernel,
)
from repro.kernels.striped_attention import striped_flash_attention as _sa_kernel
from repro.models.attention import Partial

_VALID_IMPLS = ("xla", "pallas", "interpret")


class TransientDispatchError(RuntimeError):
    """A kernel dispatch failed transiently (injected by the chaos harness
    or raised by a flaky backend).  The engine retries with bounded backoff
    before declaring the instance failed — see engine/server.py."""


# Fault-injection seam: when set, every dispatch entry point (and the
# executors' per-batch dispatch guards) calls the hook with a point name
# BEFORE doing any work; the hook may raise TransientDispatchError to
# simulate a flaky launch.  Raising happens before any compute or KV write,
# so a retried dispatch is side-effect free.  `None` (the default) is
# zero-overhead beyond one attribute read.
_fault_hook = None


def set_fault_hook(hook) -> None:
    """Install (or clear, with None) the dispatch fault hook."""
    global _fault_hook
    _fault_hook = hook


def check_fault(point: str) -> None:
    """Raise-point consulted at the top of every dispatch entry.  NOTE:
    jitted callers only reach the ops wrappers at trace time (cached
    programs never re-enter Python), so the executors additionally call
    this per batch dispatch — those are the reliable injection points."""
    if _fault_hook is not None:
        _fault_hook(point)


def _impl_from_env() -> str:
    impl = os.environ.get("REPRO_KERNEL_IMPL", "xla")
    if impl not in _VALID_IMPLS:
        raise ValueError(
            f"REPRO_KERNEL_IMPL={impl!r}: expected one of {_VALID_IMPLS}"
        )
    return impl


_DEFAULT_IMPL = _impl_from_env()

dispatch_counts: Counter = Counter()

# communication accounting (bytes, per entry point): ring ppermute legs are
# counted at trace time from their (static) per-rank payload shapes, so one
# compile of an SPMD program yields the exact per-leg byte volume without
# instrumenting the runtime.
comm_bytes: Counter = Counter()


def reset_dispatch_counts() -> None:
    dispatch_counts.clear()
    comm_bytes.clear()


def set_default_impl(impl: str) -> None:
    global _DEFAULT_IMPL
    assert impl in _VALID_IMPLS
    _DEFAULT_IMPL = impl


def get_default_impl() -> str:
    return _DEFAULT_IMPL


def attention(
    q, k, v, q_pos, k_pos, *, causal=True, window=None, softcap=None,
    impl: Optional[str] = None, block_q: int = 128, block_k: int = 128,
):
    impl = impl or _DEFAULT_IMPL
    check_fault("attention")
    dispatch_counts["attention"] += 1
    if impl == "xla":
        return ref.striped_flash_attention_ref(
            q, k, v, q_pos, k_pos, causal=causal, window=window, softcap=softcap
        )
    return _sa_kernel(
        q, k, v, jnp.asarray(q_pos), jnp.asarray(k_pos), causal=causal,
        window=window, softcap=softcap, block_q=block_q, block_k=block_k,
        interpret=(impl == "interpret"),
    )


def decode_partial(
    q, k, v, lengths, *, k_pos_offset=0, window=None, softcap=None,
    impl: Optional[str] = None, block_k: int = 128,
) -> Partial:
    """Per-request decode over a dense KV shard (legacy gather-dense path)."""
    impl = impl or _DEFAULT_IMPL
    check_fault("decode_partial")
    dispatch_counts["decode_partial"] += 1
    if impl == "xla":
        return ref.flash_decode_partial_ref(
            q, k, v, lengths, k_pos_offset=k_pos_offset, window=window,
            softcap=softcap,
        )
    return _fd_kernel(
        q, k, v, lengths, k_pos_offset=k_pos_offset, window=window,
        softcap=softcap, block_k=block_k, interpret=(impl == "interpret"),
    )


def prefill_packed(
    q, k, v, seq_offsets, *, window=None, softcap=None, max_seq_len=None,
    impl: Optional[str] = None, block_q: int = 128, block_k: int = 128,
):
    """Packed ragged causal prefill: ONE launch for a whole prefill batch
    concatenated on a single token axis (see kernels/paged_flash_prefill.py).
    ``max_seq_len`` (static) bounds the banded XLA fallback's reach; the
    Pallas kernel skips non-interacting tiles from the prefetched offsets."""
    impl = impl or _DEFAULT_IMPL
    check_fault("prefill_packed")
    dispatch_counts["prefill_packed"] += 1
    if impl == "xla":
        return ref.packed_prefill_banded(
            q, k, v, seq_offsets, window=window, softcap=softcap,
            block_q=block_q, max_seq_len=max_seq_len,
        )
    return _pfp_kernel(
        q, k, v, jnp.asarray(seq_offsets, jnp.int32), window=window,
        softcap=softcap, block_q=block_q, block_k=block_k,
        interpret=(impl == "interpret"),
    )


def prefill_ring_chunk(
    q, k, v, q_offsets, k_offsets, carry=None, *,
    q_shard: int, k_shard: int, n_shards: int,
    window=None, softcap=None, max_seq_len=None,
    impl: Optional[str] = None, block_q: int = 128, block_k: int = 128,
):
    """One ring step of the DoP>1 ESP packed prefill: fold one striped KV
    chunk into the carried unnormalized (o, m, l) flash state with a single
    ragged launch (see kernels/paged_flash_prefill.py — ring fusion).

    ``q_offsets``/``k_offsets`` are the per-shard recomputed segment offsets
    (`striped.shard_offsets`) the kernel/banded fallback derive segment ids
    from; causal/window masks evaluate on global striped positions.
    ``carry=None`` starts an empty state (m=-inf).  Finalize after the last
    step with ``o / l`` (l==0 rows are bucket padding)."""
    impl = impl or _DEFAULT_IMPL
    check_fault("prefill_ring_chunk")
    dispatch_counts["prefill_ring_chunk"] += 1
    if carry is None:
        tl, h = q.shape[0], q.shape[1]
        carry = (
            jnp.zeros((tl, h, q.shape[2]), jnp.float32),
            jnp.full((tl, h), -jnp.inf, jnp.float32),
            jnp.zeros((tl, h), jnp.float32),
        )
    if impl == "xla":
        return ref.packed_prefill_ring_chunk_banded(
            q, k, v, q_offsets, k_offsets, carry,
            q_shard=q_shard, k_shard=k_shard, n_shards=n_shards,
            window=window, softcap=softcap, block_q=block_q,
            max_seq_len=max_seq_len,
        )
    return _pfp_ring_kernel(
        q, k, v, jnp.asarray(q_offsets, jnp.int32),
        jnp.asarray(k_offsets, jnp.int32), carry,
        q_shard=q_shard, k_shard=k_shard, n_shards=n_shards,
        window=window, softcap=softcap, block_q=block_q, block_k=block_k,
        interpret=(impl == "interpret"),
    )


def _payload_bytes(operands) -> int:
    """Per-rank payload bytes of a collective's operands (static shapes
    inside a shard_map body make trace-time accounting exact)."""
    return sum(
        int(x.size) * jnp.dtype(x.dtype).itemsize
        for x in jax.tree_util.tree_leaves(operands)
    )


def ring_ppermute(operands, axis_name: str, pairs):
    """`lax.ppermute` wrapper for the SPMD prefill ring: forwards the KV
    chunk (and its per-shard offsets / any carried metadata) to the ring
    neighbour, counting one dispatch and the exact per-rank payload bytes
    (shapes are static inside the shard_map body, so trace-time accounting
    is exact).  Every ring leg of the mesh executor goes through here so
    tests and benchmarks can assert/record the communication volume."""
    dispatch_counts["ring_ppermute"] += 1
    comm_bytes["ring_ppermute"] += _payload_bytes(operands)
    return jax.lax.ppermute(operands, axis_name, pairs)


def psum(operands, axis_name: str):
    """Counted `lax.psum`: the SPMD decode LSE-merge reduces the weighted
    (o·exp(m-M), l·exp(m-M)) accumulators across the KV shards through here,
    so `comm_bytes` covers decode traffic the same way `ring_ppermute`
    covers the prefill ring.  Bytes are per-rank payload (the reduced tensor
    size), not wire volume — the all-reduce algorithm is the backend's."""
    dispatch_counts["psum"] += 1
    comm_bytes["psum"] += _payload_bytes(operands)
    return jax.lax.psum(operands, axis_name)


def pmax(operands, axis_name: str):
    """Counted `lax.pmax` (the decode merge's global running-max M)."""
    dispatch_counts["pmax"] += 1
    comm_bytes["pmax"] += _payload_bytes(operands)
    return jax.lax.pmax(operands, axis_name)


def psum_scatter(operands, axis_name: str, *, scatter_dimension: int = 0,
                 tiled: bool = True):
    """Counted `lax.psum_scatter`: the batch-sharded decode merge reduces
    the weighted (o·exp(m-M), l·exp(m-M)) accumulators AND hands each rank
    only its own batch slice of the result in one collective — the paper's
    "send back partial results" addressed to the masters (§4.2) instead of
    replicated everywhere.  Bytes are the per-rank payload CONTRIBUTED
    (the full pre-scatter tensor), like `psum`."""
    dispatch_counts["psum_scatter"] += 1
    comm_bytes["psum_scatter"] += _payload_bytes(operands)
    return jax.tree.map(
        lambda x: jax.lax.psum_scatter(
            x, axis_name, scatter_dimension=scatter_dimension, tiled=tiled
        ),
        operands,
    )


def all_gather(operands, axis_name: str, *, axis: int = 0, tiled: bool = True):
    """Counted `lax.all_gather`: the batch-sharded decode boundary's q-slice
    exchange (every rank needs the full-batch query against its local KV)
    and the in-program sampled-token / new-KV exchanges go through here so
    `comm_bytes` covers them.  Bytes are the per-rank payload contributed
    (the LOCAL slice each rank injects)."""
    dispatch_counts["all_gather"] += 1
    comm_bytes["all_gather"] += _payload_bytes(operands)
    return jax.tree.map(
        lambda x: jax.lax.all_gather(x, axis_name, axis=axis, tiled=tiled),
        operands,
    )


def count_transfer(key: str, operands) -> None:
    """Account an explicit host-driven device transfer (e.g. the per-shard
    decode loop's q broadcast / partial pull-home in `core.paged_decode`)
    under `comm_bytes[key]` — decode comm stays visible to benchmarks even
    on the non-SPMD path."""
    comm_bytes[key] += _payload_bytes(operands)


def paged_decode_partial(
    q, k_pages, v_pages, block_table, lengths, page_pos=None, *,
    query_pos=None, window=None, softcap=None, impl: Optional[str] = None,
) -> Partial:
    """Batched ragged decode over the paged pool: ONE launch for every
    request of this instance (see kernels/paged_flash_decode.py)."""
    impl = impl or _DEFAULT_IMPL
    check_fault("paged_decode_partial")
    dispatch_counts["paged_decode_partial"] += 1
    if impl == "xla":
        return ref.paged_flash_decode_partial_ref(
            q, k_pages, v_pages, block_table, lengths, page_pos,
            query_pos=query_pos, window=window, softcap=softcap,
        )
    return _pfd_kernel(
        q, k_pages, v_pages, jnp.asarray(block_table),
        jnp.asarray(lengths), page_pos,
        query_pos=query_pos, window=window, softcap=softcap,
        interpret=(impl == "interpret"),
    )

"""Pallas TPU kernel: striped flash attention (the per-ring-step partial).

This is the compute hot-spot of ESP prefill (LoongServe §6 tunes a Triton
StripedAttention kernel; the TPU adaptation per DESIGN.md §2 replaces
SM-occupancy/shared-memory tuning with BlockSpec VMEM tiling):

  * the q block (BQ x D) stays resident in VMEM across the KV stream;
  * KV is streamed through VMEM in BK x D blocks via the sequential last grid
    dimension, with f32 online-softmax accumulators in VMEM scratch;
  * masks are *position-based* (q_pos/k_pos blocks ride along), so the same
    kernel serves the striped layout, contiguous ring layouts, SWA windows
    and the non-causal encoder case;
  * block shapes default to 128 (MXU-aligned); GQA is handled by the KV-head
    index map (kv_head = q_head // q_per_kv) so KV blocks are fetched once
    per q-head group, not expanded in HBM.

Sliding-window convention (shared across ALL kernels in this package): a
query at global position ``qp`` attends keys at ``kp`` iff
``0 <= qp - kp < window`` — self-inclusive, so the attended set has exactly
``window`` elements.  This kernel applies it literally; the decode kernels
(flash_decode.py, paged_flash_decode.py) express the same predicate in terms
of the cache length because the query's own KV is not part of the shard.
Cross-kernel parity at the window boundary is pinned by
tests/test_kernels.py::test_window_convention_parity.

Validated in interpret mode against kernels/ref.py on CPU; targets TPU.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(
    q_ref, k_ref, v_ref, qp_ref, kp_ref,  # inputs
    o_ref,  # output
    acc_ref, m_ref, l_ref,  # VMEM scratch
    *,
    scale: float,
    causal: bool,
    window: Optional[int],
    softcap: Optional[float],
    n_k_blocks: int,
):
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)

    qb = q_ref[0, :, 0, :].astype(jnp.float32)  # [BQ, D]
    kb = k_ref[0, :, 0, :].astype(jnp.float32)  # [BK, D]
    vb = v_ref[0, :, 0, :].astype(jnp.float32)
    s = jax.lax.dot_general(
        qb, kb, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale  # [BQ, BK]
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)

    qp = qp_ref[:].astype(jnp.int32)  # [BQ]
    kp = kp_ref[:].astype(jnp.int32)  # [BK]
    mask = jnp.ones_like(s, dtype=jnp.bool_)
    if causal:
        mask &= qp[:, None] >= kp[None, :]
    if window is not None:
        mask &= (qp[:, None] - kp[None, :]) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[:, 0]  # [BQ]
    l_prev = l_ref[:, 0]
    m_blk = jnp.max(s, axis=1)
    m_new = jnp.maximum(m_prev, m_blk)
    m_safe = jnp.maximum(m_new, -1e29)  # fully-masked-row guard
    p = jnp.exp(s - m_safe[:, None])
    p = jnp.where(mask, p, 0.0)
    alpha = jnp.where(m_prev <= NEG_INF / 2, 0.0, jnp.exp(m_prev - m_safe))
    l_new = alpha * l_prev + jnp.sum(p, axis=1)
    acc = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p, vb, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    acc_ref[...] = acc
    m_ref[:, 0] = jnp.where(m_blk <= NEG_INF / 2, m_prev, m_new)
    l_ref[:, 0] = l_new

    @pl.when(ik == n_k_blocks - 1)
    def _finalize():
        l = l_ref[:, 0]
        denom = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, :, 0, :] = (acc_ref[...] / denom[:, None]).astype(o_ref.dtype)


def striped_flash_attention(
    q: jnp.ndarray,  # [B, Sq, H, D]
    k: jnp.ndarray,  # [B, Sk, KVH, D]
    v: jnp.ndarray,
    q_pos: jnp.ndarray,  # [Sq] int32 global positions (striped layout ok)
    k_pos: jnp.ndarray,  # [Sk]
    *,
    causal: bool = True,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    b, sq, h, d = q.shape
    sk, kvh = k.shape[1], k.shape[2]
    q_per_kv = h // kvh
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    assert sq % block_q == 0 and sk % block_k == 0, (sq, sk, block_q, block_k)
    n_q, n_k = sq // block_q, sk // block_k
    grid = (b, h, n_q, n_k)
    scale = 1.0 / math.sqrt(d)

    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, window=window, softcap=softcap,
        n_k_blocks=n_k,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, 1, d), lambda b_, h_, iq, ik: (b_, iq, h_, 0)),
            pl.BlockSpec(
                (1, block_k, 1, d),
                lambda b_, h_, iq, ik, qpk=q_per_kv: (b_, ik, h_ // qpk, 0),
            ),
            pl.BlockSpec(
                (1, block_k, 1, d),
                lambda b_, h_, iq, ik, qpk=q_per_kv: (b_, ik, h_ // qpk, 0),
            ),
            pl.BlockSpec((block_q,), lambda b_, h_, iq, ik: (iq,)),
            pl.BlockSpec((block_k,), lambda b_, h_, iq, ik: (ik,)),
        ],
        out_specs=pl.BlockSpec(
            (1, block_q, 1, d), lambda b_, h_, iq, ik: (b_, iq, h_, 0)
        ),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, q_pos.astype(jnp.int32), k_pos.astype(jnp.int32))

"""Pallas TPU kernel: batched ragged flash-decode over the paged KV pool.

One launch serves EVERY decode request of an instance: the grid runs over
``(request, kv_head_group, page)`` and each program streams one page of the
pool's paged storage through VMEM, routed by a scalar-prefetched per-request
block table (the page index is known before the DMA is issued, the classic
paged-attention trick).  This replaces O(batch) per-request
`flash_decode_partial` launches fed by dense host-side gathers — the pool is
attended *in place*.

Contract (mirrors `repro.kvcache.pool.KVPool` layout):
  * ``k_pages``/``v_pages``: [n_pages, P, KVH, D] — one attention
    application's storage, shared by all requests;
  * ``block_table``: [B, max_pages] int32 — request b's local token j lives
    in page ``block_table[b, j // P]`` at offset ``j % P`` (padding pages are
    ignored via the length mask);
  * ``lengths``: [B] int32 — number of valid local tokens per request
    (ragged; zero-length requests yield m=-inf, l=0 like any fully-masked
    shard, which the multi-master combine treats as a no-op);
  * masked tail pages: the last page of each request is partially valid.

Window semantics (shared repo convention — see striped_attention.py and
flash_decode.py): a query at global position ``qp`` attends keys with
``0 <= qp - kp < window``, self-inclusive.  The decode query's own KV is NOT
in the pool (it rides separately through the multi-master combine), so the
kernel takes explicit ``query_pos`` and per-slot global positions
(``page_pos``) and applies ``query_pos - page_pos < window``.  Causality
needs no mask here: every pooled token precedes the query by construction.

Emits the unnormalized Partial(o, m, l) for ALL requests in one launch; the
ESP multi-master combine (attention.merge_partial) merges partials across
instances exactly as before — scaling migration stays zero-copy.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.models.attention import Partial, empty_partial

NEG_INF = -1e30


def _kernel(
    # scalar-prefetch refs, inputs (pos only when windowed), outputs, scratch
    bt_ref, len_ref, qp_ref, q_ref, k_ref, v_ref, *rest,
    scale: float,
    window: Optional[int],
    softcap: Optional[float],
    page_size: int,
    n_page_blocks: int,
):
    if window is not None:
        pos_ref, o_ref, m_out_ref, l_out_ref, acc_ref, m_ref, l_ref = rest
    else:
        o_ref, m_out_ref, l_out_ref, acc_ref, m_ref, l_ref = rest
    b = pl.program_id(0)
    ip = pl.program_id(2)

    @pl.when(ip == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)

    qb = q_ref[0, 0, :, :].astype(jnp.float32)  # [H_blk, D] (q heads block)
    kb = k_ref[0, :, 0, :].astype(jnp.float32)  # [P, D] one page
    vb = v_ref[0, :, 0, :].astype(jnp.float32)
    s = jax.lax.dot_general(
        qb, kb, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale  # [H_blk, P]
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)

    n_local = len_ref[b]  # this request's ragged local token count
    j_local = ip * page_size + jax.lax.broadcasted_iota(
        jnp.int32, (s.shape[0], page_size), 1
    )
    mask = j_local < n_local  # masked tail page (+ padding pages entirely)
    if window is not None:
        kp = pos_ref[0, :].astype(jnp.int32)  # [P] global positions
        mask &= (qp_ref[b] - kp[None, :]) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[:, 0]
    l_prev = l_ref[:, 0]
    m_blk = jnp.max(s, axis=1)
    m_new = jnp.maximum(m_prev, m_blk)
    m_safe = jnp.maximum(m_new, -1e29)  # fully-masked-row guard
    p = jnp.exp(s - m_safe[:, None])
    p = jnp.where(mask, p, 0.0)
    alpha = jnp.where(m_prev <= NEG_INF / 2, 0.0, jnp.exp(m_prev - m_safe))
    l_new = alpha * l_prev + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p, vb, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_ref[:, 0] = jnp.where(m_blk <= NEG_INF / 2, m_prev, m_new)
    l_ref[:, 0] = l_new

    @pl.when(ip == n_page_blocks - 1)
    def _emit():
        o_ref[0, 0, :, :] = acc_ref[...]
        mm = m_ref[:, 0]
        m_out_ref[0, 0, :] = jnp.where(mm <= NEG_INF / 2, -jnp.inf, mm)
        l_out_ref[0, 0, :] = l_ref[:, 0]


def paged_flash_decode_partial(
    q: jnp.ndarray,  # [B, 1, H, D]
    k_pages: jnp.ndarray,  # [n_pages, P, KVH, D] pool storage (one layer)
    v_pages: jnp.ndarray,
    block_table: jnp.ndarray,  # [B, max_pages] int32 page ids
    lengths: jnp.ndarray,  # [B] int32 valid local tokens per request
    page_pos: Optional[jnp.ndarray] = None,  # [n_pages, P] int32 global pos
    *,
    query_pos: Optional[jnp.ndarray] = None,  # [B] int32, required w/ window
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    interpret: bool = False,
) -> Partial:
    """One ragged batched launch over the paged pool; returns the
    unnormalized Partial over this instance's KV shard for every request."""
    b, sq, h, d = q.shape
    assert sq == 1, "decode kernel: one query token per request"
    n_pages, page_size, kvh = k_pages.shape[0], k_pages.shape[1], k_pages.shape[2]
    q_per_kv = h // kvh
    max_pages = block_table.shape[1]
    if max_pages == 0:
        return empty_partial(b, sq, h, d)
    if window is not None:
        assert page_pos is not None and query_pos is not None, (
            "window masking needs per-slot global positions + query positions"
        )
    if query_pos is None:
        query_pos = jnp.zeros((b,), jnp.int32)
    scale = 1.0 / math.sqrt(d)

    kernel = functools.partial(
        _kernel, scale=scale, window=window, softcap=softcap,
        page_size=page_size, n_page_blocks=max_pages,
    )
    in_specs = [
        # q heads for this kv group: [1, 1, q_per_kv, D]
        pl.BlockSpec(
            (1, 1, q_per_kv, d),
            lambda b_, g, ip, bt, ln, qp: (b_, 0, g, 0),
        ),
        # one KV page, routed by the prefetched block table
        pl.BlockSpec(
            (1, page_size, 1, d),
            lambda b_, g, ip, bt, ln, qp: (bt[b_, ip], 0, g, 0),
        ),
        pl.BlockSpec(
            (1, page_size, 1, d),
            lambda b_, g, ip, bt, ln, qp: (bt[b_, ip], 0, g, 0),
        ),
    ]
    operands = [q, k_pages, v_pages]
    if window is not None:
        # per-slot positions ride along ONLY when windowed — unwindowed
        # decode skips the O(capacity) pos upload/DMA entirely
        in_specs.append(pl.BlockSpec(
            (1, page_size),
            lambda b_, g, ip, bt, ln, qp: (bt[b_, ip], 0),
        ))
        operands.append(jnp.asarray(page_pos, jnp.int32))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,  # block_table, lengths, query_pos
        grid=(b, kvh, max_pages),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec(
                (1, 1, q_per_kv, d), lambda b_, g, ip, bt, ln, qp: (b_, 0, g, 0)
            ),
            pl.BlockSpec(
                (1, 1, q_per_kv), lambda b_, g, ip, bt, ln, qp: (b_, 0, g)
            ),
            pl.BlockSpec(
                (1, 1, q_per_kv), lambda b_, g, ip, bt, ln, qp: (b_, 0, g)
            ),
        ],
        scratch_shapes=[
            pltpu.VMEM((q_per_kv, d), jnp.float32),
            pltpu.VMEM((q_per_kv, 1), jnp.float32),
            pltpu.VMEM((q_per_kv, 1), jnp.float32),
        ],
    )
    o, m, l = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((b, 1, h, d), jnp.float32),
            jax.ShapeDtypeStruct((b, 1, h), jnp.float32),
            jax.ShapeDtypeStruct((b, 1, h), jnp.float32),
        ],
        interpret=interpret,
    )(
        jnp.asarray(block_table, jnp.int32),
        jnp.asarray(lengths, jnp.int32),
        jnp.asarray(query_pos, jnp.int32),
        *operands,
    )
    return Partial(o=o, m=m, l=l)

"""Pure-jnp oracles for the Pallas kernels (allclose targets in tests)."""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.models import attention as A


def striped_flash_attention_ref(
    q, k, v, q_pos, k_pos, *, causal=True, window=None, softcap=None
):
    return A.full_attention(
        q, k, v, q_pos=jnp.asarray(q_pos), k_pos=jnp.asarray(k_pos),
        causal=causal, window=window, softcap=softcap,
    )


def flash_decode_partial_ref(
    q, k, v, lengths, *, k_pos_offset=0, window=None, softcap=None
) -> A.Partial:
    b, s = k.shape[0], k.shape[1]
    pos = k_pos_offset + jnp.arange(s)
    cl = jnp.asarray(lengths)
    valid = pos[None, :] < cl[:, None]
    if window is not None:
        valid &= pos[None, :] > (cl[:, None] - window)
    mask = jnp.broadcast_to(valid[:, None, :], (b, q.shape[1], s))
    return A.partial_attention(q, k, v, mask, softcap=softcap)

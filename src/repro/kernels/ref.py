"""Pure-jnp oracles for the Pallas kernels (allclose targets in tests)."""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.models import attention as A


def striped_flash_attention_ref(
    q, k, v, q_pos, k_pos, *, causal=True, window=None, softcap=None
):
    return A.full_attention(
        q, k, v, q_pos=jnp.asarray(q_pos), k_pos=jnp.asarray(k_pos),
        causal=causal, window=window, softcap=softcap,
    )


def flash_decode_partial_ref(
    q, k, v, lengths, *, k_pos_offset=0, window=None, softcap=None
) -> A.Partial:
    b, s = k.shape[0], k.shape[1]
    pos = k_pos_offset + jnp.arange(s)
    cl = jnp.asarray(lengths)
    valid = pos[None, :] < cl[:, None]
    if window is not None:
        # repo window convention (see striped_attention.py): the query sits at
        # global position `lengths` (its own KV is not in the shard), so
        # qp - kp < window  <=>  kp > lengths - window
        valid &= pos[None, :] > (cl[:, None] - window)
    mask = jnp.broadcast_to(valid[:, None, :], (b, q.shape[1], s))
    return A.partial_attention(q, k, v, mask, softcap=softcap)


def paged_flash_decode_partial_ref(
    q,  # [B, 1, H, D]
    k_pages,  # [n_pages, P, KVH, D]
    v_pages,
    block_table,  # [B, max_pages] int32
    lengths,  # [B] int32 valid local tokens
    page_pos=None,  # [n_pages, P] int32 global positions
    *,
    query_pos=None,  # [B] int32 (required with window)
    window=None,
    softcap=None,
) -> A.Partial:
    """XLA `take`-based oracle for the paged decode kernel (CPU parity)."""
    bt = jnp.asarray(block_table, jnp.int32)
    b, max_pages = bt.shape
    page = k_pages.shape[1]
    if max_pages == 0:
        return A.empty_partial(b, q.shape[1], q.shape[2], q.shape[3])
    s = max_pages * page
    flat = bt.reshape(-1)
    k = jnp.take(k_pages, flat, axis=0).reshape((b, s) + k_pages.shape[2:])
    v = jnp.take(v_pages, flat, axis=0).reshape((b, s) + v_pages.shape[2:])
    j = jnp.arange(s)
    valid = j[None, :] < jnp.asarray(lengths)[:, None]
    if window is not None:
        kp = jnp.take(jnp.asarray(page_pos), flat, axis=0).reshape(b, s)
        valid &= (jnp.asarray(query_pos)[:, None] - kp) < window
    mask = jnp.broadcast_to(valid[:, None, :], (b, q.shape[1], s))
    return A.partial_attention(q, k, v, mask, softcap=softcap)

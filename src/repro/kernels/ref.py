"""Pure-jnp oracles for the Pallas kernels (allclose targets in tests)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import attention as A


def serial_decode_oracle(model, params, prompt, n_decode: int) -> list:
    """Greedy token oracle for engine parity tests/demos: one serial
    prefill over `prompt` followed by ``n_decode`` dense-cache decode steps
    (argmax sampling, KV appended in place).  Returns the ``n_decode + 1``
    emitted token ids — what a real-mode engine must reproduce exactly."""
    import numpy as np

    toks = jnp.asarray(np.asarray(prompt)[None], jnp.int32)
    logits, cache = model.prefill(params, {"tokens": toks})
    nxt = int(np.argmax(np.asarray(logits[0, -1])))
    out = [nxt]
    n_in = len(prompt)
    s_max = n_in + n_decode + 2
    k_pad = jnp.zeros((cache.k.shape[0], 1, s_max) + cache.k.shape[3:],
                      cache.k.dtype).at[:, :, :n_in].set(cache.k)
    v_pad = jnp.zeros_like(k_pad).at[:, :, :n_in].set(cache.v)
    cache = cache._replace(k=k_pad, v=v_pad)
    for _ in range(n_decode):
        logits, cache, kvs = model.decode(
            params, jnp.asarray([nxt], jnp.int32), cache
        )
        pos = int(cache.length[0]) - 1
        cache = cache._replace(
            k=cache.k.at[:, :, pos : pos + 1].set(kvs[0]),
            v=cache.v.at[:, :, pos : pos + 1].set(kvs[1]),
        )
        nxt = int(np.argmax(np.asarray(logits[0])))
        out.append(nxt)
    return out


def striped_flash_attention_ref(
    q, k, v, q_pos, k_pos, *, causal=True, window=None, softcap=None
):
    return A.full_attention(
        q, k, v, q_pos=jnp.asarray(q_pos), k_pos=jnp.asarray(k_pos),
        causal=causal, window=window, softcap=softcap,
    )


def flash_decode_partial_ref(
    q, k, v, lengths, *, k_pos_offset=0, window=None, softcap=None
) -> A.Partial:
    b, s = k.shape[0], k.shape[1]
    pos = k_pos_offset + jnp.arange(s)
    cl = jnp.asarray(lengths)
    valid = pos[None, :] < cl[:, None]
    if window is not None:
        # repo window convention (see striped_attention.py): the query sits at
        # global position `lengths` (its own KV is not in the shard), so
        # qp - kp < window  <=>  kp > lengths - window
        valid &= pos[None, :] > (cl[:, None] - window)
    mask = jnp.broadcast_to(valid[:, None, :], (b, q.shape[1], s))
    return A.partial_attention(q, k, v, mask, softcap=softcap)


def packed_prefill_ref(
    q, k, v, seq_offsets, *, window=None, softcap=None
):
    """Dense segment-mask oracle for packed ragged prefill (tests only:
    O(T^2) score matrix).  Causality/window are evaluated in packed
    coordinates — within a segment the packed order IS the local order."""
    t = q.shape[0]
    ti = jnp.arange(t, dtype=jnp.int32)
    seg = A.packed_segment_ids(seq_offsets, t)
    mask = (seg[:, None] == seg[None, :]) & (ti[:, None] >= ti[None, :])
    if window is not None:
        mask &= (ti[:, None] - ti[None, :]) < window
    out = A.finalize_partial(
        A.partial_attention(q[None], k[None], v[None], mask[None],
                            softcap=softcap)
    )
    return out[0]


def packed_prefill_banded(
    q, k, v, seq_offsets, *, window=None, softcap=None, block_q=128,
    max_seq_len=None,
):
    """Production XLA fallback for packed ragged prefill.

    Scans over q blocks; each block attends a banded K/V window that is
    guaranteed to cover its segments' prefixes (a segment reaches back at
    most ``max_seq_len - 1`` packed positions, less under sliding window),
    with the segment mask killing cross-request pairs inside the band.
    Work is O(T * band) instead of the oracle's O(T^2) — the XLA analogue
    of the Pallas kernel's tile skipping.  ``max_seq_len`` must be a static
    upper bound on the longest segment (None = no bound, full reach).
    """
    t, h, d = q.shape
    blk = min(block_q, t)
    while t % blk:  # defensive: engine buckets t to powers of two
        blk //= 2
    nb = t // blk
    reach = t if max_seq_len is None else min(int(max_seq_len), t)
    if window is not None:
        reach = min(reach, window)
    w = min(-(-max(reach - 1, 0) // blk) + 1, nb)  # band width in blocks
    ti = jnp.arange(t, dtype=jnp.int32)
    seg = A.packed_segment_ids(seq_offsets, t)
    pad = (w - 1) * blk
    kp = jnp.pad(k, ((pad, 0), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((pad, 0), (0, 0), (0, 0)))
    segp = jnp.pad(seg, (pad, 0), constant_values=-1)  # pad rows never match
    tkp = jnp.pad(ti, (pad, 0), constant_values=-1)

    def body(_, i):
        s0 = i * blk  # band [s0, s0 + w*blk) of the padded axis ends at the
        # q block's end: global keys [s0 - pad, (i+1)*blk)
        qb = jax.lax.dynamic_slice_in_dim(q, s0, blk)
        tqb = jax.lax.dynamic_slice_in_dim(ti, s0, blk)
        sqb = jax.lax.dynamic_slice_in_dim(seg, s0, blk)
        kb = jax.lax.dynamic_slice_in_dim(kp, s0, w * blk)
        vb = jax.lax.dynamic_slice_in_dim(vp, s0, w * blk)
        tkb = jax.lax.dynamic_slice_in_dim(tkp, s0, w * blk)
        skb = jax.lax.dynamic_slice_in_dim(segp, s0, w * blk)
        mask = (sqb[:, None] == skb[None, :]) & (tqb[:, None] >= tkb[None, :])
        if window is not None:
            mask &= (tqb[:, None] - tkb[None, :]) < window
        out = A.finalize_partial(
            A.partial_attention(qb[None], kb[None], vb[None], mask[None],
                                softcap=softcap)
        )[0]
        return None, out

    _, outs = jax.lax.scan(body, None, jnp.arange(nb))
    return outs.reshape(t, h, d)


def _ring_chunk_mask(
    tl: int, q_shard, k_shard, n_shards: int, seq_offsets, *, window=None
):
    """[Tl, Tl] mask for one striped ring chunk: shard r's local slot j is
    global packed index ``j * n + r``; segment ids derive from the per-shard
    offsets, causal/window from the global striped positions."""
    j = jnp.arange(tl, dtype=jnp.int32)
    gq = j * n_shards + q_shard
    gk = j * n_shards + k_shard
    off = jnp.asarray(seq_offsets, jnp.int32)
    seg_q = jnp.sum(gq[:, None] >= off[None, 1:], axis=1)
    seg_k = jnp.sum(gk[:, None] >= off[None, 1:], axis=1)
    mask = (seg_q[:, None] == seg_k[None, :]) & (gq[:, None] >= gk[None, :])
    if window is not None:
        mask &= (gq[:, None] - gk[None, :]) < window
    return mask


def packed_prefill_ring_chunk_ref(
    q, k, v, seq_offsets, carry, *, q_shard, k_shard, n_shards,
    window=None, softcap=None,
):
    """Dense oracle for one ring step (tests only: O(Tl^2) scores): fold one
    striped KV chunk into the carried unnormalized (o, m, l) flash state.
    ``seq_offsets`` are the GLOBAL packed offsets; positions are global
    striped (``j * n + shard``).  Finalize with ``o / l`` after the last
    step."""
    tl = q.shape[0]
    mask = _ring_chunk_mask(
        tl, q_shard, k_shard, n_shards, seq_offsets, window=window
    )
    part = A.partial_attention(
        q[None], k[None], v[None], mask[None], softcap=softcap
    )
    o, m, l = A.merge_partial(
        A.Partial(carry[0][None], carry[1][None], carry[2][None]), part
    )
    return o[0], m[0], l[0]


def packed_prefill_ring_chunk_banded(
    q, k, v, q_offsets, k_offsets, carry, *, q_shard, k_shard, n_shards,
    window=None, softcap=None, block_q=128, max_seq_len=None,
):
    """Production XLA fallback for one ring step of the striped packed
    prefill (the chunked analogue of `packed_prefill_banded`).

    Scans over local q blocks; each block attends a banded window of the KV
    chunk guaranteed to cover its segments' global reach — a segment spans at
    most ``max_seq_len`` GLOBAL positions, i.e. ``ceil(max_seq_len / n)``
    local slots of any one shard (less under sliding window) — with the
    per-shard segment mask killing cross-request pairs inside the band.
    ``q_offsets``/``k_offsets`` are the per-shard offsets
    (`striped.shard_offsets`); global positions rebuild as ``j * n + shard``.
    Returns the updated unnormalized (o, m, l) carry."""
    tl, h, d = q.shape
    n = n_shards
    blk = min(block_q, tl)
    while tl % blk:  # defensive: engine buckets the shard length
        blk //= 2
    nb = tl // blk
    reach_g = None if max_seq_len is None else int(max_seq_len)
    if window is not None:
        reach_g = window if reach_g is None else min(reach_g, window)
    # local band reach: global reach divided across the n stripes (+1 slack
    # for shard phase rounding)
    reach_l = tl if reach_g is None else min(-(-reach_g // n) + 1, tl)
    w = min(-(-max(reach_l - 1, 0) // blk) + 1, nb)  # band width in blocks
    j = jnp.arange(tl, dtype=jnp.int32)
    gq = j * n + q_shard
    gk = j * n + k_shard
    qo = jnp.asarray(q_offsets, jnp.int32)
    ko = jnp.asarray(k_offsets, jnp.int32)
    seg_q = jnp.sum(j[:, None] >= qo[None, 1:], axis=1)
    seg_k = jnp.sum(j[:, None] >= ko[None, 1:], axis=1)
    pad = (w - 1) * blk
    kp = jnp.pad(k, ((pad, 0), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((pad, 0), (0, 0), (0, 0)))
    segkp = jnp.pad(seg_k, (pad, 0), constant_values=-1)  # pad never matches
    gkp = jnp.pad(gk, (pad, 0), constant_values=-1)

    def body(_, i):
        s0 = i * blk  # band [s0, s0 + w*blk) of the padded local axis
        qb = jax.lax.dynamic_slice_in_dim(q, s0, blk)
        gqb = jax.lax.dynamic_slice_in_dim(gq, s0, blk)
        sqb = jax.lax.dynamic_slice_in_dim(seg_q, s0, blk)
        kb = jax.lax.dynamic_slice_in_dim(kp, s0, w * blk)
        vb = jax.lax.dynamic_slice_in_dim(vp, s0, w * blk)
        gkb = jax.lax.dynamic_slice_in_dim(gkp, s0, w * blk)
        skb = jax.lax.dynamic_slice_in_dim(segkp, s0, w * blk)
        mask = (sqb[:, None] == skb[None, :]) & (gqb[:, None] >= gkb[None, :])
        if window is not None:
            mask &= (gqb[:, None] - gkb[None, :]) < window
        part = A.partial_attention(
            qb[None], kb[None], vb[None], mask[None], softcap=softcap
        )
        return None, (part.o[0], part.m[0], part.l[0])

    _, (o_b, m_b, l_b) = jax.lax.scan(body, None, jnp.arange(nb))
    part = A.Partial(
        o_b.reshape(tl, h, d), m_b.reshape(tl, h), l_b.reshape(tl, h)
    )
    o, m, l = A.merge_partial(A.Partial(*carry), part)
    return o, m, l


def paged_decode_merge_ref(
    q, k_new, v_new, shards, *, query_pos=None, window=None, softcap=None,
):
    """Dense multi-shard oracle for the distributed decode merge (SPMD or
    per-shard loop): the new token's own KV partial LSE-merged with one
    paged partial per shard, finalized.  ``shards`` is an iterable of
    ``(k_pages, v_pages, block_table, lengths, page_pos)`` tuples — the
    per-instance pool views; merge order matches the executor's (new-token
    partial first, shards in instance order), though the merge is
    order-insensitive up to float rounding."""
    part = A.partial_attention(q, k_new, v_new, None, softcap=softcap)
    for kp, vp, bt, lens, pos in shards:
        p = paged_flash_decode_partial_ref(
            q, kp, vp, bt, lens, pos, query_pos=query_pos, window=window,
            softcap=softcap,
        )
        part = A.merge_partial(part, p)
    return A.finalize_partial(part)


def paged_decode_batch_sharded_ref(
    q, k_new, v_new, shards, *, query_pos=None, window=None, softcap=None,
):
    """Dense oracle for the BATCH-SHARDED multi-master decode boundary
    (`core.esp.paged_decode_attn_sharded`): emulates the collective
    schedule in plain jnp with ``n = len(shards)`` virtual ranks.

    Rank i holds shard i's paged KV and owns batch rows
    ``[i*B/n, (i+1)*B/n)``.  The all_gather of the q-slices reconstitutes
    the full-batch q (identical to ``q`` here), each rank's full-batch
    partial is computed over its local shard, the psum_scatter is a
    weighted sum over ranks followed by slicing each rank's own rows, and
    every rank merges its slice with ITS batch slice of the new-token
    partial.  Concatenating the per-rank slices gives the full [B,1,H,D]
    output — the structural reference the shard_map program must match."""
    n = len(shards)
    b = q.shape[0]
    assert b % n == 0, (b, n)
    b_l = b // n
    parts = [
        paged_flash_decode_partial_ref(
            q, kp, vp, bt, lens, pos, query_pos=query_pos, window=window,
            softcap=softcap,
        )
        for kp, vp, bt, lens, pos in shards
    ]
    m_g = jnp.max(jnp.stack([p.m for p in parts]), axis=0)
    m_safe = jnp.where(jnp.isinf(m_g), 0.0, m_g)
    w = [jnp.where(jnp.isinf(p.m), 0.0, jnp.exp(p.m - m_safe)) for p in parts]
    o_sum = sum(p.o * wi[..., None] for p, wi in zip(parts, w))
    l_sum = sum(p.l * wi for p, wi in zip(parts, w))
    outs = []
    for r in range(n):
        sl = slice(r * b_l, (r + 1) * b_l)
        p_new = A.partial_attention(
            q[sl], k_new[sl], v_new[sl], None, softcap=softcap
        )
        merged = A.merge_partial(
            A.Partial(o_sum[sl], m_g[sl], l_sum[sl]), p_new
        )
        outs.append(A.finalize_partial(merged))
    return jnp.concatenate(outs, axis=0)


def paged_flash_decode_partial_ref(
    q,  # [B, 1, H, D]
    k_pages,  # [n_pages, P, KVH, D]
    v_pages,
    block_table,  # [B, max_pages] int32
    lengths,  # [B] int32 valid local tokens
    page_pos=None,  # [n_pages, P] int32 global positions
    *,
    query_pos=None,  # [B] int32 (required with window)
    window=None,
    softcap=None,
) -> A.Partial:
    """XLA `take`-based oracle for the paged decode kernel (CPU parity)."""
    bt = jnp.asarray(block_table, jnp.int32)
    b, max_pages = bt.shape
    page = k_pages.shape[1]
    if max_pages == 0:
        return A.empty_partial(b, q.shape[1], q.shape[2], q.shape[3])
    s = max_pages * page
    flat = bt.reshape(-1)
    k = jnp.take(k_pages, flat, axis=0).reshape((b, s) + k_pages.shape[2:])
    v = jnp.take(v_pages, flat, axis=0).reshape((b, s) + v_pages.shape[2:])
    j = jnp.arange(s)
    valid = j[None, :] < jnp.asarray(lengths)[:, None]
    if window is not None:
        kp = jnp.take(jnp.asarray(page_pos), flat, axis=0).reshape(b, s)
        valid &= (jnp.asarray(query_pos)[:, None] - kp) < window
    mask = jnp.broadcast_to(valid[:, None, :], (b, q.shape[1], s))
    return A.partial_attention(q, k, v, mask, softcap=softcap)

"""Baseline serving systems on the shared engine substrate (§7 comparison)."""
from repro.baselines.static_tp import StaticTPEngine  # noqa: F401
from repro.baselines.chunked_prefill import ChunkedPrefillEngine  # noqa: F401
from repro.baselines.pd_disagg import PDDisaggEngine  # noqa: F401
from repro.baselines.fixed_groups import FixedGroupsEngine  # noqa: F401

"""Ablation baselines for Fig. 12: LoongServe w/o ESP.

`FixedGroupsEngine` partitions instances into STATIC groups; each group is an
independent continuous-batching server (locality constraint: a request's KV
lives entirely inside one group). Covers:
  * static hybrid parallelism (TP x SP fixed): one group of all instances
    (equivalently use StaticTPEngine);
  * parallelism with replication ((TP=2) x 4): four singleton groups.
Requests are dispatched FCFS to the group with the most free KV slots that
fits them — fragmentation across groups is exactly what Fig. 4 depicts.
"""
from __future__ import annotations

from typing import Dict, List, Sequence

from repro.engine.request import Phase, Request
from repro.engine.server import BaseServingEngine
from repro.kvcache.pool import OutOfSlots


class FixedGroupsEngine(BaseServingEngine):
    def __init__(self, *args, groups: Sequence[Sequence[int]], **kwargs):
        super().__init__(*args, **kwargs)
        self.groups: List[List[int]] = [list(g) for g in groups]
        self.active: Dict[int, List[Request]] = {g: [] for g in range(len(groups))}
        self._running: Dict[int, bool] = {g: False for g in range(len(groups))}

    def _grp(self, gi: int) -> List[int]:
        return [i for i in self.groups[gi] if i not in self.failed]

    def _free_of(self, gi: int) -> int:
        return sum(self.pool.pools[i].free_slots for i in self._grp(gi))

    def _try_schedule(self) -> None:
        self.pending.sort(key=lambda r: r.arrival)
        for gi in range(len(self.groups)):
            self._schedule_group(gi)

    def _schedule_group(self, gi: int) -> None:
        if self._running[gi]:
            return
        grp = self._grp(gi)
        if not grp:
            return
        dop = len(grp)
        admit: List[Request] = []
        free = self._free_of(gi)
        for r in list(self.pending):
            reserve = int(0.2 * r.max_new_tokens)
            if r.max_total_len > self.capacity * dop:
                continue  # cannot ever fit this group; maybe another can
            if r.input_len + reserve <= free:
                admit.append(r)
                free -= r.input_len
                if len(admit) >= 16:
                    break
            else:
                break  # FCFS head-of-line within the group
        if admit:
            for r in admit:
                self.pending.remove(r)
                r.phase = Phase.PREFILL
                if r.prefill_start is None:
                    r.prefill_start = self.clock
                plan = self.pool.plan_placement(
                    r.rid, list(range(r.input_len)), grp
                )
                self.pool.place(plan)
            dur = self.sib.prefill_time(dop, [r.input_len for r in admit], grp)
            end = self.clock + dur
            self._occupy(grp, end)
            self._running[gi] = True
            self.metrics.prefill_iters += 1
            self._push(end, "prefill_done", (gi, admit))
            return
        if self.active[gi]:
            sum_kv = sum(r.seq_len for r in self.active[gi])
            dur = self.sib.decode_time(dop, len(self.active[gi]), sum_kv, grp)
            end = self.clock + dur
            self._occupy(grp, end)
            self._running[gi] = True
            self.metrics.decode_iters += 1
            self._push(end, "decode_done", (gi, list(self.active[gi])))

    def _on_prefill_done(self, payload) -> None:
        gi, batch = payload
        self._running[gi] = False
        for r in batch:
            r.prefill_end = self.clock
            r.phase = Phase.DECODE
            r.generated += 1
            r.output_tokens.append(self._sample_token())
            if r.done:
                self._finish_request(r)
            else:
                self.active[gi].append(r)

    def _on_decode_done(self, payload) -> None:
        gi, batch = payload
        self._running[gi] = False
        grp = self._grp(gi)
        for r in batch:
            if r not in self.active[gi]:
                continue
            pos = r.seq_len - 1
            r.generated += 1
            r.output_tokens.append(self._sample_token())
            placed = False
            for inst in grp:
                try:
                    self.pool.pools[inst].alloc(r.rid, [pos])
                    placed = True
                    break
                except OutOfSlots:
                    continue
            if not placed:
                self.pool.free_request(r.rid)
                r.n_evictions += 1
                r.phase = Phase.PENDING
                r.input_len = r.seq_len
                r.prefill_end = None
                self.active[gi].remove(r)
                self.pending.append(r)
                continue
            if r.done:
                self.active[gi].remove(r)
                self._finish_request(r)

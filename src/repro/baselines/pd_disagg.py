"""Baseline: prefill-decode disaggregation (DistServe-like, §7).

Instances split statically into a prefill group and a decode group. After the
prefill phase the whole KV cache migrates to the decode group — *reactive*
migration, the overhead LoongServe's proactive scale-down eliminates. Each
group only sees half the fleet's memory: long requests that fit the unified
pool OOM here (the paper's LV-Eval rows), reproduced via `rejected`.
"""
from __future__ import annotations

from typing import List

from repro.engine.request import Phase, Request
from repro.engine.server import BaseServingEngine
from repro.kvcache.pool import OutOfSlots


class PDDisaggEngine(BaseServingEngine):
    def __init__(self, *args, prefill_frac: float = 0.5, **kwargs):
        super().__init__(*args, **kwargs)
        split = max(1, int(self.n * prefill_frac))
        self.p_group = list(range(split))
        self.d_group = list(range(split, self.n))
        self.active: List[Request] = []
        self._p_running = False
        self._d_running = False

    def _pg(self):
        return [i for i in self.p_group if i not in self.failed]

    def _dg(self):
        return [i for i in self.d_group if i not in self.failed]

    def _try_schedule(self) -> None:
        self._schedule_prefill()
        self._schedule_decode()

    def _schedule_prefill(self) -> None:
        if self._p_running:
            return
        pg = self._pg()
        if not pg:
            return
        self.pending.sort(key=lambda r: r.arrival)
        admit: List[Request] = []
        free_p = sum(self.pool.pools[i].free_slots for i in pg)
        # decode group must ALSO fit the request post-migration
        free_d = sum(self.pool.pools[i].free_slots for i in self._dg())
        for r in list(self.pending):
            reserve = int(0.2 * r.max_new_tokens)
            if r.input_len > self.capacity * len(pg) or (
                r.input_len + reserve > self.capacity * len(self._dg())
            ):
                # static halves cannot serve it at all -> OOM/reject
                self.pending.remove(r)
                self.metrics.rejected += 1
                continue
            if r.input_len <= free_p and r.input_len + reserve <= free_d:
                admit.append(r)
                free_p -= r.input_len
                free_d -= r.input_len
                if len(admit) >= 16:
                    break
            else:
                break
        if not admit:
            return
        for r in admit:
            self.pending.remove(r)
            r.phase = Phase.PREFILL
            if r.prefill_start is None:
                r.prefill_start = self.clock
            plan = self.pool.plan_placement(r.rid, list(range(r.input_len)), pg)
            self.pool.place(plan)
        dur = self.sib.prefill_time(len(pg), [r.input_len for r in admit], pg)
        end = self.clock + dur
        self._occupy(pg, end)
        self._p_running = True
        self.metrics.prefill_iters += 1
        self._push(end, "prefill_done", admit)

    def _schedule_decode(self) -> None:
        if self._d_running or not self.active:
            return
        dg = self._dg()
        if not dg:
            return
        sum_kv = sum(r.seq_len for r in self.active)
        dur = self.sib.decode_time(len(dg), len(self.active), sum_kv, dg)
        end = self.clock + dur
        self._occupy(dg, end)
        self._d_running = True
        self.metrics.decode_iters += 1
        self._push(end, "decode_done", list(self.active))

    def _on_prefill_done(self, batch: List[Request]) -> None:
        self._p_running = False
        dg = self._dg()
        for r in batch:
            # REACTIVE migration prefill->decode group (the cost ESP avoids)
            moved_tokens = 0
            for src in self._pg():
                toks = len(self.pool.pools[src].tokens_of(r.rid))
                if toks == 0:
                    continue
                try:
                    self.pool.migrate_request(r.rid, src, dg)
                    moved_tokens += toks
                except OutOfSlots:
                    self.pool.free_request(r.rid)
                    r.n_evictions += 1
                    r.phase = Phase.PENDING
                    r.input_len = r.seq_len
                    self.pending.append(r)
                    moved_tokens = -1
                    break
            if moved_tokens < 0:
                continue
            self.metrics.reactive_migration_bytes += (
                moved_tokens * self.pool.pools[0].bytes_per_slot
            )
            t_mig = self.sib.migration_time(moved_tokens)
            r.prefill_end = self.clock + t_mig  # migration delays first token
            r.phase = Phase.DECODE
            r.generated += 1
            r.output_tokens.append(self._sample_token())
            if r.done:
                self._finish_request(r)
            else:
                self.active.append(r)

    def _on_decode_done(self, batch: List[Request]) -> None:
        self._d_running = False
        dg = self._dg()
        for r in batch:
            if r not in self.active:
                continue
            pos = r.seq_len - 1
            r.generated += 1
            r.output_tokens.append(self._sample_token())
            placed = False
            for inst in dg:
                try:
                    self.pool.pools[inst].alloc(r.rid, [pos])
                    placed = True
                    break
                except OutOfSlots:
                    continue
            if not placed:
                self.pool.free_request(r.rid)
                r.n_evictions += 1
                r.phase = Phase.PENDING
                r.input_len = r.seq_len
                r.prefill_end = None
                self.active.remove(r)
                self.pending.append(r)
                continue
            if r.done:
                self.active.remove(r)
                self._finish_request(r)

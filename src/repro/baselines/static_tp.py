"""Baseline: static tensor parallelism with continuous batching (vLLM-like).

All instances form ONE group (TP spans the fleet, as the paper configures
vLLM with TP=8 on 8 GPUs). Iteration-level scheduling: pending prefills run
as a batch on the whole group (blocking decode — the interference the paper
measures); otherwise one decode iteration over all active requests.
"""
from __future__ import annotations

from typing import List

from repro.engine.request import Phase, Request
from repro.engine.server import BaseServingEngine
from repro.kvcache.pool import OutOfSlots


class StaticTPEngine(BaseServingEngine):
    def __init__(self, *args, **kwargs):
        super().__init__(*args, **kwargs)
        self.active: List[Request] = []
        self.group = list(range(self.n))
        self._running = False

    def _group(self) -> List[int]:
        return [i for i in self.group if i not in self.failed]

    def _try_schedule(self) -> None:
        if self._running:
            return
        grp = self._group()
        if not grp or self.busy_until[grp[0]] > self.clock + 1e-12:
            return
        dop = len(grp)
        self.pending.sort(key=lambda r: r.arrival)

        # admit prefills (FCFS, memory-constrained; whole request on the
        # single group -> per-group locality, no cross-group flexibility)
        admit: List[Request] = []
        free = self.pool.total_free
        for r in list(self.pending):
            reserve = int(0.2 * r.max_new_tokens)
            if r.input_len + reserve <= free and len(admit) < 64:
                admit.append(r)
                free -= r.input_len
            else:
                break
        if admit:
            for r in admit:
                self.pending.remove(r)
                r.phase = Phase.PREFILL
                if r.prefill_start is None:
                    r.prefill_start = self.clock
                plan = self.pool.plan_placement(
                    r.rid, list(range(r.input_len)), grp
                )
                self.pool.place(plan)
            dur = self.sib.prefill_time(dop, [r.input_len for r in admit], grp)
            end = self.clock + dur
            self._occupy(grp, end)
            self._running = True
            self.metrics.prefill_iters += 1
            self._push(end, "prefill_done", admit)
            return

        if self.active:
            sum_kv = sum(r.seq_len for r in self.active)
            dur = self.sib.decode_time(dop, len(self.active), sum_kv, grp)
            end = self.clock + dur
            self._occupy(grp, end)
            self._running = True
            self.metrics.decode_iters += 1
            self._push(end, "decode_done", list(self.active))

    def _on_prefill_done(self, batch: List[Request]) -> None:
        self._running = False
        for r in batch:
            r.prefill_end = self.clock
            r.phase = Phase.DECODE
            r.generated += 1
            r.output_tokens.append(self._sample_token())
            if r.done:
                self._finish_request(r)
            else:
                self.active.append(r)

    def _on_decode_done(self, batch: List[Request]) -> None:
        self._running = False
        grp = self._group()
        for r in batch:
            if r not in self.active:
                continue
            pos = r.seq_len - 1
            r.generated += 1
            r.output_tokens.append(self._sample_token())
            placed = False
            for inst in grp:
                try:
                    self.pool.pools[inst].alloc(r.rid, [pos])
                    placed = True
                    break
                except OutOfSlots:
                    continue
            if not placed:
                self.pool.free_request(r.rid)
                r.n_evictions += 1
                r.phase = Phase.PENDING
                r.input_len = r.seq_len
                r.prefill_end = None
                self.active.remove(r)
                self.pending.append(r)
                continue
            if r.done:
                self.active.remove(r)
                self._finish_request(r)

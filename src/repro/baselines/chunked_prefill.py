"""Baseline: chunked prefill / SplitFuse (Sarathi, DeepSpeed-FastGen,
LightLLM w/ SplitFuse — the paper's strongest baseline, §7).

Each iteration fuses the decode batch with a chunk of pending prefill tokens
(budget `chunk_size`). Decode is protected from long prompts, but splitting
the prompt makes the prefill phase less efficient (the KV of earlier chunks
is re-read per chunk) and long-context "P:D" ratios still interfere — the
effects the paper measures in Fig. 10.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

from repro.engine.request import Phase, Request
from repro.engine.server import BaseServingEngine
from repro.kvcache.pool import OutOfSlots


class ChunkedPrefillEngine(BaseServingEngine):
    def __init__(self, *args, chunk_size: int = 2048, **kwargs):
        super().__init__(*args, **kwargs)
        self.chunk_size = chunk_size
        self.active: List[Request] = []  # decoding
        self.prefilling: Dict[int, int] = {}  # rid -> tokens prefilled so far
        self.in_prefill: List[Request] = []
        self._running = False

    def _group(self) -> List[int]:
        return [i for i in range(self.n) if i not in self.failed]

    def _try_schedule(self) -> None:
        if self._running:
            return
        grp = self._group()
        if not grp:
            return
        dop = len(grp)
        self.pending.sort(key=lambda r: r.arrival)

        # admit new requests into the prefilling set while memory allows
        free = self.pool.total_free
        committed = sum(
            r.input_len - self.prefilling[r.rid] for r in self.in_prefill
        )
        for r in list(self.pending):
            reserve = int(0.2 * r.max_new_tokens)
            if r.input_len + reserve + committed <= free:
                self.pending.remove(r)
                r.phase = Phase.PREFILL
                if r.prefill_start is None:
                    r.prefill_start = self.clock
                self.in_prefill.append(r)
                self.prefilling[r.rid] = 0
                committed += r.input_len
            else:
                break

        # build the fused iteration: decode tokens + prefill chunk budget
        chunk_alloc: List[Tuple[Request, int, int]] = []  # (req, start, n)
        budget = self.chunk_size
        for r in self.in_prefill:
            if budget <= 0:
                break
            done_tok = self.prefilling[r.rid]
            take = min(budget, r.input_len - done_tok)
            if take > 0:
                chunk_alloc.append((r, done_tok, take))
                budget -= take
        if not chunk_alloc and not self.active:
            return

        # cost: decode part + chunk part; chunk attention re-reads the KV
        # prefix of earlier chunks (quadratic surcharge via sum over chunks)
        sum_kv = sum(r.seq_len for r in self.active)
        t = self.sib.decode_time(dop, max(len(self.active), 1), sum_kv, grp)
        for r, start, take in chunk_alloc:
            # effective cost of a chunk at offset `start`: linear part for
            # `take` tokens + attention against `start+take` prefix
            fit = self.sib._fit_prefill(dop)
            t += fit.beta * take + fit.gamma * float(take) * float(start + take)
        end = self.clock + t
        self._occupy(grp, end)
        self._running = True
        self.metrics.prefill_iters += 1 if chunk_alloc else 0
        self.metrics.decode_iters += 1 if self.active else 0
        self._push(end, "decode_done", (list(self.active), chunk_alloc))

    def _on_decode_done(self, payload) -> None:
        self._running = False
        active, chunk_alloc = payload
        grp = self._group()
        # prefill chunk progress
        for r, start, take in chunk_alloc:
            try:
                plan = self.pool.plan_placement(
                    r.rid, list(range(start, start + take)), grp
                )
                self.pool.place(plan)
            except OutOfSlots:
                continue
            self.prefilling[r.rid] += take
            if self.prefilling[r.rid] >= r.input_len:
                self.in_prefill.remove(r)
                self.prefilling.pop(r.rid)
                r.prefill_end = self.clock
                r.phase = Phase.DECODE
                r.generated += 1
                r.output_tokens.append(self._sample_token())
                if r.done:
                    self._finish_request(r)
                else:
                    self.active.append(r)
        # decode progress
        for r in active:
            if r not in self.active:
                continue
            pos = r.seq_len - 1
            r.generated += 1
            r.output_tokens.append(self._sample_token())
            placed = False
            for inst in grp:
                try:
                    self.pool.pools[inst].alloc(r.rid, [pos])
                    placed = True
                    break
                except OutOfSlots:
                    continue
            if not placed:
                self.pool.free_request(r.rid)
                r.n_evictions += 1
                r.phase = Phase.PENDING
                r.input_len = r.seq_len
                r.prefill_end = None
                self.active.remove(r)
                self.pending.append(r)
                continue
            if r.done:
                self.active.remove(r)
                self._finish_request(r)

    def _on_prefill_done(self, payload) -> None:  # pragma: no cover
        raise AssertionError("chunked engine fuses phases")

"""LoongServe global manager: the scalable four-step scheduler (§5).

Per iteration:
  1. dispatching   — choose R_p from the pending queue (FCFS with Appendix-A
                     relaxations): GPU-memory constraint incl. future-KV
                     eviction avoidance, compute tipping point, gain/cost
                     preemption analysis (Eq. 1-2);
  2. allocation    — give R_p idle instances first, migrate-to-avoid-preempt,
                     then marginal instances while Gain > Cost (Eq. 3-4);
  3. batching      — DP over (sorted requests x sorted instances) with the
                     monotone-split speedup (Eq. 5-6);
  4. scaling plans — proactive scale-down targets for prefill batches (to the
                     min DoP whose pools fit the KV), decode scale-up on
                     memory pressure or the compute-bound batch threshold,
                     multi-master assignment (§5.4).

The manager is pure decision logic over an `InstanceState` registry + the
distributed pool + SIB — no JAX, so it ports to a multi-controller driver
unchanged (DESIGN.md §2).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Set, Tuple

from repro.configs.base import ModelConfig
from repro.engine.request import Phase, Request
from repro.kvcache.distributed import DistributedKVPool
from repro.manager.batching import BatchSplit, dp_batching, make_prefill_cost
from repro.manager.sib import SIB


@dataclass
class PrefillBatch:
    requests: List[Request]
    instances: List[int]  # ESP group (DoP = len)
    scale_down_to: List[int]  # proactive scale-down target R' ⊆ instances
    placement: Dict[int, Dict[int, List[int]]] = field(default_factory=dict)
    # rid -> {instance: [positions]} proactive retention plan

    @property
    def dop(self) -> int:
        return len(self.instances)


@dataclass
class DecodeBatch:
    requests: List[Request]
    instances: List[int]  # parallel group
    masters: Dict[int, int]  # rid -> master instance (multi-master, §4.2)

    @property
    def dop(self) -> int:
        return len(self.instances)


@dataclass
class UnifiedWork:
    """One link of a unified continuous-batching chain: a prefill batch
    advanced chunk-by-chunk (``prefill_chunk_tokens`` per iteration) with
    in-flight decode groups riding the SAME fused iteration, so decode
    tokens keep flowing while a long prompt prefills (the LoongServe
    unified iteration; executed by `Executor.unified`).

    ``chunks`` maps rid -> (start, length): the slice of the request's
    prompt packed THIS iteration (recomputed by the engine per link from
    each request's ``prefill_pos`` cursor).  A batch request absent from
    ``chunks`` waits this iteration (chunk budget exhausted)."""

    batch: PrefillBatch
    groups: List[DecodeBatch] = field(default_factory=list)
    chunks: Dict[int, Tuple[int, int]] = field(default_factory=dict)

    def alive_instances(self, failed) -> List[int]:
        insts = {i for i in self.batch.instances if i not in failed}
        for g in self.groups:
            insts.update(i for i in g.instances if i not in failed)
        return sorted(insts)


@dataclass
class Migration:
    rid: int
    src: int
    dsts: List[int]
    n_tokens: int


@dataclass
class IterationPlan:
    prefill: List[PrefillBatch] = field(default_factory=list)
    decode: List[DecodeBatch] = field(default_factory=list)
    migrations: List[Migration] = field(default_factory=list)
    preempted: List[Request] = field(default_factory=list)
    log: List[str] = field(default_factory=list)


@dataclass
class ManagerConfig:
    max_num_ooe: int = 8  # Appendix A: bounded out-of-order execution
    enable_ooe: bool = True
    enable_delay_execution: bool = True
    enable_multi_master: bool = True
    max_prefill_batch: int = 64
    future_kv_reserve_frac: float = 0.2  # fraction of max_total_len reserved
    scale_up_batch_threshold: Optional[int] = None  # None -> SIB ridge point
    watermark_frac: float = 0.02  # keep-free watermark per instance
    # unified continuous batching: when set, real-mode prefill batches run
    # as a chain of fused iterations of at most this many prefill tokens
    # each, with in-flight decode groups interleaved into every iteration
    # (decode TBT stays bounded during long-prompt prefill).  None keeps
    # the one-shot packed prefill.
    prefill_chunk_tokens: Optional[int] = None


class GlobalManager:
    def __init__(
        self,
        cfg: ModelConfig,
        sib: SIB,
        pool: DistributedKVPool,
        mcfg: Optional[ManagerConfig] = None,
    ):
        self.cfg = cfg
        self.sib = sib
        self.pool = pool
        self.mcfg = mcfg or ManagerConfig()
        self._ooe_counter = 0
        self._finished_decode_lat: List[float] = []  # AvgLat_d estimator

    # ================================================================ public
    def schedule(
        self,
        pending: List[Request],
        decode_groups: List[DecodeBatch],
        idle_instances: List[int],
        now: float,
        group_busy_until: Optional[Dict[int, float]] = None,
    ) -> IterationPlan:
        plan = IterationPlan()
        group_busy_until = group_busy_until or {}

        # ---- step 1: dispatching --------------------------------------
        rp, preempt_groups = self._dispatch(
            pending, decode_groups, idle_instances, now, group_busy_until, plan
        )

        # ---- step 2: elastic instance allocation ----------------------
        ep = self._allocate(rp, decode_groups, idle_instances, preempt_groups, plan)
        # capacity safety: trim R_p tail until the allocated group can hold
        # every admitted prompt (unified pool semantics apply only inside E_p)
        if rp:
            ep_free = sum(self.pool.pools[i].free_slots for i in ep)
            while rp and sum(r.input_len for r in rp) > ep_free:
                dropped = rp.pop()
                plan.log.append(f"trim r{dropped.rid}: E_p capacity")

        # ---- step 3: batching (DP) ------------------------------------
        batches = self._batch(rp, ep, plan)

        # ---- step 4: elastic scaling plan generation -------------------
        pending_left = any(r not in rp for r in pending)
        self._scaling_plans(
            batches, decode_groups, idle_instances, ep, plan,
            under_load=pending_left,
        )
        return plan

    def note_finished_decode(self, norm_output_latency: float) -> None:
        self._finished_decode_lat.append(norm_output_latency)
        if len(self._finished_decode_lat) > 256:
            self._finished_decode_lat = self._finished_decode_lat[-256:]

    # ========================================================== step 1
    def _avg_lat_d(self) -> float:
        if not self._finished_decode_lat:
            return self.sib.decode_time(1, 1, 1024)
        return sum(self._finished_decode_lat) / len(self._finished_decode_lat)

    def _memory_admissible(self, req: Request, free_now: int,
                           active_future_kv: int) -> bool:
        """§5.1 GPU-memory constraint: room for the prompt now AND a reserve
        against future growth to avoid eviction/recompute."""
        need_now = req.input_len
        reserve = int(self.mcfg.future_kv_reserve_frac * req.max_new_tokens)
        future_reserve = int(
            self.mcfg.future_kv_reserve_frac * active_future_kv
        )
        return need_now + reserve + future_reserve <= free_now

    def _dispatch(
        self, pending, decode_groups, idle_instances, now, busy, plan
    ) -> Tuple[List[Request], List[DecodeBatch]]:
        mcfg = self.mcfg
        rp: List[Request] = []
        preempt_groups: List[DecodeBatch] = []
        free_now = self.pool.total_free
        active_future = sum(
            (r.max_new_tokens - r.generated)
            for g in decode_groups
            for r in g.requests
        )
        idle_dop = max(len(idle_instances), 1)
        tipping = self.sib.prefill_tipping_point(idle_dop)

        skipped_head = False
        for req in list(pending):
            if len(rp) >= mcfg.max_prefill_batch:
                break
            lens = [r.input_len for r in rp] + [req.input_len]
            # compute tipping point (§5.1): stop once the batch saturates
            if rp and self.sib.prefill_time(idle_dop, lens) > tipping:
                break
            if not self._memory_admissible(req, free_now, active_future):
                # Appendix A: bounded out-of-order execution
                if mcfg.enable_ooe and self._ooe_counter < mcfg.max_num_ooe:
                    skipped_head = True
                    continue
                break
            # Appendix A: delay execution — if waiting for busy instances to
            # free up beats running now on what's idle, postpone.
            if (
                mcfg.enable_delay_execution
                and not rp
                and idle_instances
                and decode_groups
            ):
                t_now = self.sib.prefill_time(idle_dop, [req.input_len])
                all_dop = idle_dop + sum(len(g.instances) for g in decode_groups)
                t_all = self.sib.prefill_time(all_dop, [req.input_len])
                wait = self._avg_lat_d()
                if t_all + wait < t_now:
                    plan.log.append(f"delay r{req.rid} for bigger group")
                    break
            rp.append(req)
            free_now -= req.input_len
        self._ooe_counter = self._ooe_counter + 1 if skipped_head else 0

        # gain/cost preemption analysis (Eq. 1-2): consider extending R_p with
        # requests that only fit if a decode group's slots are taken.
        remaining = [r for r in pending if r not in rp]
        if remaining and decode_groups:
            avg_lat_d = self._avg_lat_d()
            for g in decode_groups:
                if not remaining:
                    break
                g_free = sum(
                    self.pool.pools[i].free_slots for i in g.instances
                )
                extra: List[Request] = []
                need = 0
                for r in remaining:
                    if need + r.input_len <= g_free:
                        extra.append(r)
                        need += r.input_len
                if not extra:
                    continue
                ep_lens = [r.input_len for r in rp + extra]
                dop = max(len(idle_instances) + len(g.instances), 1)
                t_joint = self.sib.prefill_time(dop, ep_lens)
                cost = sum(
                    t_joint / max(r.max_new_tokens - r.generated, 1)
                    for r in g.requests
                )  # Eq. 1
                min_exec = min(
                    (r.decode_exec_time for r in g.requests), default=0.0
                )
                gain = sum(
                    max(avg_lat_d - min_exec, 0.0) / max(r.input_len, 1)
                    for r in extra
                )  # Eq. 2
                if gain > cost:
                    rp.extend(extra)
                    remaining = [r for r in remaining if r not in extra]
                    preempt_groups.append(g)
                    plan.log.append(
                        f"preempt group {g.instances} (gain {gain:.3g} > cost {cost:.3g})"
                    )
        return rp, preempt_groups

    # ========================================================== step 2
    def _allocate(
        self, rp, decode_groups, idle_instances, preempt_groups, plan
    ) -> List[int]:
        if not rp:
            return []
        ep: List[int] = list(idle_instances)
        for g in preempt_groups:
            ep.extend(i for i in g.instances if i not in ep)
        need = sum(r.input_len for r in rp)

        def ep_free() -> int:
            return sum(self.pool.pools[i].free_slots for i in ep)

        # preempt instances with the most unused slots; migrate their decode
        # KV away instead of evicting when possible (§5.2)
        # deduped: an instance can transiently sit in two groups (stalled
        # groups under failure churn) — duplicate entries here would emit
        # migrations with duplicate destinations
        decode_insts = list(dict.fromkeys(
            i
            for g in decode_groups
            if g not in preempt_groups
            for i in g.instances
        ))
        candidates = sorted(
            (i for i in decode_insts if i not in ep),
            key=lambda i: -self.pool.pools[i].free_slots,
        )
        while ep_free() < need and candidates:
            inst = candidates.pop(0)
            others = [j for j in decode_insts if j != inst and j not in ep]
            moved_ok = True
            # rid < 0 is foreign occupancy (not engine-owned, e.g. chaos
            # ballast): immovable — plan around it, never migrate it
            movable = [r for r in self.pool.pools[inst].requests() if r >= 0]
            for rid in movable:
                toks = len(self.pool.pools[inst].tokens_of(rid))
                dst_free = sum(self.pool.pools[j].free_slots for j in others)
                if toks > dst_free:
                    moved_ok = False
                    break
            if not moved_ok:
                continue
            for rid in movable:
                toks = len(self.pool.pools[inst].tokens_of(rid))
                plan.migrations.append(Migration(rid, inst, list(others), toks))
            ep.append(inst)
            plan.log.append(f"annex instance {inst} for prefill (KV migrated)")

        # marginal-gain expansion (Eq. 3-4): add e_min while Gain > Cost
        lens = [r.input_len for r in rp]
        while True:
            rest = sorted(
                (i for i in decode_insts if i not in ep),
                key=lambda i: self.pool.pools[i].used,
            )
            if not rest:
                break
            e_min = rest[0]
            d0, d1 = len(ep), len(ep) + 1
            t0 = self.sib.prefill_time(max(d0, 1), lens)
            t1 = self.sib.prefill_time(d1, lens)
            gain = sum((t0 - t1) / max(r.input_len, 1) for r in rp)  # Eq. 3
            v_bytes_tokens = self.pool.pools[e_min].used
            t_mig = self.sib.migration_time(v_bytes_tokens)
            cost = sum(t_mig / max(r.input_len, 1) for r in rp)  # Eq. 4
            if gain <= cost:
                break
            others = [j for j in decode_insts if j != e_min and j not in ep]
            dst_free = sum(self.pool.pools[j].free_slots for j in others)
            if self.pool.pools[e_min].used > dst_free:
                break
            for rid in self.pool.pools[e_min].requests():
                if rid < 0:  # foreign occupancy — immovable
                    continue
                toks = len(self.pool.pools[e_min].tokens_of(rid))
                plan.migrations.append(Migration(rid, e_min, list(others), toks))
            ep.append(e_min)
            plan.log.append(
                f"annex e_min {e_min} (gain {gain:.3g} > cost {cost:.3g})"
            )
        return ep

    # ========================================================== step 3
    def _batch(self, rp, ep, plan) -> List[PrefillBatch]:
        if not rp or not ep:
            return []
        reqs = sorted(rp, key=lambda r: -r.input_len)
        insts = sorted(ep, key=lambda i: self.pool.pools[i].free_slots)
        lens = [r.input_len for r in reqs]
        caps = [self.pool.pools[i].free_slots for i in insts]
        speeds = [self.sib.instance_speed.get(i, 1.0) for i in insts]
        cost = make_prefill_cost(self.sib, lens, speeds)
        total, splits = dp_batching(lens, caps, cost)
        if not splits:
            # fall back: one batch on all instances (capacity permitting)
            plan.log.append("DP infeasible; fallback single batch")
            return [PrefillBatch(reqs, insts, scale_down_to=[])]
        batches = []
        for s in splits:
            batches.append(
                PrefillBatch(
                    requests=reqs[s.req_lo : s.req_hi],
                    instances=insts[s.inst_lo : s.inst_hi],
                    scale_down_to=[],
                )
            )
        plan.log.append(
            f"DP batching: {[(len(b.requests), b.dop) for b in batches]} "
            f"cost {total:.4g}"
        )
        return batches

    # ========================================================== step 4
    def _merge_decode_groups(
        self, groups: List[DecodeBatch], under_load: bool, plan
    ) -> List[DecodeBatch]:
        """Consolidate decode batches when it frees instance-time (shared
        weight read). Multi-master + token-granularity KV make the merge
        zero-copy: requests keep their KV placement, only masters/groups are
        reassigned. Under light load we keep groups separate (latency)."""
        if len(groups) <= 1:
            return list(groups)
        merged: List[DecodeBatch] = []
        for g in sorted(groups, key=lambda g: -len(g.requests)):
            placed = False
            for m in merged:
                union = sorted(set(m.instances) | set(g.instances))
                overlap = bool(set(m.instances) & set(g.instances))
                if not union:
                    continue
                t_m = self.sib.decode_time(
                    len(union), len(m.requests) + len(g.requests),
                    sum(r.seq_len for r in m.requests + g.requests),
                )
                t_a = self.sib.decode_time(
                    max(m.dop, 1), len(m.requests),
                    sum(r.seq_len for r in m.requests),
                )
                t_b = self.sib.decode_time(
                    max(g.dop, 1), len(g.requests),
                    sum(r.seq_len for r in g.requests),
                )
                save = t_a * max(m.dop, 1) + t_b * max(g.dop, 1) - t_m * len(union)
                if overlap or save > 0:
                    m.requests = m.requests + g.requests
                    m.instances = union
                    placed = True
                    plan.log.append(
                        f"merge decode groups -> {len(m.requests)} reqs on {union}"
                    )
                    break
            if not placed:
                merged.append(DecodeBatch(list(g.requests), list(g.instances), dict(g.masters)))
        return merged

    def _scaling_plans(self, batches, decode_groups, idle_instances, ep, plan,
                       under_load: bool = False):
        # prefill: proactive scale-down to the min DoP whose pools fit the
        # batch's KV (incl. reserve) — §5.4 "scale down the DoP to the minimum
        # DoP that the key-value tensors of requests can fit"
        for b in batches:
            need = sum(r.input_len for r in b.requests)
            reserve = int(
                self.mcfg.future_kv_reserve_frac
                * sum(r.max_new_tokens for r in b.requests)
            )
            target: List[int] = []
            acc = 0
            # prefer instances with most free slots for the shrunken group
            for i in sorted(
                b.instances, key=lambda j: -self.pool.pools[j].free_slots
            ):
                target.append(i)
                acc += self.pool.pools[i].free_slots
                if acc >= need + reserve and len(target) >= self.sib.min_best_decode_dop():
                    break
            b.scale_down_to = sorted(target)
            # token-level retention placement for the proactive scale-down
            kept = []
            for r in b.requests:
                try:
                    pl = self.pool.plan_placement(
                        r.rid, list(range(r.input_len)), b.scale_down_to
                    )
                except Exception:  # capacity race: leave it pending
                    plan.log.append(f"defer r{r.rid}: no placement")
                    continue
                b.placement[r.rid] = pl.assignment
                self.pool.place(pl)  # reserve slots now (zero-copy at exec)
                kept.append(r)
            b.requests = kept
            if kept:
                plan.prefill.append(b)

        # decode: scale up on memory pressure or compute-bound batch (§5.4)
        thresh = (
            self.mcfg.scale_up_batch_threshold
            or self.sib.decode_compute_bound_batch(1)
        )
        free_idle = [i for i in idle_instances if i not in ep]
        decode_groups = self._merge_decode_groups(decode_groups, under_load, plan)
        for g in decode_groups:
            new_insts = list(g.instances)
            g_free = sum(self.pool.pools[i].free_slots for i in new_insts)
            growth = len(g.requests)  # one token per request per iteration
            sum_kv = sum(r.seq_len for r in g.requests)
            mem_pressure = g_free < growth * 4
            compute_bound = len(g.requests) > thresh * max(len(new_insts), 1)
            while (mem_pressure or compute_bound) and free_idle:
                add = free_idle.pop(0)
                new_insts.append(add)
                g_free += self.pool.pools[add].free_slots
                mem_pressure = g_free < growth * 4
                compute_bound = len(g.requests) > thresh * len(new_insts)
                plan.log.append(f"scale up decode group -> {new_insts}")
            # opportunistic scale-up under light load (§5: "as long as
            # scaling-up is beneficial ... use more idle GPUs"): multi-master
            # scale-up is migration-free, so the only cost is the per-DoP
            # communication term already inside the SIB decode model.
            while free_idle and new_insts:
                d = len(new_insts)
                t_now = self.sib.decode_time(d, len(g.requests), sum_kv)
                t_up = self.sib.decode_time(d + 1, len(g.requests), sum_kv)
                if t_up < t_now * 0.98:
                    new_insts.append(free_idle.pop(0))
                    plan.log.append(f"opportunistic decode scale-up -> {len(new_insts)}")
                else:
                    break
            if not new_insts and free_idle:  # stalled group revival
                new_insts.append(free_idle.pop(0))
            masters = (
                self._assign_masters(g.requests, new_insts) if new_insts else {}
            )
            plan.decode.append(
                DecodeBatch(list(g.requests), new_insts, masters)
            )

    def _assign_masters(self, requests, instances) -> Dict[int, int]:
        """Multi-master: spread new-KV writes as uniformly as memory allows
        (§5.4 'the number of newly key-value tensors generated by each master
        is set to as uniform as possible')."""
        if not self.mcfg.enable_multi_master or len(instances) == 1:
            inst = max(
                instances, key=lambda i: self.pool.pools[i].free_slots
            )
            return {r.rid: inst for r in requests}
        masters: Dict[int, int] = {}
        load = {i: 0 for i in instances}
        free = {i: self.pool.pools[i].free_slots for i in instances}
        for r in sorted(requests, key=lambda r: -r.seq_len):
            cand = [i for i in instances if free[i] > load[i]]
            if not cand:
                cand = list(instances)
            pick = min(cand, key=lambda i: load[i])
            masters[r.rid] = pick
            load[pick] += 1
        return masters

"""Scaling Information Base (SIB) + analytical iteration-time model (§5.5).

T_p(R) = α_p + β_p · Σ len + γ_p · Σ len²   (Eq. 7)

Coefficients are least-squares fitted per parallelism strategy (keyed by DoP)
from profiling samples. Before any profiles exist the SIB bootstraps from a
hardware napkin model (params FLOPs / chip peak), so the scheduler always has
an estimate; profiled data then overrides it — mirroring the paper's SQLite
profile store + offline fit.

A linear model covers the decode phase (α + β·batch + γ·Σ kv_len), which the
paper treats with the same machinery.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from repro.configs.base import ModelConfig


@dataclass
class PrefillCoeffs:
    alpha: float
    beta: float
    gamma: float

    def predict(self, sum_len: float, sum_len2: float) -> float:
        return self.alpha + self.beta * sum_len + self.gamma * sum_len2


@dataclass
class DecodeCoeffs:
    alpha: float
    beta: float  # per request in batch
    gamma: float  # per cached token

    def predict(self, batch: float, sum_kv: float) -> float:
        return self.alpha + self.beta * batch + self.gamma * sum_kv


@dataclass
class HardwareSpec:
    """TPU v5e defaults (per chip) — see the roofline brief."""

    peak_flops: float = 197e12  # bf16
    hbm_bw: float = 819e9
    ici_bw: float = 50e9  # per link
    chips_per_instance: int = 2  # intra-instance TP (paper: TP=2)
    mfu: float = 0.45  # sustained fraction for the napkin bootstrap
    decode_hbm_eff: float = 0.6


class SIB:
    def __init__(self, cfg: ModelConfig, hw: Optional[HardwareSpec] = None):
        self.cfg = cfg
        self.hw = hw or HardwareSpec()
        # dop -> samples
        self._prefill_samples: Dict[int, List[Tuple[float, float, float]]] = {}
        self._decode_samples: Dict[int, List[Tuple[float, float, float]]] = {}
        self._prefill_fit: Dict[int, PrefillCoeffs] = {}
        self._decode_fit: Dict[int, DecodeCoeffs] = {}
        # per-instance relative speed (1.0 = nominal); stragglers < 1.0
        self.instance_speed: Dict[int, float] = {}
        self._n2 = 2 * self.cfg.param_count(active_only=True)

    # ---------------------------------------------------------------- record
    def record_prefill(self, dop: int, lens: Sequence[int], t: float) -> None:
        s1 = float(sum(lens))
        s2 = float(sum(l * l for l in lens))
        self._prefill_samples.setdefault(dop, []).append((s1, s2, t))
        self._prefill_fit.pop(dop, None)

    def record_decode(self, dop: int, batch: int, sum_kv: int, t: float) -> None:
        self._decode_samples.setdefault(dop, []).append(
            (float(batch), float(sum_kv), t)
        )
        self._decode_fit.pop(dop, None)

    def set_instance_speed(self, instance: int, speed: float) -> None:
        self.instance_speed[instance] = speed

    def group_speed(self, instances: Sequence[int]) -> float:
        """A group is bottlenecked by its slowest member (§2.4)."""
        if not instances:
            return 1.0
        return min(self.instance_speed.get(i, 1.0) for i in instances)

    # ------------------------------------------------------------------- fit
    def _fit_prefill(self, dop: int) -> PrefillCoeffs:
        if dop in self._prefill_fit:
            return self._prefill_fit[dop]
        samples = self._prefill_samples.get(dop, [])
        if len(samples) >= 4:
            a = np.array([[1.0, s1, s2] for s1, s2, _ in samples])
            y = np.array([t for _, _, t in samples])
            coef, *_ = np.linalg.lstsq(a, y, rcond=None)
            fit = PrefillCoeffs(*[float(c) for c in coef])
            # degenerate fits (tiny profile sets) fall back to the napkin
            if fit.beta <= 0 or fit.gamma < 0:
                fit = self._napkin_prefill(dop)
        else:
            fit = self._napkin_prefill(dop)
        self._prefill_fit[dop] = fit
        return fit

    def _napkin_prefill(self, dop: int) -> PrefillCoeffs:
        hw, cfg = self.hw, self.cfg
        rate = dop * hw.chips_per_instance * hw.peak_flops * hw.mfu
        # β: linear FLOPs = 2·N_active per token; γ: attention 2·2·L·H·Dh per
        # token-pair (QK^T + PV), halved for causality.
        beta = self._n2 / rate
        attn_pair = 2 * cfg.n_attention_applications * cfg.n_heads * cfg.head_dim * 2
        gamma = 0.5 * attn_pair / rate
        alpha = 0.003  # dispatch/launch overhead floor (s)
        return PrefillCoeffs(alpha, beta, gamma)

    def _fit_decode(self, dop: int) -> DecodeCoeffs:
        if dop in self._decode_fit:
            return self._decode_fit[dop]
        samples = self._decode_samples.get(dop, [])
        if len(samples) >= 4:
            a = np.array([[1.0, b, kv] for b, kv, _ in samples])
            y = np.array([t for _, _, t in samples])
            coef, *_ = np.linalg.lstsq(a, y, rcond=None)
            fit = DecodeCoeffs(*[float(c) for c in coef])
            if fit.beta < 0 or fit.gamma < 0:
                fit = self._napkin_decode(dop)
        else:
            fit = self._napkin_decode(dop)
        self._decode_fit[dop] = fit
        return fit

    def _napkin_decode(self, dop: int) -> DecodeCoeffs:
        hw, cfg = self.hw, self.cfg
        chips = dop * hw.chips_per_instance
        # decode is HBM-bound: weights once per step + KV stream
        weight_bytes = 2 * self.cfg.param_count(active_only=True)
        alpha = 0.002 + weight_bytes / (chips * hw.hbm_bw * hw.decode_hbm_eff)
        beta = self._n2 / (chips * hw.peak_flops * hw.mfu)
        kv_per_tok = max(cfg.kv_bytes_per_token, 1)
        gamma = kv_per_tok / (chips * hw.hbm_bw * hw.decode_hbm_eff)
        # communication penalty for distributing decode (q broadcast +
        # partial combine), per §2.4's poor decode scaling
        comm = 2e-5 * math.log2(max(dop, 1) + 1)
        return DecodeCoeffs(alpha + comm, beta, gamma)

    # ------------------------------------------------------------- estimates
    def prefill_time(self, dop: int, lens: Sequence[int],
                     instances: Optional[Sequence[int]] = None) -> float:
        fit = self._fit_prefill(dop)
        s1 = float(sum(lens))
        s2 = float(sum(l * l for l in lens))
        t = fit.predict(s1, s2)
        return t / self.group_speed(instances or [])

    def decode_time(self, dop: int, batch: int, sum_kv: int,
                    instances: Optional[Sequence[int]] = None) -> float:
        fit = self._fit_decode(dop)
        t = fit.predict(batch, sum_kv)
        return t / self.group_speed(instances or [])

    def migration_time(self, n_tokens: int, n_links: int = 1) -> float:
        bytes_ = n_tokens * max(self.cfg.kv_bytes_per_token, 1)
        return bytes_ / (self.hw.ici_bw * max(n_links, 1))

    # ------------------------------------------------------ scheduler knobs
    def prefill_tipping_point(self, dop: int) -> float:
        """Upper bound of the memory-bound regime (§5.1): iteration time at
        which a prefill batch saturates compute. Profilable; napkin default
        = time to read weights at HBM speed x compute/memory crossover."""
        hw = self.hw
        chips = dop * hw.chips_per_instance
        weight_bytes = 2 * self.cfg.param_count(active_only=True)
        t_mem = weight_bytes / (chips * hw.hbm_bw)
        # a batch is memory-bound while compute time < weight-read time;
        # sustained-efficiency margin on top.
        return t_mem / hw.mfu

    def decode_compute_bound_batch(self, dop: int) -> int:
        """Batch-size threshold past which decode FFN turns compute-bound
        (§5.4). Ridge point: B* ~ peak_flops/hbm_bw (ops per weight byte)."""
        ridge = self.hw.peak_flops / self.hw.hbm_bw  # ~240 for v5e
        return int(ridge)

    def min_best_decode_dop(self) -> int:
        """§5.4: the minimum best DoP for the decoding phase, used as the
        model-parallel degree at launch. For HBM-bound decode more instances
        only help once KV streaming dominates; 1 is the right floor."""
        return 1

"""DP batching (§5.3): split sorted requests across sorted elastic instances.

f[i][k] = min over j<i, l<k with D(j,i) <= V(l,k) of f[j][l] + T(R(j,i), E(l,k))

Requests are sorted by length descending ("requests with similar sequence
lengths ... batched together"); instances ascending by free KV slots. Uses the
split-point monotonicity of Eq. 6 (quadrangle-inequality structure) to shrink
the (j, l) search windows: near-O((n+m)²) in practice. NOTE: the paper's QI
argument assumes the capacity constraint D(j,i) <= V(l,k) is slack; when it
binds, monotone windows can prune the optimum — `dp_batching` is then a
bounded-suboptimality heuristic (tests pin exactness in the slack regime and
a tight bound under binding capacity).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Callable, List, Optional, Sequence, Tuple

INF = float("inf")


@dataclass
class BatchSplit:
    """Requests [req_lo, req_hi) mapped to instances [inst_lo, inst_hi)."""

    req_lo: int
    req_hi: int
    inst_lo: int
    inst_hi: int

    @property
    def dop(self) -> int:
        return self.inst_hi - self.inst_lo


def dp_batching(
    lens: Sequence[int],  # request lengths, sorted DESC
    capacities: Sequence[int],  # per-instance free KV slots, sorted ASC
    cost: Callable[[int, int, int, int], float],  # cost(j, i, l, k) of batch
    *,
    monotone: bool = True,
    max_dop: Optional[int] = None,
) -> Tuple[float, List[BatchSplit]]:
    """Returns (min total input latency, batch splits). `cost(j,i,l,k)` is the
    summed input latency of requests j..i-1 on instances l..k-1 (paper: sum of
    T over the batch's requests, weighted handled by caller)."""
    n, m = len(lens), len(capacities)
    if n == 0:
        return 0.0, []
    d = [0] * (n + 1)
    for i, ln in enumerate(lens):
        d[i + 1] = d[i] + ln
    vcap = [0] * (m + 1)
    for k, c in enumerate(capacities):
        vcap[k + 1] = vcap[k] + c

    f = [[INF] * (m + 1) for _ in range(n + 1)]
    sj = [[0] * (m + 1) for _ in range(n + 1)]  # split_req
    sl = [[0] * (m + 1) for _ in range(n + 1)]  # split_ins
    for k in range(m + 1):
        f[0][k] = 0.0

    back = 2  # window back-off: recovers most QI violations cheaply
    for i in range(1, n + 1):
        for k in range(1, m + 1):
            j_lo = (
                max(sj[i][k - 1] - back, 0)
                if (monotone and k > 1 and f[i][k - 1] < INF) else 0
            )
            l_lo = (
                max(sl[i - 1][k] - back, 0)
                if (monotone and i > 1 and f[i - 1][k] < INF) else 0
            )
            def search(jl, ll):
                best, bj, bl = INF, 0, 0
                for j in range(jl, i):
                    for l in range(ll, k):
                        if f[j][l] == INF:
                            continue
                        need = d[i] - d[j]
                        have = vcap[k] - vcap[l]
                        if need > have:
                            continue
                        if max_dop is not None and (k - l) > max_dop:
                            continue
                        c = f[j][l] + cost(j, i, l, k)
                        if c < best:
                            best, bj, bl = c, j, l
                return best, bj, bl

            best, bj, bl = search(j_lo, l_lo)
            if best == INF and (j_lo > 0 or l_lo > 0):
                # capacity can make the pruned window infeasible even when a
                # wider split exists — fall back to the exhaustive window
                best, bj, bl = search(0, 0)
            f[i][k] = best
            sj[i][k], sl[i][k] = bj, bl

    best_k, best_val = -1, INF
    for k in range(1, m + 1):
        if f[n][k] < best_val:
            best_val, best_k = f[n][k], k
    if best_k < 0:
        return INF, []

    # backtrack
    splits: List[BatchSplit] = []
    i, k = n, best_k
    while i > 0:
        j, l = sj[i][k], sl[i][k]
        splits.append(BatchSplit(j, i, l, k))
        i, k = j, l
    splits.reverse()
    return best_val, splits


def dp_batching_naive(lens, capacities, cost, *, max_dop=None):
    return dp_batching(lens, capacities, cost, monotone=False, max_dop=max_dop)


def make_prefill_cost(sib, lens: Sequence[int], speeds: Optional[Sequence[float]] = None):
    """Paper objective: per-batch sum over its requests of normalized input
    latency contribution — here Σ_r T(batch)/input_len_r (matches Eq. 3's
    normalization). Instances are interchangeable up to speed; a batch on
    instances l..k-1 runs at the slowest member's speed."""

    def cost(j: int, i: int, l: int, k: int) -> float:
        batch_lens = lens[j:i]
        t = sib.prefill_time(k - l, batch_lens)
        if speeds is not None:
            t = t / min(speeds[l:k])
        return sum(t / max(ln, 1) for ln in batch_lens)

    return cost

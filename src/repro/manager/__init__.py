"""Global manager: four-step scheduler, DP batching, SIB analytical model."""
from repro.manager.sib import SIB, HardwareSpec, PrefillCoeffs, DecodeCoeffs  # noqa: F401
from repro.manager.batching import dp_batching, dp_batching_naive, BatchSplit, make_prefill_cost  # noqa: F401
from repro.manager.scheduler import (  # noqa: F401
    GlobalManager, ManagerConfig, IterationPlan, PrefillBatch, DecodeBatch, Migration,
)

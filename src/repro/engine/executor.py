"""Pluggable compute executors: every real-mode kernel-dispatch body lives
here, behind one seam.

`engine/server.py` owns the control plane — clock, events, scheduling,
request lifecycle, pool *accounting*; an Executor owns the compute plane:
how a scheduled PrefillBatch / DecodeBatch actually turns into model steps,
kernel launches and KV writes.  The engine calls exactly four entry points
(`prefill`, `decode`, plus the `prefill_packed`/`decode_paged` fast paths it
never invokes directly but benchmarks do), so policies and executors evolve
independently:

  * `LocalExecutor` — today's in-process paths, moved verbatim from the
    engine: ONE jitted packed model step per prefill batch (DoP>1 groups
    replay the striped ppermute ring in-process, one ring-chunk launch per
    instance per ring step), batched paged decode with per-instance
    partials, and the per-request serial fallbacks for recurrent/moe
    families.
  * `MeshExecutor` — the SPMD production shape: the SAME packed prefill
    step, but the DoP>1 ring runs as ONE `shard_map` program over a real
    ``("data", "model")`` mesh (`core.esp.ring_packed_prefill_spmd`): each
    elastic instance physically owns its stripe of the packed token axis on
    its own device, KV stripes rotate between devices with `lax.ppermute`,
    and the next stripe's transfer is double-buffered against the current
    chunk's compute.  Each instance's KV-pool device mirror is bound to its
    own data-shard device, so `fill_packed` write-through lands every
    reserved placement column on the device that owns it — ESP scale-down
    stays zero-migration *physically*, not just in the bookkeeping.

Exactness is anchored to the dense oracle in `kernels/ref.py`: both
executors produce bit-identical token sequences to the serial per-request
path (tests/test_ring_prefill.py, tests/mesh_exec_cases.py).
"""
from __future__ import annotations

from typing import Any, Dict, List, Tuple

import numpy as np


class LocalExecutor:
    """In-process executor: one device, ring replayed as a chunk schedule."""

    def __init__(self, engine):
        self.eng = engine
        # batched paged decode: the multi-master paged attention impl is
        # swapped in only around a batched decode step (the model object is
        # caller-owned and may be shared between engines).  Pure-attention
        # families only: hybrids/ssm keep the serial per-request path, and
        # moe stays serial because expert-capacity dropping is batch-size
        # dependent (batching would change generated tokens).
        self._paged_impl = None
        # packed ragged prefill: one jitted model step per bucketed
        # (total_tokens, batch, max_len, dop) shape — O(log max_tokens)
        # programs per DoP instead of one per distinct prompt length.  DoP>1
        # ESP groups run the SAME packed step with the token axis striped
        # across the group and attention ring-fused — no serial fallback for
        # scaled-up groups.  Same family gating as the paged decode path.
        self._packed_prefill_impl = None
        self._prefill_programs: Dict[Tuple, Any] = {}
        if engine.cfg.family in ("dense", "vlm"):
            from repro.core.paged_decode import PagedDecodeAttnImpl
            from repro.core.paged_prefill import PackedPrefillAttnImpl
            from repro.models.transformer import DefaultAttnImpl

            if type(getattr(engine.model, "attn_impl", None)) is DefaultAttnImpl:
                self._paged_impl = PagedDecodeAttnImpl()
                self._packed_prefill_impl = PackedPrefillAttnImpl()

    # ------------------------------------------------------------- buckets
    @staticmethod
    def _bucket(n: int, lo: int = 16) -> int:
        """Power-of-two padding bucket: O(log max) compiled shapes (shared
        formula with the pool's scatter-index bucketing)."""
        from repro.kvcache.pool import _pad_bucket

        return max(lo, _pad_bucket(n))

    @classmethod
    def _token_bucket(cls, n: int, lo: int = 16) -> int:
        """Packed-token-axis bucket: powers of two plus their 3/4 points
        (16, 24, 32, 48, 64, ...).  Still O(log max_tokens) compiled shapes
        — 2x the constant — but worst-case padding waste drops from ~2x to
        ~4/3 on the axis every attention launch scans."""
        b = cls._bucket(n, lo)
        mid = (b * 3) // 4
        return mid if (n <= mid and mid >= lo) else b

    # ------------------------------------------------------------- prefill
    def prefill(self, batch) -> None:
        """Dispatch one prefill batch: packed fast path when armed and every
        prompt is materialized, per-request serial otherwise.

        Fast-path guard: every instance holding a request's reserved
        placement must still be alive — scattering would silently skip the
        dead shard and leave partial KV on EITHER path, so such requests
        are pruned and requeued for recompute (normally _on_prefill_done
        already did this; the re-check covers direct callers) while the
        rest of the batch keeps packed speed."""
        eng = self.eng
        lost = [r for r in batch.requests if eng._placement_lost(batch, r)]
        if lost:
            batch.requests = [r for r in batch.requests if r not in lost]
            batch.instances = [
                i for i in batch.instances if i not in eng.failed
            ]
            for r in lost:
                eng.pool.free_request(r.rid)
                eng._requeue_for_recompute(r)
                if r not in eng.pending:
                    eng.pending.append(r)
            if not batch.requests:
                return
        if self._packed_prefill_impl is not None and all(
            r.prompt is not None and len(r.prompt) == r.input_len
            for r in batch.requests
        ):
            return self.prefill_packed(batch)
        return self.prefill_serial(batch)

    def _arm_packed_step(self, impl, offsets, max_len_b: int, dop: int):
        """Arm the packed attention impl for one jitted step (the mesh
        executor overrides this to hand the impl its shard_map mesh)."""
        impl.begin_step(offsets, max_len_b, dop=dop)

    def _program_key(self, tb: int, bb: int, max_len_b: int, dop: int):
        return (tb, bb, max_len_b, dop)

    def _packed_prefill_step(self, tb: int, bb: int, max_len_b: int, dop: int):
        """Jitted packed prefill program for one bucket tuple; cached so
        the compile count stays O(log max_tokens) per DoP (the mesh executor
        additionally keys by mesh shape)."""
        key = self._program_key(tb, bb, max_len_b, dop)
        fn = self._prefill_programs.get(key)
        if fn is None:
            import jax

            model, impl = self.eng.model, self._packed_prefill_impl
            arm = self._arm_packed_step

            def step(params, tokens, positions, offsets, last_idx):
                arm(impl, offsets, max_len_b, dop)
                try:
                    return model.prefill_packed(
                        params, {"tokens": tokens[None]}, positions, last_idx
                    )
                finally:
                    impl.end_step()

            fn = self._prefill_programs[key] = jax.jit(step)
        return fn

    def prefill_packed(self, batch) -> None:
        """One packed model step for the WHOLE prefill batch: prompts are
        concatenated on a single (bucketed) token axis, attention is
        segment-masked by one ragged kernel launch per layer (DoP>1 groups:
        one ring-chunk launch per instance per ring step), first tokens are
        sampled from the packed logits, and the per-layer KV output is
        scattered straight into paged device storage at the slots the
        scheduler reserved (`pool.fill_packed` write-through — the decode
        mirror never re-uploads prefill KV)."""
        import jax.numpy as jnp

        eng = self.eng
        reqs = batch.requests
        lens = [len(r.prompt) for r in reqs]
        total = sum(lens)
        # ring degree = the (alive) ESP group driving this batch; the token
        # bucket is a bucketed SHARD length x dop so the striped shards stay
        # block-aligned (dop=1 degenerates to plain token bucketing)
        dop = max(len([i for i in batch.instances if i not in eng.failed]), 1)
        tb = self._token_bucket(-(-total // dop)) * dop
        bb = self._bucket(len(reqs), lo=1)
        max_len_b = self._bucket(max(lens))
        tokens = np.zeros(tb, np.int32)
        positions = np.zeros(tb, np.int32)
        offsets = np.full(bb + 1, total, np.int32)
        offsets[0] = 0
        last_idx = np.zeros(bb, np.int32)
        c = 0
        for b, r in enumerate(reqs):
            n = lens[b]
            tokens[c : c + n] = np.asarray(r.prompt, np.int32)
            positions[c : c + n] = np.arange(n)
            c += n
            offsets[b + 1] = c
            last_idx[b] = c - 1
        fn = self._packed_prefill_step(tb, bb, max_len_b, dop)
        prev_impl = eng.model.attn_impl
        eng.model.attn_impl = self._packed_prefill_impl
        try:
            logits, (k_packed, v_packed) = fn(
                eng.params, jnp.asarray(tokens), jnp.asarray(positions),
                jnp.asarray(offsets), jnp.asarray(last_idx),
            )
        finally:
            eng.model.attn_impl = prev_impl
        logits = np.asarray(logits)
        for b, r in enumerate(reqs):
            r.output_tokens.append(eng._sample_token(logits[b]))
        if not eng.pool.pools[0].store_values:
            return
        # direct-to-pool paged KV writes: per instance, gather the packed
        # columns this instance retains (striped placement from
        # batch.placement — ESP scale-down stays zero-migration) and
        # write-through into its mirror at the reserved block-table slots
        # (per-data-shard mirrors under the mesh executor: the columns land
        # on the instance's OWN device)
        starts = np.concatenate([[0], np.cumsum(lens)])
        per_inst: Dict[int, Tuple[List[np.ndarray], List[np.ndarray]]] = {}
        for b, r in enumerate(reqs):
            for inst, pos_list in batch.placement.get(r.rid, {}).items():
                if not pos_list or inst in eng.failed:
                    continue
                p = np.asarray(pos_list, np.int64)
                cols, slots = per_inst.setdefault(inst, ([], []))
                cols.append(starts[b] + p)
                slots.append(eng.pool.pools[inst].slots_for(r.rid, p))
        for inst, (cols, slots) in per_inst.items():
            cidx = jnp.asarray(np.concatenate(cols))
            eng.pool.pools[inst].fill_packed(
                np.concatenate(slots),
                jnp.take(k_packed, cidx, axis=1),
                jnp.take(v_packed, cidx, axis=1),
            )

    def prefill_serial(self, batch) -> None:
        """Per-request fallback (recurrent/hybrid state, moe capacity)."""
        import jax.numpy as jnp

        from repro.kernels import ops

        eng = self.eng
        for r in batch.requests:
            # dispatch-counted so tests/benches can assert the packed paths
            # (incl. DoP>1 ring fusion) never fall back to serial prefill
            ops.dispatch_counts["prefill_serial_model"] += 1
            toks = jnp.asarray(np.asarray(r.prompt, np.int32)[None])
            logits, cache = eng.model.prefill(eng.params, {"tokens": toks})
            r.output_tokens.append(
                eng._sample_token(np.asarray(logits[0, -1]))
            )
            if cache.k is not None:
                k = np.asarray(cache.k[:, 0], np.float32)  # [L, T, KVH, D]
                v = np.asarray(cache.v[:, 0], np.float32)
                assign = batch.placement[r.rid]
                for inst, positions in assign.items():
                    if positions and inst not in eng.failed:
                        eng.pool.pools[inst].fill(
                            r.rid, positions, k[:, positions], v[:, positions]
                        )
            if cache.ssm is not None:
                eng._real_cache[r.rid] = cache.ssm

    # -------------------------------------------------------------- decode
    def decode(self, g) -> None:
        if self._paged_impl is not None and self.eng.pool.pools[0].store_values:
            return self.decode_paged(g)
        return self.decode_serial(g)

    def decode_paged(self, g) -> None:
        """Gather-free batched decode: ONE model step for the whole group;
        per layer, one paged-kernel launch per instance over the pool storage
        in place (block tables), partials LSE-merged multi-master style."""
        import jax.numpy as jnp

        from repro.core.paged_decode import PagedShard
        from repro.models.transformer import Cache

        eng = self.eng
        rids = [r.rid for r in g.requests]
        n_cached = np.array([r.seq_len - 1 for r in g.requests], np.int32)
        shards, covered = [], np.zeros(len(rids), np.int64)
        for pool in eng.pool.pools:
            if pool.instance_id in eng.failed:
                continue
            table, lengths = pool.block_table(rids)
            if not lengths.any():
                continue
            covered += lengths
            # pool-owned incrementally-synced mirror: steady-state decode
            # uploads one slot per request; packed-prefill slots upload 0
            kdev, vdev, posdev = pool.device_kv()
            paged_shape = (pool.n_attn, pool.n_pages, pool.page_size) + kdev.shape[2:]
            shards.append(PagedShard(
                # block tables ride with the mirror's device so the whole
                # per-shard partial computes where the stripe lives
                k_pages=kdev.reshape(paged_shape),
                v_pages=vdev.reshape(paged_shape),
                table=pool._dev_put(table),
                lengths=pool._dev_put(lengths),
                # per-slot positions are only consumed by window masking
                pos=(posdev.reshape(pool.n_pages, pool.page_size)
                     if eng.cfg.sliding_window else None),
            ))
        # cache holds tokens 0..seq_len-2; the processed token's KV is
        # produced by this step and appended at the master afterwards
        assert (covered == n_cached).all(), (covered, n_cached)
        toks = jnp.asarray([r.output_tokens[-1] for r in g.requests], jnp.int32)
        cache = Cache(length=jnp.asarray(n_cached))
        prev_impl = eng.model.attn_impl
        eng.model.attn_impl = self._paged_impl
        self._paged_impl.begin_step(shards)
        try:
            logits, _, kvs = eng.model.decode(eng.params, toks, cache)
        finally:
            self._paged_impl.end_step()
            eng.model.attn_impl = prev_impl
        logits = np.asarray(logits)
        for b, r in enumerate(g.requests):
            r.output_tokens.append(eng._sample_token(logits[b]))
            if kvs is not None:
                # stash; _on_decode_done fills it once the slot is allocated
                eng._pending_kv[r.rid] = (
                    np.asarray(kvs[0][:, b], np.float32),  # [L, 1, KVH, D]
                    np.asarray(kvs[1][:, b], np.float32),
                )

    def decode_serial(self, g) -> None:
        """Per-request fallback (recurrent/hybrid state or custom impls)."""
        import jax.numpy as jnp

        from repro.models.transformer import Cache

        eng = self.eng
        for r in g.requests:
            positions, k, v = eng.pool.gather_request(r.rid)
            # cache holds tokens 0..seq_len-2; the processed token's KV is
            # produced by this step and appended at the master afterwards
            n_cached = r.seq_len - 1
            if k is not None:
                assert len(positions) == n_cached, (len(positions), n_cached)
            cache = Cache(
                k=jnp.asarray(k[:, None].astype(eng.model.dtype)) if k is not None else None,
                v=jnp.asarray(v[:, None].astype(eng.model.dtype)) if v is not None else None,
                length=jnp.asarray([n_cached], jnp.int32),
                ssm=eng._real_cache.get(r.rid),
            )
            last_tok = r.output_tokens[-1]
            logits, new_cache, kvs = eng.model.decode(
                eng.params, jnp.asarray([last_tok], jnp.int32), cache
            )
            r.output_tokens.append(eng._sample_token(np.asarray(logits[0])))
            if new_cache.ssm is not None:
                eng._real_cache[r.rid] = new_cache.ssm
            if kvs is not None:
                # stash; _on_decode_done fills it once the slot is allocated
                eng._pending_kv[r.rid] = (
                    np.asarray(kvs[0][:, 0], np.float32),  # [L, 1, KVH, D]
                    np.asarray(kvs[1][:, 0], np.float32),
                )


class MeshExecutor(LocalExecutor):
    """SPMD executor: DoP>1 packed ring prefill as a real shard_map program.

    Construction binds each engine instance ``i`` to data-mesh coordinate
    ``i`` of a ``("data", "model")`` mesh (`launch.mesh`): the instance's
    KV-pool device mirror is pinned to ``mesh.devices[i, 0]`` so both the
    ring pass's `fill_packed` write-through and the paged decode partials
    run on the device that owns the stripe.  A prefill batch over a subset
    of instances runs on the sub-mesh of exactly those devices (cached per
    instance tuple), so elastic DoP groups map to disjoint device groups of
    one physical mesh, like the paper's ESP groups on one GPU cluster.

    Decode reuses the Local paths: the per-instance paged partials already
    execute on each instance's own device (the pool mirrors are bound
    there) and the LSE-merge pulls only the tiny (o, m, l) partials to the
    master — wiring that merge through a decode-side shard_map is the
    ROADMAP's "overlap decode combine" item, now tractable behind this
    seam.

    ``double_buffer=False`` degrades the ring to the sequential baseline
    (transfer strictly after compute) — the benchmark's comparison arm.
    """

    def __init__(self, engine, mesh=None, *, double_buffer: bool = True):
        super().__init__(engine)
        if mesh is None:
            import jax

            from repro.launch.mesh import make_test_mesh

            n_dev = len(jax.devices())
            data = min(len(engine.pool.pools), n_dev)
            mesh = make_test_mesh(data=data, model=max(n_dev // data, 1))
        assert "data" in mesh.axis_names, mesh.axis_names
        self.mesh = mesh
        self.double_buffer = double_buffer
        self._group_meshes: Dict[Tuple[int, ...], Any] = {}
        self._bind_pool_devices()

    def _bind_pool_devices(self) -> None:
        """Pin instance i's KV mirror to data-shard device i (mod data)."""
        devs = self._data_devices()
        for i, pool in enumerate(self.eng.pool.pools):
            pool.bind_device(devs[i % len(devs)])

    def _data_devices(self):
        """One device per data coordinate (model coordinate 0)."""
        import numpy as np_

        devs = np_.asarray(self.mesh.devices)
        data_ax = list(self.mesh.axis_names).index("data")
        # move the data axis first, take coordinate 0 of every other axis
        devs = np_.moveaxis(devs, data_ax, 0)
        return [devs[i].flat[0] for i in range(devs.shape[0])]

    def _group_mesh(self, instances):
        """Sub-mesh ("data", "model") over exactly the group's devices.
        Returns None (-> in-process replay) when the group cannot get one
        distinct data-shard device per instance (more engine instances than
        data coordinates and the group aliases)."""
        import numpy as np_
        from jax.sharding import Mesh

        key = tuple(sorted(instances))
        if key in self._group_meshes:
            return self._group_meshes[key]
        devs = np_.asarray(self.mesh.devices)
        data_ax = list(self.mesh.axis_names).index("data")
        devs = np_.moveaxis(devs, data_ax, 0)
        n_data = devs.shape[0]
        coords = [i % n_data for i in key]
        if len(set(coords)) < len(coords):
            m = None  # aliased devices: no physical ring for this group
        else:
            rows = np_.stack(
                [devs[c].reshape(-1) for c in coords]
            )  # [dop, model*...]
            m = Mesh(rows, ("data", "model"))
        self._group_meshes[key] = m
        return m

    # prefill arming: the SAME packed step, ring under shard_map ----------
    def prefill_packed(self, batch) -> None:
        alive = tuple(
            i for i in batch.instances if i not in self.eng.failed
        )
        self._step_mesh = self._group_mesh(alive) if len(alive) > 1 else None
        try:
            return super().prefill_packed(batch)
        finally:
            self._step_mesh = None

    def _program_key(self, tb, bb, max_len_b, dop):
        # one compiled program per (bucket tuple, dop, mesh): the concrete
        # mesh (hashable) keys the cache because the shard_map bakes the
        # device group in — two DoP groups of the same shape on different
        # devices need separate programs
        return (tb, bb, max_len_b, dop, getattr(self, "_step_mesh", None))

    def _arm_packed_step(self, impl, offsets, max_len_b, dop):
        impl.begin_step(
            offsets, max_len_b, dop=dop,
            mesh=getattr(self, "_step_mesh", None),
            double_buffer=self.double_buffer,
        )

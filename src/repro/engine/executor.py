"""Pluggable compute executors: every real-mode kernel-dispatch body lives
here, behind one seam.

`engine/server.py` owns the control plane — clock, events, scheduling,
request lifecycle, pool *accounting*; an Executor owns the compute plane:
how a scheduled PrefillBatch / DecodeBatch actually turns into model steps,
kernel launches and KV writes.  The engine calls exactly four entry points
(`prefill`, `decode`, plus the `prefill_packed`/`decode_paged` fast paths it
never invokes directly but benchmarks do), so policies and executors evolve
independently:

  * `LocalExecutor` — today's in-process paths, moved verbatim from the
    engine: ONE jitted packed model step per prefill batch (DoP>1 groups
    replay the striped ppermute ring in-process, one ring-chunk launch per
    instance per ring step), batched paged decode with per-instance
    partials, and the per-request serial fallbacks for recurrent/moe
    families.
  * `MeshExecutor` — the SPMD production shape: the SAME packed prefill
    step, but the DoP>1 ring runs as ONE `shard_map` program over a real
    ``("data", "model")`` mesh (`core.esp.ring_packed_prefill_spmd`): each
    elastic instance physically owns its stripe of the packed token axis on
    its own device, KV stripes rotate between devices with `lax.ppermute`,
    and the next stripe's transfer is double-buffered against the current
    chunk's compute.  Each instance's KV-pool device mirror is bound to its
    own data-shard device, so `fill_packed` write-through lands every
    reserved placement column on the device that owns it — ESP scale-down
    stays zero-migration *physically*, not just in the bookkeeping.

Exactness is anchored to the dense oracle in `kernels/ref.py`: both
executors produce bit-identical token sequences to the serial per-request
path (tests/test_ring_prefill.py, tests/mesh_exec_cases.py).
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Any, Dict, List, NamedTuple, Tuple

import numpy as np


class _USeg(NamedTuple):
    """One segment of a unified iteration's packed token axis."""

    r: Any  # the Request
    decode: bool  # decode row (ln == 1) vs prefill chunk
    start: int  # first global position this iteration
    ln: int  # token count this iteration
    limit: int  # filled-prefix length: positions < limit are in the pool
    final: bool  # sample a token from this segment's last row


def _token_span(r, start: int, ln: int) -> np.ndarray:
    """Token ids at positions [start, start+ln): prompt ids below
    `input_len`, generated tokens above (token at position p >= input_len
    is output_tokens[p - input_len] — what a decode-resume recovery hole
    re-feeds when the lost stripe covers generated positions)."""
    end = start + ln
    out = list(r.prompt[start:min(end, r.input_len)])
    if end > r.input_len:
        lo = max(start, r.input_len) - r.input_len
        out += list(r.output_tokens[lo:end - r.input_len])
    return np.asarray(out, np.int32)


class LocalExecutor:
    """In-process executor: one device, ring replayed as a chunk schedule."""

    def __init__(self, engine):
        self.eng = engine
        # batched paged decode: the multi-master paged attention impl is
        # swapped in only around a batched decode step (the model object is
        # caller-owned and may be shared between engines).  Pure-attention
        # families only: hybrids/ssm keep the serial per-request path, and
        # moe stays serial because expert-capacity dropping is batch-size
        # dependent (batching would change generated tokens).
        self._paged_impl = None
        # packed ragged prefill: one jitted model step per bucketed
        # (total_tokens, batch, max_len, dop) shape — O(log max_tokens)
        # programs per DoP instead of one per distinct prompt length.  DoP>1
        # ESP groups run the SAME packed step with the token axis striped
        # across the group and attention ring-fused — no serial fallback for
        # scaled-up groups.  Same family gating as the paged decode path.
        self._packed_prefill_impl = None
        self._unified_impl = None
        # ONE iteration-program cache for every compiled variant the
        # executor dispatches — prefill, decode and unified steps share it,
        # keyed by (kind, bucket tuple..., mesh) with LRU eviction so a
        # long-lived engine cycling many bucket/mesh shapes cannot grow the
        # compiled-program set without bound.
        self._programs: "OrderedDict[Tuple, Any]" = OrderedDict()
        if engine.cfg.family in ("dense", "vlm"):
            from repro.core.paged_decode import PagedDecodeAttnImpl
            from repro.core.paged_prefill import PackedPrefillAttnImpl
            from repro.core.unified import UnifiedAttnImpl
            from repro.models.transformer import DefaultAttnImpl

            if type(getattr(engine.model, "attn_impl", None)) is DefaultAttnImpl:
                self._paged_impl = PagedDecodeAttnImpl()
                self._packed_prefill_impl = PackedPrefillAttnImpl()
                self._unified_impl = UnifiedAttnImpl()

    # --------------------------------------------------- program LRU cache
    _program_cache_cap = 64

    def _program_get(self, key):
        fn = self._programs.get(key)
        if fn is not None:
            self._programs.move_to_end(key)
        return fn

    def _program_put(self, key, fn):
        self._programs[key] = fn
        self._programs.move_to_end(key)
        while len(self._programs) > self._program_cache_cap:
            self._programs.popitem(last=False)
        return fn

    @property
    def _prefill_programs(self) -> Dict[Tuple, Any]:
        """Cached packed-prefill programs, keyed without the kind prefix
        (compat view over the merged cache for tests/benchmarks)."""
        return {k[1:]: v for k, v in self._programs.items() if k[0] == "prefill"}

    @property
    def _decode_programs(self) -> Dict[Tuple, Any]:
        return {k[1:]: v for k, v in self._programs.items() if k[0] == "decode"}

    def on_instance_failed(self, inst: int) -> None:
        """Failure notification from the engine. The in-process executor
        holds no per-instance compiled state (programs are keyed by bucket
        shape only), so there is nothing to purge; the mesh executor
        overrides this to drop sub-meshes containing the dead rank."""

    # ------------------------------------------------------------ NaN guard
    def _guard_logits(self, r, row):
        """Value guard on one request's logits row: a NaN/inf row quarantines
        ONLY that request (`engine._quarantine` — the completion handler
        requeues it for recompute) instead of finishing it with a garbage
        argmax or poisoning the batch.  Chaos injection (`_logit_poison`)
        overwrites the row BEFORE the finite check, so the guard is
        exercised by value exactly as a real kernel fault would present.
        Returns the row, or None when the request was quarantined (caller
        skips its token emission and KV stash)."""
        eng = self.eng
        if r.rid in eng._logit_poison:
            eng._logit_poison.discard(r.rid)
            row = np.full_like(row, np.nan)
        if not np.isfinite(row).all():
            eng._quarantine.add(r.rid)
            return None
        return row

    # ------------------------------------------------------------- buckets
    @staticmethod
    def _bucket(n: int, lo: int = 16) -> int:
        """Power-of-two padding bucket: O(log max) compiled shapes (shared
        formula with the pool's scatter-index bucketing)."""
        from repro.kvcache.pool import _pad_bucket

        return max(lo, _pad_bucket(n))

    @classmethod
    def _token_bucket(cls, n: int, lo: int = 16) -> int:
        """Packed-token-axis bucket: powers of two plus their 3/4 points
        (16, 24, 32, 48, 64, ...).  Still O(log max_tokens) compiled shapes
        — 2x the constant — but worst-case padding waste drops from ~2x to
        ~4/3 on the axis every attention launch scans."""
        b = cls._bucket(n, lo)
        mid = (b * 3) // 4
        return mid if (n <= mid and mid >= lo) else b

    # ------------------------------------------------------------- prefill
    def prefill(self, batch) -> None:
        """Dispatch one prefill batch: packed fast path when armed and every
        prompt is materialized, per-request serial otherwise.

        Fast-path guard: every instance holding a request's reserved
        placement must still be alive — scattering would silently skip the
        dead shard and leave partial KV on EITHER path, so such requests
        are pruned and requeued for recompute (normally _on_prefill_done
        already did this; the re-check covers direct callers) while the
        rest of the batch keeps packed speed."""
        eng = self.eng
        lost = [r for r in batch.requests if eng._placement_lost(batch, r)]
        if lost:
            batch.requests = [r for r in batch.requests if r not in lost]
            batch.instances = [
                i for i in batch.instances if i not in eng.failed
            ]
            for r in lost:
                eng.pool.free_request(r.rid)
                eng._requeue_for_recompute(r)
                if r not in eng.pending:
                    eng.pending.append(r)
            if not batch.requests:
                return
        if self._packed_prefill_impl is not None and all(
            r.prompt is not None and len(r.prompt) == r.input_len
            for r in batch.requests
        ):
            return self.prefill_packed(batch)
        return self.prefill_serial(batch)

    def _arm_packed_step(self, impl, offsets, max_len_b: int, dop: int):
        """Arm the packed attention impl for one jitted step (the mesh
        executor overrides this to hand the impl its shard_map mesh)."""
        impl.begin_step(offsets, max_len_b, dop=dop)

    def _program_key(self, tb: int, bb: int, max_len_b: int, dop: int):
        return (tb, bb, max_len_b, dop)

    def _packed_prefill_step(self, tb: int, bb: int, max_len_b: int, dop: int):
        """Jitted packed prefill program for one bucket tuple; cached so
        the compile count stays O(log max_tokens) per DoP (the mesh executor
        additionally keys by mesh shape)."""
        key = ("prefill",) + self._program_key(tb, bb, max_len_b, dop)
        fn = self._program_get(key)
        if fn is None:
            import jax

            model, impl = self.eng.model, self._packed_prefill_impl
            arm = self._arm_packed_step

            def step(params, tokens, positions, offsets, last_idx):
                arm(impl, offsets, max_len_b, dop)
                try:
                    return model.prefill_packed(
                        params, {"tokens": tokens[None]}, positions, last_idx
                    )
                finally:
                    impl.end_step()

            fn = self._program_put(key, jax.jit(step))
        return fn

    def prefill_packed(self, batch) -> None:
        """One packed model step for the WHOLE prefill batch: prompts are
        concatenated on a single (bucketed) token axis, attention is
        segment-masked by one ragged kernel launch per layer (DoP>1 groups:
        one ring-chunk launch per instance per ring step), first tokens are
        sampled from the packed logits, and the per-layer KV output is
        scattered straight into paged device storage at the slots the
        scheduler reserved (`pool.fill_packed` write-through — the decode
        mirror never re-uploads prefill KV)."""
        import jax.numpy as jnp

        eng = self.eng
        reqs = batch.requests
        lens = [len(r.prompt) for r in reqs]
        total = sum(lens)
        # ring degree = the (alive) ESP group driving this batch; the token
        # bucket is a bucketed SHARD length x dop so the striped shards stay
        # block-aligned (dop=1 degenerates to plain token bucketing)
        dop = max(len([i for i in batch.instances if i not in eng.failed]), 1)
        tb = self._token_bucket(-(-total // dop)) * dop
        bb = self._bucket(len(reqs), lo=1)
        max_len_b = self._bucket(max(lens))
        tokens = np.zeros(tb, np.int32)
        positions = np.zeros(tb, np.int32)
        offsets = np.full(bb + 1, total, np.int32)
        offsets[0] = 0
        last_idx = np.zeros(bb, np.int32)
        c = 0
        for b, r in enumerate(reqs):
            n = lens[b]
            tokens[c : c + n] = np.asarray(r.prompt, np.int32)
            positions[c : c + n] = np.arange(n)
            c += n
            offsets[b + 1] = c
            last_idx[b] = c - 1
        fn = self._packed_prefill_step(tb, bb, max_len_b, dop)
        prev_impl = eng.model.attn_impl
        eng.model.attn_impl = self._packed_prefill_impl
        try:
            logits, (k_packed, v_packed) = fn(
                eng.params, jnp.asarray(tokens), jnp.asarray(positions),
                jnp.asarray(offsets), jnp.asarray(last_idx),
            )
        finally:
            eng.model.attn_impl = prev_impl
        logits = np.asarray(logits)
        for b, r in enumerate(reqs):
            row = self._guard_logits(r, logits[b])
            if row is None:
                continue  # quarantined: no first token, engine requeues
            r.output_tokens.append(eng._sample_token(row))
        if not eng.pool.pools[0].store_values:
            return
        # direct-to-pool paged KV writes: per instance, gather the packed
        # columns this instance retains (striped placement from
        # batch.placement — ESP scale-down stays zero-migration) and
        # write-through into its mirror at the reserved block-table slots
        # (per-data-shard mirrors under the mesh executor: the columns land
        # on the instance's OWN device)
        starts = np.concatenate([[0], np.cumsum(lens)])
        per_inst: Dict[int, Tuple[List[np.ndarray], List[np.ndarray]]] = {}
        for b, r in enumerate(reqs):
            for inst, pos_list in batch.placement.get(r.rid, {}).items():
                if not pos_list or inst in eng.failed:
                    continue
                p = np.asarray(pos_list, np.int64)
                cols, slots = per_inst.setdefault(inst, ([], []))
                cols.append(starts[b] + p)
                slots.append(eng.pool.pools[inst].slots_for(r.rid, p))
        for inst, (cols, slots) in per_inst.items():
            cidx = jnp.asarray(np.concatenate(cols))
            eng.pool.pools[inst].fill_packed(
                np.concatenate(slots),
                jnp.take(k_packed, cidx, axis=1),
                jnp.take(v_packed, cidx, axis=1),
            )

    def prefill_serial(self, batch) -> None:
        """Per-request fallback (recurrent/hybrid state, moe capacity)."""
        import jax.numpy as jnp

        from repro.kernels import ops

        eng = self.eng
        for r in batch.requests:
            # dispatch-counted so tests/benches can assert the packed paths
            # (incl. DoP>1 ring fusion) never fall back to serial prefill
            ops.dispatch_counts["prefill_serial_model"] += 1
            toks = jnp.asarray(np.asarray(r.prompt, np.int32)[None])
            logits, cache = eng.model.prefill(eng.params, {"tokens": toks})
            row = self._guard_logits(r, np.asarray(logits[0, -1]))
            if row is None:
                continue  # quarantined: no first token, engine requeues
            r.output_tokens.append(eng._sample_token(row))
            if cache.k is not None:
                k = np.asarray(cache.k[:, 0], np.float32)  # [L, T, KVH, D]
                v = np.asarray(cache.v[:, 0], np.float32)
                assign = batch.placement[r.rid]
                for inst, positions in assign.items():
                    if positions and inst not in eng.failed:
                        eng.pool.pools[inst].fill(
                            r.rid, positions, k[:, positions], v[:, positions]
                        )
            if cache.ssm is not None:
                eng._real_cache[r.rid] = cache.ssm

    # -------------------------------------------------------------- decode
    def decode(self, g) -> None:
        if self._paged_impl is not None and self.eng.pool.pools[0].store_values:
            return self.decode_paged(g)
        return self.decode_serial(g)

    def decode_paged(self, g) -> None:
        """Gather-free batched decode: ONE model step for the whole group;
        per layer, one paged-kernel launch per instance over the pool storage
        in place (block tables), partials LSE-merged multi-master style."""
        import jax.numpy as jnp

        from repro.core.paged_decode import PagedShard
        from repro.models.transformer import Cache

        eng = self.eng
        rids = [r.rid for r in g.requests]
        n_cached = np.array([r.seq_len - 1 for r in g.requests], np.int32)
        shards, covered = [], np.zeros(len(rids), np.int64)
        for pool in eng.pool.pools:
            if pool.instance_id in eng.failed:
                continue
            table, lengths = pool.block_table(rids)
            if not lengths.any():
                continue
            covered += lengths
            # pool-owned incrementally-synced mirror: steady-state decode
            # uploads one slot per request; packed-prefill slots upload 0
            kdev, vdev, posdev = pool.device_paged_kv()
            shards.append(PagedShard(
                # block tables ride with the mirror's device so the whole
                # per-shard partial computes where the stripe lives
                k_pages=kdev,
                v_pages=vdev,
                table=pool._dev_put(table),
                lengths=pool._dev_put(lengths),
                # per-slot positions are only consumed by window masking
                pos=(posdev if eng.cfg.sliding_window else None),
            ))
        # cache holds tokens 0..seq_len-2; the processed token's KV is
        # produced by this step and appended at the master afterwards
        assert (covered == n_cached).all(), (covered, n_cached)
        toks = jnp.asarray([r.output_tokens[-1] for r in g.requests], jnp.int32)
        cache = Cache(length=jnp.asarray(n_cached))
        prev_impl = eng.model.attn_impl
        eng.model.attn_impl = self._paged_impl
        self._paged_impl.begin_step(shards)
        try:
            logits, _, kvs = eng.model.decode(eng.params, toks, cache)
        finally:
            self._paged_impl.end_step()
            eng.model.attn_impl = prev_impl
        self._emit_decoded(g, logits, kvs)

    def _emit_decoded(self, g, logits, kvs) -> None:
        """Shared batched-decode epilogue: sample one token per request and
        stash the step's new per-layer KV; _on_decode_done fills it once the
        slot is allocated.  logits [>=B, V]; kvs [L, >=B, 1, KVH, D] (rows
        past len(g.requests) are bucket padding)."""
        eng = self.eng
        logits = np.asarray(logits)
        for b, r in enumerate(g.requests):
            row = self._guard_logits(r, logits[b])
            if row is None:
                continue  # quarantined: no token, no KV stash
            r.output_tokens.append(eng._sample_token(row))
            if kvs is not None:
                eng._pending_kv[r.rid] = (
                    np.asarray(kvs[0][:, b], np.float32),  # [L, 1, KVH, D]
                    np.asarray(kvs[1][:, b], np.float32),
                )

    def decode_serial(self, g) -> None:
        """Per-request fallback (recurrent/hybrid state or custom impls)."""
        import jax.numpy as jnp

        from repro.models.transformer import Cache

        eng = self.eng
        for r in g.requests:
            positions, k, v = eng.pool.gather_request(r.rid)
            # cache holds tokens 0..seq_len-2; the processed token's KV is
            # produced by this step and appended at the master afterwards
            n_cached = r.seq_len - 1
            if k is not None:
                assert len(positions) == n_cached, (len(positions), n_cached)
            cache = Cache(
                k=jnp.asarray(k[:, None].astype(eng.model.dtype)) if k is not None else None,
                v=jnp.asarray(v[:, None].astype(eng.model.dtype)) if v is not None else None,
                length=jnp.asarray([n_cached], jnp.int32),
                ssm=eng._real_cache.get(r.rid),
            )
            last_tok = r.output_tokens[-1]
            logits, new_cache, kvs = eng.model.decode(
                eng.params, jnp.asarray([last_tok], jnp.int32), cache
            )
            row = self._guard_logits(r, np.asarray(logits[0]))
            if row is None:
                continue  # quarantined: no token, no cache/KV update
            r.output_tokens.append(eng._sample_token(row))
            if new_cache.ssm is not None:
                eng._real_cache[r.rid] = new_cache.ssm
            if kvs is not None:
                # stash; _on_decode_done fills it once the slot is allocated
                eng._pending_kv[r.rid] = (
                    np.asarray(kvs[0][:, 0], np.float32),  # [L, 1, KVH, D]
                    np.asarray(kvs[1][:, 0], np.float32),
                )

    # ------------------------------------------------------------- unified
    @property
    def supports_unified(self) -> bool:
        """The fused chunked-prefill+decode iteration needs the packed attn
        impls (dense/vlm family) and real paged KV storage for the prefix
        partials to read from."""
        return (
            self._unified_impl is not None
            and self.eng.pool.pools[0].store_values
        )

    def _unified_segments(self, work) -> List[_USeg]:
        """Packed-axis layout of one unified iteration: every admitted
        prompt's prefill chunk (batch order), then one decode row per
        in-flight request.  A prefill segment's filled prefix is everything
        before its chunk cursor; a decode row's is its whole cache (tokens
        0..seq_len-2 — the processed token's KV is produced by this step)."""
        segs: List[_USeg] = []
        recovering = getattr(self.eng, "_recovering", {})
        for r in work.batch.requests:
            if r.rid not in work.chunks:
                continue  # out of chunk budget this iteration
            start, ln = work.chunks[r.rid]
            # a decode-resume recovery hole may cover generated positions
            # (up to seq_len - 2), not just the prompt
            hi = max(r.input_len, r.seq_len - 1)
            assert ln > 0 and start + ln <= hi, (start, ln, r.input_len, hi)
            rec = recovering.get(r.rid)
            # hole chunks of a decode-resume recovery NEVER sample: the
            # request's tokens already exist — it re-enters decode at its
            # cursor once coverage is whole (a hole ending exactly at
            # input_len must not re-emit the first generated token)
            final = start + ln == r.input_len and (
                rec is None or not rec.resume_decode
            )
            segs.append(_USeg(r, False, start, ln, start, final))
        for g in work.groups:
            for r in g.requests:
                segs.append(_USeg(r, True, r.seq_len - 1, 1, r.seq_len - 1, True))
        return segs

    def _unified_pack(self, segs, tb: int = None):
        """Host-side packing: (tokens [tb], positions [tb], offsets [bb+1],
        last_idx [bb]) — exactly `prefill_packed`'s layout, with decode rows
        as length-1 segments carrying their request's last sampled token.
        ``tb`` overrides the token bucket (the SPMD path needs a multiple of
        the rank count)."""
        total = sum(s.ln for s in segs)
        if tb is None:
            tb = self._token_bucket(total)
        bb = self._bucket(len(segs), lo=1)
        tokens = np.zeros(tb, np.int32)
        positions = np.zeros(tb, np.int32)
        offsets = np.full(bb + 1, total, np.int32)
        offsets[0] = 0
        last_idx = np.zeros(bb, np.int32)
        c = 0
        for b, s in enumerate(segs):
            if s.decode:
                tokens[c] = s.r.output_tokens[-1]
            else:
                tokens[c : c + s.ln] = _token_span(s.r, s.start, s.ln)
            positions[c : c + s.ln] = np.arange(s.start, s.start + s.ln)
            c += s.ln
            offsets[b + 1] = c
            last_idx[b] = c - 1
        return tokens, positions, offsets, last_idx

    def _unified_count(self, segs) -> None:
        from repro.kernels import ops

        n_pre = sum(s.ln for s in segs if not s.decode)
        ops.dispatch_counts["unified_step"] += 1
        ops.dispatch_counts["unified_prefill_tokens"] += n_pre
        ops.dispatch_counts["unified_decode_tokens"] += sum(
            s.ln for s in segs if s.decode
        )

    def _unified_shards(self, segs, tb: int):
        """Per-pool `core.unified.UnifiedShard`s with PER-TOKEN paged prefix
        operands: one `prefix_block_table` row per segment (clipped to the
        filled prefix), expanded to the packed token axis.  Returns
        (shards, covered); covered[b] sums segment b's prefix length over
        every pool and must equal its limit — no filled slot unreachable,
        none double-counted."""
        from repro.core.unified import UnifiedShard

        eng = self.eng
        rids = [s.r.rid for s in segs]
        limits = np.array([s.limit for s in segs], np.int64)
        infos = []
        for pool in eng.pool.pools:
            if pool.instance_id in eng.failed:
                continue
            table, lengths = pool.prefix_block_table(rids, limits)
            if lengths.any():
                infos.append((pool, table, lengths))
        covered = (
            np.sum([lg for _, _, lg in infos], axis=0)
            if infos
            else np.zeros(len(segs), np.int64)
        )
        mpb = self._bucket(
            max((t.shape[1] for _, t, _ in infos), default=1), lo=1
        )
        shards = []
        for pool, table, lengths in infos:
            tbl_t = np.zeros((tb, mpb), np.int32)
            len_t = np.zeros(tb, np.int32)
            c = 0
            for b, s in enumerate(segs):
                tbl_t[c : c + s.ln, : table.shape[1]] = table[b]
                len_t[c : c + s.ln] = lengths[b]
                c += s.ln
            kdev, vdev, posdev = pool.device_paged_kv()
            shards.append(UnifiedShard(
                k_pages=kdev,
                v_pages=vdev,
                page_pos=(posdev if eng.cfg.sliding_window else None),
                table=pool._dev_put(tbl_t),
                lengths=pool._dev_put(len_t),
            ))
        return shards, covered

    def _unified_step(self, tb: int, bb: int, max_len_b: int, n_shards: int):
        """Jitted in-process unified program for one bucket tuple: one
        packed model step with `UnifiedAttnImpl` merging the paged prefix
        partials into the chunk attention at every layer (static python
        layer loop — `unroll=True` — so the impl can keep a layer cursor)."""
        key = ("unified", tb, bb, max_len_b, n_shards)
        fn = self._program_get(key)
        if fn is None:
            import jax

            model, impl = self.eng.model, self._unified_impl

            def step(params, tokens, positions, offsets, last_idx, shards):
                impl.begin_step(
                    offsets, positions, max_seq_len=max_len_b, shards=shards
                )
                try:
                    return model.prefill_packed(
                        params, {"tokens": tokens[None]}, positions, last_idx,
                        unroll=True,
                    )
                finally:
                    impl.end_step()

            fn = self._program_put(key, jax.jit(step))
        return fn

    def unified(self, work) -> None:
        """ONE packed model step for a whole unified iteration: a bounded
        chunk of each admitted prompt's prefill tokens AND every in-flight
        decode token share one ragged token axis; per layer the chunk
        attention folds on top of the paged prefix partials
        (`core.unified`).  First/next tokens are sampled from the packed
        logits, prefill chunk KV write-throughs at the reserved slots, and
        decode KV is stashed exactly like `decode_paged`."""
        segs = self._unified_segments(work)
        self._unified_local(work, segs)

    def _unified_local(self, work, segs) -> None:
        import jax.numpy as jnp

        eng = self.eng
        tokens, positions, offsets, last_idx = self._unified_pack(segs)
        tb, bb = len(tokens), len(last_idx)
        max_len_b = self._bucket(max(s.ln for s in segs))
        shards, covered = self._unified_shards(segs, tb)
        limits = np.array([s.limit for s in segs], np.int64)
        assert (covered == limits).all(), (covered, limits)
        self._unified_count(segs)
        fn = self._unified_step(tb, bb, max_len_b, len(shards))
        prev_impl = eng.model.attn_impl
        eng.model.attn_impl = self._unified_impl
        try:
            logits, (k_packed, v_packed) = fn(
                eng.params, jnp.asarray(tokens), jnp.asarray(positions),
                jnp.asarray(offsets), jnp.asarray(last_idx), tuple(shards),
            )
        finally:
            eng.model.attn_impl = prev_impl
        self._unified_emit(
            work, segs, np.asarray(logits), None, k_packed, v_packed, None
        )

    def _unified_emit(
        self, work, segs, logits, ids, k_packed, v_packed, colmap
    ) -> None:
        """Shared unified epilogue.  Host-sampling path: ``logits`` [>=S, V]
        rows pass the NaN guard then argmax (``ids`` None); SPMD path:
        ``ids`` [>=S] were sampled in-program (logits never leave the
        program, so no value guard — same documented gap as
        `_emit_decoded_routed`).  ``colmap`` maps a packed column to its row
        on the KV output's token axis (striped order under SPMD; None =
        identity).  Prefill chunk KV scatters write-through at the chunk's
        reserved placement slots; decode KV is stashed for
        `_on_unified_done` to fill once the slot is allocated."""
        import jax.numpy as jnp

        eng = self.eng
        starts = np.concatenate([[0], np.cumsum([s.ln for s in segs])])
        col_of = (lambda c: c) if colmap is None else (lambda c: colmap[c])
        emitted = set()
        for b, s in enumerate(segs):
            if not s.final:
                continue
            if ids is None:
                row = self._guard_logits(s.r, logits[b])
                if row is None:
                    continue  # quarantined: no token, engine requeues
                s.r.output_tokens.append(eng._sample_token(row))
            else:
                s.r.output_tokens.append(int(ids[b]))
            emitted.add(s.r.rid)
        if not eng.pool.pools[0].store_values:
            return
        per_inst: Dict[int, Tuple[List[np.ndarray], List[np.ndarray]]] = {}
        dec_cols: List[int] = []
        dec_reqs: List[Any] = []
        for b, s in enumerate(segs):
            if s.decode:
                if s.r.rid in emitted:  # quarantined rows stash no KV
                    dec_cols.append(int(col_of(starts[b])))
                    dec_reqs.append(s.r)
                continue
            lo, hi = s.start, s.start + s.ln
            for inst, pos_list in work.batch.placement.get(s.r.rid, {}).items():
                if not pos_list or inst in eng.failed:
                    continue
                p = np.asarray(pos_list, np.int64)
                p = p[(p >= lo) & (p < hi)]
                if not len(p):
                    continue
                cols, slots = per_inst.setdefault(inst, ([], []))
                cols.append(np.asarray(col_of(starts[b] + (p - lo)), np.int64))
                slots.append(eng.pool.pools[inst].slots_for(s.r.rid, p))
        for inst, (cols, slots) in per_inst.items():
            cidx = jnp.asarray(np.concatenate(cols))
            eng.pool.pools[inst].fill_packed(
                np.concatenate(slots),
                jnp.take(k_packed, cidx, axis=1),
                jnp.take(v_packed, cidx, axis=1),
            )
        if dec_cols:
            dc = jnp.asarray(np.asarray(dec_cols, np.int64))
            kd = np.asarray(jnp.take(k_packed, dc, axis=1), np.float32)
            vd = np.asarray(jnp.take(v_packed, dc, axis=1), np.float32)
            for j, r in enumerate(dec_reqs):
                eng._pending_kv[r.rid] = (kd[:, j : j + 1], vd[:, j : j + 1])


class MeshExecutor(LocalExecutor):
    """SPMD executor: DoP>1 packed ring prefill as a real shard_map program.

    Construction binds each engine instance ``i`` to data-mesh coordinate
    ``i`` of a ``("data", "model")`` mesh (`launch.mesh`): the instance's
    KV-pool device mirror is pinned to ``mesh.devices[i, 0]`` so both the
    ring pass's `fill_packed` write-through and the paged decode partials
    run on the device that owns the stripe.  A prefill batch over a subset
    of instances runs on the sub-mesh of exactly those devices (cached per
    instance tuple), so elastic DoP groups map to disjoint device groups of
    one physical mesh, like the paper's ESP groups on one GPU cluster.

    Decode is SPMD too (``spmd_decode=True``): the whole batched decode
    iteration compiles as ONE program in which every layer's multi-master
    LSE-merge is a shard_map collective over a 1-D "data" mesh of exactly
    the KV-holding instances' mirror devices.  The sharded paged operand is
    assembled ZERO-COPY from the per-rank pool mirrors
    (`KVPool.device_paged_kv` slices aliased together with
    `jax.make_array_from_single_device_arrays`), the query reaches the
    shards as a compiled replication instead of a per-shard `device_put`
    loop, and the merge is a `pmax`+`psum` on the weighted
    (o·exp(m-M), l·exp(m-M)) accumulator (`core.esp.paged_decode_spmd`) —
    no per-layer host sync points.  ``decode_overlap=False`` pins each
    merge collective behind an optimization barrier (the benchmark's
    sequential baseline, mirroring ``double_buffer=False`` for prefill).
    Groups that cannot get one distinct mirror device per KV-holding
    instance fall back to the per-shard loop.

    ``batch_shard=True`` (default) additionally BATCH-SHARDS the
    non-attention stack (LoongServe §4.2 multi-master): each rank embeds,
    runs FFN/norms, unembeds and greedy-samples only its B/n slice of the
    decode batch — per-rank decode FLOPs ~1/n instead of n-fold replicated
    — and the per-layer boundary becomes all_gather(q-slice) in /
    `psum_scatter` of the LSE-merged output back to batch shards
    (`core.esp.paged_decode_iteration_spmd`).  Sampled ids are exchanged
    in-program and each rank gathers the new KV rows of the requests it
    MASTERS (routing matrix from `DecodeBatch.masters`), so the routed
    per-master append rows land master-major — sharded onto the masters'
    own devices — instead of the host re-slicing a replicated tensor.
    Params stay replicated over the decode mesh: batch sharding is data
    parallelism, every rank runs the full layer stack on its slice, so no
    parameter axis is sharded over "data".  ``batch_shard=False`` keeps
    the PR 5 replicated-stack program (the benchmark's comparison arm).

    ``double_buffer=False`` degrades the ring to the sequential baseline
    (transfer strictly after compute) — the benchmark's comparison arm.
    """

    def __init__(self, engine, mesh=None, *, double_buffer: bool = True,
                 spmd_decode: bool = True, decode_overlap: bool = True,
                 batch_shard: bool = True):
        super().__init__(engine)
        if mesh is None:
            import jax

            from repro.launch.mesh import make_test_mesh

            n_dev = len(jax.devices())
            data = min(len(engine.pool.pools), n_dev)
            mesh = make_test_mesh(data=data, model=max(n_dev // data, 1))
        assert "data" in mesh.axis_names, mesh.axis_names
        self.mesh = mesh
        self.double_buffer = double_buffer
        self.spmd_decode = spmd_decode
        self.decode_overlap = decode_overlap
        self.batch_shard = batch_shard
        self._group_meshes: Dict[Tuple[int, ...], Any] = {}
        self._decode_meshes: Dict[Tuple[int, ...], Any] = {}
        self._params_rep: Dict[Any, Any] = {}
        self._bind_pool_devices()

    def _bind_pool_devices(self) -> None:
        """Pin instance i's KV mirror to data-shard device i (mod data)."""
        devs = self._data_devices()
        for i, pool in enumerate(self.eng.pool.pools):
            pool.bind_device(devs[i % len(devs)])

    def _data_devices(self):
        """One device per data coordinate (model coordinate 0)."""
        import numpy as np_

        devs = np_.asarray(self.mesh.devices)
        data_ax = list(self.mesh.axis_names).index("data")
        # move the data axis first, take coordinate 0 of every other axis
        devs = np_.moveaxis(devs, data_ax, 0)
        return [devs[i].flat[0] for i in range(devs.shape[0])]

    def on_instance_failed(self, inst: int) -> None:
        """Purge every cached sub-mesh containing the dead rank, plus the
        replicated params and compiled programs baked to those meshes.  A
        surviving group re-forms at DoP−1 through the normal `_group_mesh`
        / `_decode_mesh` path — the reduced-DoP program compiles (or LRU-
        hits) on first use, exactly like any other elastic resize."""
        dead = []
        for cache in (self._group_meshes, self._decode_meshes):
            for key in [k for k in cache if inst in k]:
                m = cache.pop(key)
                if m is not None:
                    dead.append(m)
        for m in dead:
            self._params_rep.pop(m, None)
        if dead:
            for key in [k for k in self._programs if any(m in key for m in dead)]:
                del self._programs[key]

    def _group_mesh(self, instances):
        """Sub-mesh ("data", "model") over exactly the group's devices.
        Returns None (-> in-process replay) when the group cannot get one
        distinct data-shard device per instance (more engine instances than
        data coordinates and the group aliases)."""
        import numpy as np_
        from jax.sharding import Mesh

        key = tuple(sorted(instances))
        if key in self._group_meshes:
            return self._group_meshes[key]
        devs = np_.asarray(self.mesh.devices)
        data_ax = list(self.mesh.axis_names).index("data")
        devs = np_.moveaxis(devs, data_ax, 0)
        n_data = devs.shape[0]
        coords = [i % n_data for i in key]
        if len(set(coords)) < len(coords):
            m = None  # aliased devices: no physical ring for this group
        else:
            rows = np_.stack(
                [devs[c].reshape(-1) for c in coords]
            )  # [dop, model*...]
            m = Mesh(rows, ("data", "model"))
        self._group_meshes[key] = m
        return m

    # prefill arming: the SAME packed step, ring under shard_map ----------
    def prefill_packed(self, batch) -> None:
        alive = tuple(
            i for i in batch.instances if i not in self.eng.failed
        )
        self._step_mesh = self._group_mesh(alive) if len(alive) > 1 else None
        try:
            return super().prefill_packed(batch)
        finally:
            self._step_mesh = None

    def _program_key(self, tb, bb, max_len_b, dop):
        # one compiled program per (bucket tuple, dop, mesh): the concrete
        # mesh (hashable) keys the cache because the shard_map bakes the
        # device group in — two DoP groups of the same shape on different
        # devices need separate programs
        return (tb, bb, max_len_b, dop, getattr(self, "_step_mesh", None))

    def _arm_packed_step(self, impl, offsets, max_len_b, dop):
        impl.begin_step(
            offsets, max_len_b, dop=dop,
            mesh=getattr(self, "_step_mesh", None),
            double_buffer=self.double_buffer,
        )

    # decode: the whole iteration as ONE SPMD program ---------------------
    def _decode_mesh(self, instances: Tuple[int, ...]):
        """1-D ("data",) mesh over exactly the KV-holding instances' mirror
        devices (cached per instance tuple).  Returns None (-> per-shard
        loop fallback) when the instances don't map to distinct devices."""
        if instances in self._decode_meshes:
            return self._decode_meshes[instances]
        import numpy as np_
        from jax.sharding import Mesh

        devs = [self.eng.pool.pools[i].device for i in instances]
        if None in devs or len(set(devs)) < len(devs):
            m = None
        else:
            m = Mesh(np_.asarray(devs), ("data",))
        self._decode_meshes[instances] = m
        return m

    def _replicated_params(self, mesh):
        """Engine params replicated over the decode mesh ONCE (committed),
        so steady-state decode iterations re-transfer nothing."""
        pr = self._params_rep.get(mesh)
        if pr is None:
            import jax
            from jax.sharding import NamedSharding, PartitionSpec as P

            pr = jax.device_put(
                self.eng.params, NamedSharding(mesh, P())
            )
            self._params_rep[mesh] = pr
        return pr

    def _decode_program(self, bb: int, mpb: int, mesh, rb=None):
        """Jitted whole-iteration decode program for one (batch bucket,
        page bucket, mesh[, route bucket]) tuple — O(log) compiled
        variants, like the prefill program cache.  ``rb=None`` compiles the
        replicated-stack program (every rank runs the full batch, per-layer
        pmax+psum merge); ``rb`` set compiles the batch-sharded iteration
        (`core.esp.paged_decode_iteration_spmd`) with R=rb routed KV-append
        rows per master."""
        key = ("decode", bb, mpb, mesh, self.decode_overlap, rb)
        fn = self._program_get(key)
        if fn is None:
            import jax

            from repro.core.paged_decode import SpmdPagedShards
            from repro.models.transformer import Cache

            model, impl = self.eng.model, self._paged_impl
            overlap = self.decode_overlap

            if rb is not None:
                from repro.core.esp import paged_decode_iteration_spmd

                def step(params, toks, n_cached, k_g, v_g, tbl_g, len_g,
                         pos_g, route):
                    return paged_decode_iteration_spmd(
                        mesh, model, impl, params, toks, n_cached,
                        k_g, v_g, tbl_g, len_g, pos_g, route,
                        overlap=overlap,
                    )
            else:
                def step(params, toks, n_cached, k_g, v_g, tbl_g, len_g,
                         pos_g):
                    shards = SpmdPagedShards(k_g, v_g, tbl_g, len_g, pos_g)
                    impl.begin_step(shards, mesh=mesh, overlap=overlap)
                    try:
                        logits, _, kvs = model.decode(
                            params, toks, Cache(length=n_cached)
                        )
                    finally:
                        impl.end_step()
                    return logits, kvs

            fn = self._program_put(key, jax.jit(step))
        return fn

    def _decode_spmd_setup(self, g):
        """Assemble the SPMD decode call for one DecodeBatch: returns
        (jitted program, concrete args, rowmap) or None when the group
        cannot run SPMD (single shard, unbound/aliased mirror devices).

        The paged operands are assembled from the per-rank mirrors IN
        PLACE: each pool's `device_paged_kv` view becomes data-rank i's
        slice of one mesh-sharded array — the executor ships per-request
        block-table rows (tiny) and ZERO KV bytes.

        ``rowmap`` is None for the replicated program; for the
        batch-sharded program it maps rid -> row of the master-major
        routed KV output (rank*rb + j, from the route matrix built out of
        `DecodeBatch.masters` — a master not holding KV in this group
        routes through rank 0)."""
        import jax
        import jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P

        eng = self.eng
        rids = [r.rid for r in g.requests]
        n_cached = np.array([r.seq_len - 1 for r in g.requests], np.int32)
        infos = []
        for pool in eng.pool.pools:
            if pool.instance_id in eng.failed:
                continue
            table, lengths = pool.block_table(rids)
            if lengths.any():
                infos.append((pool, table, lengths))
        if len(infos) < 2:
            return None
        mesh = self._decode_mesh(tuple(p.instance_id for p, _, _ in infos))
        if mesh is None:
            return None
        covered = np.sum([lg for _, _, lg in infos], axis=0)
        # cache holds tokens 0..seq_len-2; the processed token's KV is
        # produced by this step and appended at the master afterwards
        assert (covered == n_cached).all(), (covered, n_cached)
        n, b = len(infos), len(rids)
        bb = self._bucket(b, lo=1)
        if self.batch_shard:
            # each rank owns bb/n batch rows: round the bucket up to a
            # multiple of the rank count (padded rows hold zero KV
            # everywhere and their sampled tokens are discarded)
            bb = -(-bb // n) * n
        mpb = self._bucket(max(t.shape[1] for _, t, _ in infos), lo=1)
        sh = NamedSharding(mesh, P("data"))
        kds, vds, pds = [], [], []
        for pool, _, _ in infos:
            kd, vd, pd = pool.device_paged_kv()
            kds.append(kd[None])
            vds.append(vd[None])
            pds.append(pd[None])
        assemble = jax.make_array_from_single_device_arrays
        k_g = assemble((n,) + kds[0].shape[1:], sh, kds)
        v_g = assemble((n,) + vds[0].shape[1:], sh, vds)
        pos_g = (
            assemble((n,) + pds[0].shape[1:], sh, pds)
            if eng.cfg.sliding_window else None
        )
        tbl = np.zeros((n, bb, mpb), np.int32)
        lens = np.zeros((n, bb), np.int32)
        for i, (_, t, lg) in enumerate(infos):
            tbl[i, :b, : t.shape[1]] = t
            lens[i, :b] = lg
        toks = np.zeros(bb, np.int32)
        toks[:b] = [r.output_tokens[-1] for r in g.requests]
        ncb = np.zeros(bb, np.int32)
        ncb[:b] = n_cached
        rb = route = rowmap = None
        if self.batch_shard:
            # per-master KV-append routing: rank i gathers the new KV rows
            # of the requests instance infos[i] masters, so the routed
            # output lands master-major on the masters' own devices
            inst_rank = {
                p.instance_id: i for i, (p, _, _) in enumerate(infos)
            }
            per_rank: List[List[int]] = [[] for _ in range(n)]
            owner_of: List[Tuple[int, int]] = []
            for bi, r in enumerate(g.requests):
                rank = inst_rank.get(g.masters.get(r.rid), 0)
                owner_of.append((rank, len(per_rank[rank])))
                per_rank[rank].append(bi)
            rb = self._bucket(max(len(rows) for rows in per_rank), lo=1)
            route = np.zeros((n, rb), np.int32)  # padding rows read row 0
            for i, rows in enumerate(per_rank):
                route[i, : len(rows)] = rows
            rowmap = {
                r.rid: rank * rb + j
                for r, (rank, j) in zip(g.requests, owner_of)
            }
        fn = self._decode_program(bb, mpb, mesh, rb)
        args = [
            self._replicated_params(mesh), jnp.asarray(toks),
            jnp.asarray(ncb), k_g, v_g, jax.device_put(tbl, sh),
            jax.device_put(lens, sh), pos_g,
        ]
        if route is not None:
            args.append(jax.device_put(route, sh))
        return fn, tuple(args), rowmap

    def decode_paged(self, g) -> None:
        """One shard_map decode iteration for the whole group: per layer,
        each rank's paged partial computes over the mirror it holds and the
        LSE-merge is a collective XLA can schedule against independent
        compute — zero per-shard Python-loop merges, zero per-layer
        `device_put` hops (see `core.esp.paged_decode_spmd`)."""
        setup = self._decode_spmd_setup(g) if self.spmd_decode else None
        if setup is None:
            return super().decode_paged(g)
        fn, args, rowmap = setup
        eng = self.eng
        prev_impl = eng.model.attn_impl
        eng.model.attn_impl = self._paged_impl
        try:
            if rowmap is None:
                logits, kvs = fn(*args)
            else:
                toks_next, k_rt, v_rt = fn(*args)
        finally:
            eng.model.attn_impl = prev_impl
        if rowmap is None:
            self._emit_decoded(g, logits, kvs)
        else:
            self._emit_decoded_routed(g, toks_next, k_rt, v_rt, rowmap)

    def _emit_decoded_routed(self, g, toks_next, k_rt, v_rt, rowmap) -> None:
        """Batch-sharded epilogue: tokens were sampled IN-PROGRAM (each
        rank argmaxed its own logits slice, ids exchanged by all_gather) and
        the new per-layer KV arrives master-major pre-routed
        [L, n*rb, 1, KVH, D] — this just appends each request's id and
        stashes its routed KV rows for _on_decode_done to fill.

        NOTE: the NaN-logit value guard cannot apply here — logits never
        leave the program, only sampled ids do.  Chaos logit poisoning
        targets the host-sampling paths (`_emit_decoded`/serial/packed);
        `_logit_poison` entries are simply not consumed on this path."""
        eng = self.eng
        toks = np.asarray(toks_next)
        k_rt = np.asarray(k_rt, np.float32)
        v_rt = np.asarray(v_rt, np.float32)
        for b, r in enumerate(g.requests):
            r.output_tokens.append(int(toks[b]))
            row = rowmap[r.rid]
            eng._pending_kv[r.rid] = (k_rt[:, row], v_rt[:, row])

    # unified: the whole fused iteration as ONE shard_map program ---------
    def _unified_spmd_program(self, tb, bb, max_len_b, mesh):
        """Jitted SPMD unified program for one (bucket tuple, mesh) —
        cached in the same merged LRU iteration cache as the prefill and
        decode programs."""
        key = ("unified_spmd", tb, bb, max_len_b, mesh)
        fn = self._program_get(key)
        if fn is None:
            import jax

            from repro.core.esp import unified_iteration_spmd

            model, impl = self.eng.model, self._unified_impl
            dbuf = self.double_buffer

            def step(params, toks, positions, offsets, last_idx, k_g, v_g,
                     tbl_g, len_g, pos_g):
                return unified_iteration_spmd(
                    mesh, model, impl, params, toks, positions, offsets,
                    last_idx, k_g, v_g, tbl_g, len_g, pos_g,
                    max_seq_len=max_len_b, double_buffer=dbuf,
                )

            fn = self._program_put(key, jax.jit(step))
        return fn

    def _unified_spmd_setup(self, work, segs):
        """Assemble the SPMD unified call: returns (fn, args, inv) or None
        when the iteration cannot run SPMD (fewer than two KV-holding
        instances with distinct mirror devices).  ``inv`` maps a packed
        column to its striped row on the program's token axis.

        Exactly `_decode_spmd_setup`'s zero-copy shape: each pool's
        `device_paged_kv` view becomes data-rank i's slice of one
        mesh-sharded array; the executor ships per-TOKEN prefix block-table
        rows (tiny, striped order) and ZERO KV bytes."""
        import jax
        import jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P

        from repro.core import striped

        eng = self.eng
        rids = [s.r.rid for s in segs]
        limits = np.array([s.limit for s in segs], np.int64)
        infos = []
        for pool in eng.pool.pools:
            if pool.instance_id in eng.failed:
                continue
            table, lengths = pool.prefix_block_table(rids, limits)
            if lengths.any():
                infos.append((pool, table, lengths))
        if len(infos) < 2:
            return None
        mesh = self._decode_mesh(tuple(p.instance_id for p, _, _ in infos))
        if mesh is None:
            return None
        covered = np.sum([lg for _, _, lg in infos], axis=0)
        assert (covered == limits).all(), (covered, limits)
        n = len(infos)
        total = sum(s.ln for s in segs)
        tb = self._token_bucket(-(-total // n)) * n
        tokens, positions, offsets, last_idx = self._unified_pack(segs, tb)
        bb = len(last_idx)
        max_len_b = self._bucket(max(s.ln for s in segs))
        # striped layout: packed col c lives at striped row inv[c] (rank
        # c % n); block-sharding a pre-striped array hands every rank
        # exactly its stripe
        perm = striped.stripe_indices(tb, n)
        inv = striped.unstripe_indices(tb, n)
        mpb = self._bucket(max(t.shape[1] for _, t, _ in infos), lo=1)
        sh = NamedSharding(mesh, P("data"))
        kds, vds, pds = [], [], []
        tbl = np.zeros((n, tb, mpb), np.int32)
        lens = np.zeros((n, tb), np.int32)
        for i, (pool, table, lengths) in enumerate(infos):
            kd, vd, pd = pool.device_paged_kv()
            kds.append(kd[None])
            vds.append(vd[None])
            pds.append(pd[None])
            len_t = np.zeros(tb, np.int32)
            tbl_t = np.zeros((tb, table.shape[1]), np.int32)
            c = 0
            for b, s in enumerate(segs):
                tbl_t[c : c + s.ln] = table[b]
                len_t[c : c + s.ln] = lengths[b]
                c += s.ln
            tbl[i, :, : table.shape[1]] = tbl_t[perm]
            lens[i] = len_t[perm]
        assemble = jax.make_array_from_single_device_arrays
        k_g = assemble((n,) + kds[0].shape[1:], sh, kds)
        v_g = assemble((n,) + vds[0].shape[1:], sh, vds)
        pos_g = (
            assemble((n,) + pds[0].shape[1:], sh, pds)
            if eng.cfg.sliding_window else None
        )
        fn = self._unified_spmd_program(tb, bb, max_len_b, mesh)
        args = (
            self._replicated_params(mesh),
            jax.device_put(tokens[perm], sh),
            jnp.asarray(positions[perm]),
            jnp.asarray(offsets),
            jnp.asarray(inv[last_idx].astype(np.int32)),
            k_g, v_g, jax.device_put(tbl, sh), jax.device_put(lens, sh),
            pos_g,
        )
        return fn, args, inv

    def unified(self, work) -> None:
        """The whole unified iteration as ONE shard_map program
        (`core.esp.unified_iteration_spmd`): per layer, the decode-style
        paged prefix merge and the prefill-style ppermute chunk ring run
        back to back on the striped token axis, and tokens are sampled
        in-program.  Falls back to the in-process fused loop when the group
        cannot run SPMD."""
        segs = self._unified_segments(work)
        setup = (
            self._unified_spmd_setup(work, segs) if self.spmd_decode else None
        )
        if setup is None:
            return self._unified_local(work, segs)
        fn, args, inv = setup
        self._unified_count(segs)
        eng = self.eng
        prev_impl = eng.model.attn_impl
        eng.model.attn_impl = self._unified_impl
        try:
            ids, k_packed, v_packed = fn(*args)
        finally:
            eng.model.attn_impl = prev_impl
        self._unified_emit(
            work, segs, None, np.asarray(ids), k_packed, v_packed, inv
        )

"""Request lifecycle & metrics (pending -> prefill -> decode -> finished)."""
from __future__ import annotations

import enum
import itertools
from dataclasses import dataclass, field
from typing import List, Optional


class Phase(enum.Enum):
    PENDING = "pending"
    PREFILL = "prefill"
    DECODE = "decode"
    FINISHED = "finished"
    EVICTED = "evicted"  # KV dropped; needs prefill recompute


_req_counter = itertools.count()


@dataclass
class Request:
    input_len: int
    max_new_tokens: int
    arrival: float = 0.0
    rid: int = field(default_factory=lambda: next(_req_counter))
    prompt: Optional[list] = None  # token ids (real-exec mode)
    phase: Phase = Phase.PENDING

    # progress
    generated: int = 0
    output_tokens: List[int] = field(default_factory=list)
    # unified chunked prefill: tokens [0, prefill_pos) have been computed
    # and written to the pool; the next chunk starts here
    prefill_pos: int = 0

    # metrics (timestamps)
    prefill_start: Optional[float] = None
    prefill_end: Optional[float] = None
    finish_time: Optional[float] = None
    decode_exec_time: float = 0.0  # accumulated decode compute time
    n_evictions: int = 0

    @property
    def seq_len(self) -> int:
        return self.input_len + self.generated

    @property
    def max_total_len(self) -> int:
        """Worst-case KV demand (§5.1 eviction-avoidance estimate)."""
        return self.input_len + self.max_new_tokens

    @property
    def done(self) -> bool:
        return self.generated >= self.max_new_tokens

    # ------------------------------------------------------------- metrics
    def input_latency(self) -> Optional[float]:
        if self.prefill_end is None:
            return None
        return self.prefill_end - self.arrival

    def norm_input_latency(self) -> Optional[float]:
        lat = self.input_latency()
        return None if lat is None else lat / max(self.input_len, 1)

    def output_latency(self) -> Optional[float]:
        if self.finish_time is None or self.prefill_end is None:
            return None
        return self.finish_time - self.prefill_end

    def norm_output_latency(self) -> Optional[float]:
        lat = self.output_latency()
        if lat is None or self.generated == 0:
            return None
        return lat / self.generated

    def e2e_latency(self) -> Optional[float]:
        if self.finish_time is None:
            return None
        return self.finish_time - self.arrival

    def norm_e2e_latency(self) -> Optional[float]:
        lat = self.e2e_latency()
        if lat is None:
            return None
        return lat / max(self.seq_len, 1)

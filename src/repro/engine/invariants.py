"""Engine invariant sanitizer: global-state consistency checks for the
serving loop, run after every event under chaos (see repro/chaos.py).

The engine's failure semantics are distributed bookkeeping: pool slots,
group membership, launch stamps and per-request token budgets must stay
mutually consistent through ANY interleaving of failures, rejoins,
preemptions, quarantines and memory pressure.  Each check here is an
invariant that holds at event boundaries (after `_handle` returns — i.e.
after the event's completion processing AND the scheduling round it
triggered):

  I1  slot accounting — every rid holding slots on any pool is either a
      live (PREFILL/DECODE) request or chaos ballast (rid < 0); FINISHED /
      PENDING requests hold zero slots anywhere (no leaks after failure,
      preemption, quarantine or finish).
  I2  pool internal consistency — free pages + owned pages == total pages,
      used tokens == Σ per-request tokens == occupied slot_pos entries,
      free-page stack entries unique and disjoint from owned pages.
  I3  KV coverage — a DECODE-phase request stores exactly positions
      {0..seq_len-2} across the fleet, each exactly once (the final emitted
      token's KV is appended at the next decode completion); a PREFILL-phase
      request holds exactly its reserved placement {0..input_len-1}.  A
      request inside the salvage-recovery window (`eng._recovering`)
      instead validates against its DECLARED coverage target
      `RecoveryState.expected` — salvage re-reserves the dead rank's spans
      immediately, so coverage is {0..expected-1} throughout recovery and
      the check snaps back to exact phase-derived coverage the moment the
      recovery chain completes and the rid leaves `_recovering`.
  I4  group sanity — ready_decode groups contain only DECODE-phase
      requests, membership ∩ failed == ∅, and no rid sits in two groups.
  I5  placement liveness — every slot-holding instance of a live request is
      alive (failure handling freed dead shards synchronously).
  I6  transient-state consistency — `_pending_kv` is drained at event
      boundaries; decode launch stamps (`_decode_launch_seq`,
      `_running_decode_ends`) key only in-flight decode_done events and
      mirror each other; prefill epoch stamps key only in-flight
      prefill_done events.
  I7  clock/failure sanity — failed instances are parked at busy_until=inf,
      alive ones finite; pending queue has no duplicate rids.
  I8  token conservation — `max_total_len` (input + remaining budget) is
      constant across evictions/recomputes, emitted tokens == (input_len -
      original input_len) + generated (folded prefixes are counted once),
      and generated never exceeds the remaining budget.

Violations raise `InvariantViolation` with the event context; the checker
is pure read-only over engine state (safe to arm on any engine, sim or
real).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import numpy as np

from repro.engine.request import Phase

LIVE_PHASES = (Phase.PREFILL, Phase.DECODE)


class InvariantViolation(AssertionError):
    """An engine global-state invariant does not hold."""


class InvariantChecker:
    """Read-only sanitizer over one engine's global state.

    Arm with `arm()` (registers an event hook: checked after EVERY handled
    event) or call `check()` manually at chosen points.  Per-request token
    baselines (I8) are recorded the first time a rid is seen; arming before
    `run()` makes them exact from arrival.

    ``check_every_n`` samples the armed hook: only every n-th handled event
    runs the full check (long local chaos soaks); CI keeps the default of 1
    (after-every-event).  Manual `check()` calls are never sampled.
    """

    def __init__(self, engine, check_every_n: int = 1):
        assert check_every_n >= 1, check_every_n
        self.eng = engine
        self.check_every_n = check_every_n
        self._event_i = 0
        self.checks = 0
        # rid -> (original input_len, original max_total_len); recorded at
        # first sight (self-consistent even when armed mid-flight: emitted
        # tokens so far == len(output_tokens))
        self._baseline: Dict[int, Tuple[int, int]] = {}

    # ------------------------------------------------------------------ arm
    def arm(self) -> None:
        self.eng.event_hooks.append(self._on_event)

    def disarm(self) -> None:
        if self._on_event in self.eng.event_hooks:
            self.eng.event_hooks.remove(self._on_event)

    def _on_event(self, eng, kind, payload) -> None:
        self._event_i += 1
        if self._event_i % self.check_every_n == 0:
            self.check(context=f"after event {kind!r}")

    # ---------------------------------------------------------------- check
    def _fail(self, inv: str, msg: str, context: str) -> None:
        raise InvariantViolation(
            f"[{inv}] {msg} ({context}; check #{self.checks})"
        )

    def check(self, context: str = "manual") -> None:
        self.checks += 1
        eng = self.eng
        live = {
            rid for rid, r in eng._req_index.items() if r.phase in LIVE_PHASES
        }

        # I1 + I2: per-pool slot accounting ------------------------------
        holders: Dict[int, Dict[int, np.ndarray]] = {}  # rid -> inst -> pos
        for pool in eng.pool.pools:
            owned_pages = 0
            used = 0
            for rid in pool.requests():
                st = pool._reqs[rid]
                owned_pages += st.n_pages
                used += st.n_tok
                if rid >= 0 and rid not in live:
                    r = eng._req_index.get(rid)
                    self._fail(
                        "I1",
                        f"instance {pool.instance_id} holds {st.n_tok} slots "
                        f"of rid {rid} (phase "
                        f"{r.phase if r else 'UNKNOWN'}) — leaked slots",
                        context,
                    )
                if rid >= 0:
                    holders.setdefault(rid, {})[pool.instance_id] = (
                        st.pos[: st.n_tok].copy()
                    )
            if pool._n_free_pages + owned_pages != pool.n_pages:
                self._fail(
                    "I2",
                    f"instance {pool.instance_id}: free pages "
                    f"{pool._n_free_pages} + owned {owned_pages} != total "
                    f"{pool.n_pages}",
                    context,
                )
            if used != pool.used:
                self._fail(
                    "I2",
                    f"instance {pool.instance_id}: used counter {pool.used} "
                    f"!= Σ per-request tokens {used}",
                    context,
                )
            if int((pool.slot_pos >= 0).sum()) != used:
                self._fail(
                    "I2",
                    f"instance {pool.instance_id}: occupied slot_pos "
                    f"{int((pool.slot_pos >= 0).sum())} != used {used}",
                    context,
                )
            free = pool._free_pages[: pool._n_free_pages]
            if len(np.unique(free)) != pool._n_free_pages:
                self._fail(
                    "I2",
                    f"instance {pool.instance_id}: duplicate pages on the "
                    "free stack",
                    context,
                )

        # I3: KV coverage per live request --------------------------------
        recovering = getattr(eng, "_recovering", {})
        for rid, per_inst in holders.items():
            r = eng._req_index[rid]
            pos = np.concatenate(list(per_inst.values()))
            rec = recovering.get(rid)
            if rec is not None:
                # salvage window: validate the DECLARED coverage target —
                # the lost spans were re-reserved at salvage time, so the
                # fleet holds exactly {0..expected-1} until the recovery
                # chain completes (then the rid leaves _recovering and the
                # exact phase-derived rule below applies again)
                expect = rec.expected
            else:
                expect = (
                    r.seq_len - 1 if r.phase is Phase.DECODE else r.input_len
                )
            if len(pos) != expect or (
                len(pos) and not np.array_equal(np.sort(pos),
                                                np.arange(expect))
            ):
                self._fail(
                    "I3",
                    f"rid {rid} ({r.phase.value}, seq_len {r.seq_len}) "
                    f"stores {len(pos)} positions, expected exactly "
                    f"0..{expect - 1} once each",
                    context,
                )

        # I4: ready group sanity ------------------------------------------
        seen_in_group = set()
        for g in getattr(eng, "ready_decode", []):
            dead = set(g.instances) & eng.failed
            if dead:
                self._fail(
                    "I4", f"ready group {g.instances} ∩ failed = {dead}",
                    context,
                )
            for r in g.requests:
                if r.phase is not Phase.DECODE:
                    self._fail(
                        "I4",
                        f"rid {r.rid} in a ready group with phase "
                        f"{r.phase.value}",
                        context,
                    )
                if r.rid in seen_in_group:
                    self._fail(
                        "I4", f"rid {r.rid} in two ready groups", context
                    )
                seen_in_group.add(r.rid)

        # I5: placement liveness ------------------------------------------
        for rid, per_inst in holders.items():
            dead = set(per_inst) & eng.failed
            if dead:
                self._fail(
                    "I5",
                    f"rid {rid} holds KV on failed instance(s) {dead}",
                    context,
                )

        # I6: transient state ----------------------------------------------
        if getattr(eng, "_pending_kv", None):
            self._fail(
                "I6",
                f"_pending_kv not drained: rids {list(eng._pending_kv)}",
                context,
            )
        queued = {}
        for _, _, kind, payload in eng.events:
            queued.setdefault(kind, set()).add(id(payload))
        if hasattr(eng, "_decode_launch_seq"):
            stamps = set(eng._decode_launch_seq)
            ends = set(eng._running_decode_ends)
            if stamps != ends:
                self._fail(
                    "I6", "_decode_launch_seq and _running_decode_ends "
                    "key different launches", context,
                )
            # a unified (fused prefill+decode) launch stamps both maps and
            # completes through a single "unified_done" event
            unified = queued.get("unified_done", set())
            if not stamps <= queued.get("decode_done", set()) | unified:
                self._fail(
                    "I6", "decode launch stamp without an in-flight "
                    "decode_done/unified_done event", context,
                )
            if not set(eng._prefill_launch_epoch) <= (
                queued.get("prefill_done", set()) | unified
            ):
                self._fail(
                    "I6", "prefill epoch stamp without an in-flight "
                    "prefill_done/unified_done event", context,
                )

        # I7: failure/clock sanity -----------------------------------------
        for i in range(eng.n):
            if i in eng.failed and eng.busy_until[i] != float("inf"):
                self._fail(
                    "I7", f"failed instance {i} not parked at inf", context
                )
            if i not in eng.failed and eng.busy_until[i] == float("inf"):
                self._fail(
                    "I7", f"alive instance {i} parked at inf", context
                )
        rids_pending = [r.rid for r in eng.pending]
        if len(set(rids_pending)) != len(rids_pending):
            self._fail("I7", "duplicate rids in the pending queue", context)

        # I8: token conservation --------------------------------------------
        for rid, r in eng._req_index.items():
            base = self._baseline.get(rid)
            if base is None:
                base = self._baseline[rid] = (
                    r.input_len + r.generated - len(r.output_tokens),
                    r.max_total_len,
                )
            input0, budget0 = base
            if r.max_total_len != budget0:
                self._fail(
                    "I8",
                    f"rid {rid}: max_total_len drifted "
                    f"{budget0} -> {r.max_total_len}",
                    context,
                )
            emitted = (r.input_len - input0) + r.generated
            if len(r.output_tokens) != emitted:
                self._fail(
                    "I8",
                    f"rid {rid}: {len(r.output_tokens)} emitted tokens vs "
                    f"(input_len - input0) + generated = {emitted}",
                    context,
                )
            if r.generated > r.max_new_tokens:
                self._fail(
                    "I8",
                    f"rid {rid}: generated {r.generated} exceeds budget "
                    f"{r.max_new_tokens}",
                    context,
                )

    # -------------------------------------------------------------- helpers
    def leaked_slots(self) -> int:
        """Tokens held by non-live, non-ballast rids (0 when I1 holds)."""
        eng = self.eng
        live = {
            rid for rid, r in eng._req_index.items() if r.phase in LIVE_PHASES
        }
        return sum(
            pool._reqs[rid].n_tok
            for pool in eng.pool.pools
            for rid in pool.requests()
            if rid >= 0 and rid not in live
        )

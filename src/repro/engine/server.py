"""Serving engines: event-driven iteration loop over elastic instances.

`BaseServingEngine` owns the clock, the event queue, the distributed KV pool,
the SIB and metrics; `LoongServeEngine` drives it with the four-step global
manager (ESP). Baselines (repro.baselines) subclass the same loop so the
comparison is apples-to-apples: identical cost model, pool accounting and
request lifecycle — only the policy differs.

Two compute modes:
  * sim  — tokens are synthetic; iteration durations come from the SIB
           analytical model (the paper's own scheduling signal). This scales
           to paper-sized workloads (Fig. 10-12) on CPU.
  * real — a reduced model actually prefills/decodes on CPU; KV tensors flow
           through the pools exactly as the plans dictate (used by tests and
           the runnable examples; also the source of SIB profiles).

Fault tolerance: `fail_instance` drops an instance and its KV shards —
affected decode requests are re-queued for prefill recompute; `join_instance`
adds fresh capacity; `checkpoint`/`restore` snapshot the full serving state.
Elasticity is the recovery mechanism (DESIGN.md §7).
"""
from __future__ import annotations

import heapq
import itertools
import pickle
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from repro.configs.base import ModelConfig
from repro.engine.request import Phase, Request
from repro.kvcache.distributed import DistributedKVPool
from repro.kvcache.pool import OutOfSlots
from repro.manager.scheduler import (
    DecodeBatch,
    GlobalManager,
    ManagerConfig,
    PrefillBatch,
)
from repro.manager.sib import SIB, HardwareSpec


@dataclass
class EngineMetrics:
    finished: List[Request] = field(default_factory=list)
    rejected: int = 0
    scaling_migration_bytes: int = 0  # ESP transitions: MUST stay 0
    reactive_migration_bytes: int = 0
    q_broadcast_bytes: int = 0
    prefill_iters: int = 0
    decode_iters: int = 0

    def summary(self) -> Dict[str, float]:
        fin = [r for r in self.finished if r.finish_time is not None]
        out: Dict[str, float] = {
            "n_finished": len(fin),
            "rejected": self.rejected,
            "scaling_migration_bytes": self.scaling_migration_bytes,
            "reactive_migration_bytes": self.reactive_migration_bytes,
            "prefill_iters": self.prefill_iters,
            "decode_iters": self.decode_iters,
        }
        if fin:
            for name, fn in [
                ("norm_e2e", lambda r: r.norm_e2e_latency()),
                ("norm_input", lambda r: r.norm_input_latency()),
                ("norm_output", lambda r: r.norm_output_latency()),
            ]:
                vals = [fn(r) for r in fin if fn(r) is not None]
                if vals:
                    out[f"{name}_mean"] = float(np.mean(vals))
                    out[f"{name}_p90"] = float(np.percentile(vals, 90))
            span = max(r.finish_time for r in fin) - min(r.arrival for r in fin)
            toks = sum(r.seq_len for r in fin)
            out["throughput_tok_s"] = toks / max(span, 1e-9)
        return out


_event_seq = itertools.count()


class BaseServingEngine:
    def __init__(
        self,
        cfg: ModelConfig,
        n_instances: int,
        capacity_per_instance: int,
        *,
        hw: Optional[HardwareSpec] = None,
        store_values: bool = False,
        model=None,
        params=None,
        seed: int = 0,
        page_size: int = 1,
    ):
        self.cfg = cfg
        self.n = n_instances
        self.capacity = capacity_per_instance
        self.page_size = page_size
        self.pool = DistributedKVPool(cfg, n_instances, capacity_per_instance,
                                      store_values, page_size)
        self.sib = SIB(cfg, hw)
        self.clock = 0.0
        self.pending: List[Request] = []
        self.events: List[Tuple[float, int, str, Any]] = []
        self.busy_until: Dict[int, float] = {i: 0.0 for i in range(n_instances)}
        self.failed: Set[int] = set()
        self.metrics = EngineMetrics()
        self.model = model
        self.params = params
        self.real = model is not None
        self.rng = np.random.default_rng(seed)
        self._req_index: Dict[int, Request] = {}

    # ----------------------------------------------------------- submission
    def submit(self, req: Request, at: Optional[float] = None) -> None:
        t = req.arrival if at is None else at
        req.arrival = t
        cap_total = self.capacity * (self.n - len(self.failed))
        if req.max_total_len > cap_total:
            self.metrics.rejected += 1
            return
        self._push(t, "arrival", req)
        self._req_index[req.rid] = req

    def _push(self, t: float, kind: str, payload: Any) -> None:
        heapq.heappush(self.events, (t, next(_event_seq), kind, payload))

    # ------------------------------------------------------------ main loop
    def run(self, max_time: float = float("inf"), max_events: int = 2_000_000):
        n_ev = 0
        while self.events and n_ev < max_events:
            t, seq, kind, payload = heapq.heappop(self.events)
            if t > max_time:
                # keep the event for a later run()/restore
                heapq.heappush(self.events, (t, seq, kind, payload))
                break
            self.clock = max(self.clock, t)
            self._handle(kind, payload)
            n_ev += 1
        return self.metrics

    def _handle(self, kind: str, payload: Any) -> None:
        if kind == "arrival":
            self.pending.append(payload)
            payload.phase = Phase.PENDING
        elif kind == "prefill_done":
            self._on_prefill_done(payload)
        elif kind == "decode_done":
            self._on_decode_done(payload)
        elif kind == "fail":
            self._apply_failure(payload)
        elif kind == "join":
            self._apply_join(payload)
        self._try_schedule()

    # hooks ------------------------------------------------------------
    def _try_schedule(self) -> None:  # pragma: no cover - abstract
        raise NotImplementedError

    def _on_prefill_done(self, batch) -> None:  # pragma: no cover
        raise NotImplementedError

    def _on_decode_done(self, batch) -> None:  # pragma: no cover
        raise NotImplementedError

    # ------------------------------------------------------------- helpers
    def idle_instances(self) -> List[int]:
        return [
            i
            for i in range(self.n)
            if i not in self.failed and self.busy_until[i] <= self.clock + 1e-12
        ]

    def _occupy(self, instances: Sequence[int], until: float) -> None:
        for i in instances:
            self.busy_until[i] = until

    def _finish_request(self, req: Request) -> None:
        req.phase = Phase.FINISHED
        req.finish_time = self.clock
        self.pool.free_request(req.rid)
        self.metrics.finished.append(req)

    def _sample_token(self, logits=None) -> int:
        if logits is None:
            return int(self.rng.integers(0, self.cfg.vocab_size))
        return int(np.argmax(logits))

    # -------------------------------------------------- fault tolerance API
    def fail_instance(self, inst: int, at: Optional[float] = None) -> None:
        self._push(at if at is not None else self.clock, "fail", inst)

    def join_instance(self, inst: int, at: Optional[float] = None) -> None:
        self._push(at if at is not None else self.clock, "join", inst)

    def _apply_failure(self, inst: int) -> None:
        self.failed.add(inst)
        self.busy_until[inst] = float("inf")
        # KV shards on the instance are lost: re-queue affected requests for
        # prefill recompute (generated prefix becomes part of the new prompt).
        affected = list(self.pool.pools[inst].requests())
        for rid in affected:
            req = self._req_index.get(rid)
            self.pool.free_request(rid)
            if req is None or req.phase in (Phase.FINISHED,):
                continue
            req.n_evictions += 1
            req.phase = Phase.PENDING
            req.input_len = req.seq_len  # recompute over everything so far
            req.prefill_end = None
            if req not in self.pending:
                self.pending.append(req)
        self._drop_request_state(affected)

    def _apply_join(self, inst: int) -> None:
        if inst in self.failed:
            self.failed.discard(inst)
            self.busy_until[inst] = self.clock
        elif inst >= self.n:  # truly new instance: grow the registry
            for j in range(self.n, inst + 1):
                self.pool.pools.append(
                    type(self.pool.pools[0])(
                        self.cfg, self.capacity, j,
                        self.pool.pools[0].store_values, self.page_size,
                    )
                )
                self.busy_until[j] = self.clock
            self.n = inst + 1

    def _drop_request_state(self, rids: Sequence[int]) -> None:
        """Subclasses drop any per-request runtime state for re-queued rids."""

    # ------------------------------------------------------- checkpointing
    def checkpoint(self, path: str) -> None:
        state = {
            "clock": self.clock,
            "pending": self.pending,
            "events": self.events,
            "busy_until": self.busy_until,
            "failed": self.failed,
            "metrics": self.metrics,
            "req_index": self._req_index,
            "pool_state": [p.state_dict() for p in self.pool.pools],
            "extra": self._checkpoint_extra(),
        }
        with open(path, "wb") as f:
            pickle.dump(state, f)

    def restore(self, path: str) -> None:
        with open(path, "rb") as f:
            state = pickle.load(f)
        self.clock = state["clock"]
        self.pending = state["pending"]
        self.events = state["events"]
        self.busy_until = state["busy_until"]
        self.failed = state["failed"]
        self.metrics = state["metrics"]
        self._req_index = state["req_index"]
        for p, ps in zip(self.pool.pools, state["pool_state"]):
            p.load_state_dict(ps)
        self._restore_extra(state["extra"])

    def _checkpoint_extra(self) -> Any:
        return None

    def _restore_extra(self, extra: Any) -> None:
        pass


# ======================================================================= ESP


class LoongServeEngine(BaseServingEngine):
    """The paper's system: ESP + four-step global manager."""

    def __init__(self, *args, mcfg: Optional[ManagerConfig] = None, **kwargs):
        super().__init__(*args, **kwargs)
        self.manager = GlobalManager(self.cfg, self.sib, self.pool,
                                     mcfg or ManagerConfig())
        self.ready_decode: List[DecodeBatch] = []
        self._real_cache: Dict[int, Any] = {}  # rid -> recurrent state (real)
        self._pending_kv: Dict[int, Any] = {}  # rid -> new kv awaiting alloc
        self._running_decode_ends: Dict[int, float] = {}  # gid -> end time
        # batched paged decode: the multi-master paged attention impl is
        # swapped in only around a batched decode step (the model object is
        # caller-owned and may be shared between engines).  Pure-attention
        # families only: hybrids/ssm keep the serial per-request path, and
        # moe stays serial because expert-capacity dropping is batch-size
        # dependent (batching would change generated tokens).
        self._paged_impl = None
        self._kv_mirror: Dict[int, Any] = {}  # instance -> (k_dev, v_dev)
        self._kv_scatter = None  # lazily-jitted dirty-slot mirror update
        if self.real and self.cfg.family in ("dense", "vlm"):
            from repro.core.paged_decode import PagedDecodeAttnImpl
            from repro.models.transformer import DefaultAttnImpl

            if type(getattr(self.model, "attn_impl", None)) is DefaultAttnImpl:
                self._paged_impl = PagedDecodeAttnImpl()

    # ------------------------------------------------------------- schedule
    def _try_schedule(self) -> None:
        for _ in range(4):  # drain: admit more work onto leftover instances
            idle = [
                i
                for i in self.idle_instances()
                if not any(i in g.instances for g in self.ready_decode)
            ]
            if not idle and not self.ready_decode:
                return
            if not self.pending and not self.ready_decode:
                return
            self.pending.sort(key=lambda r: r.arrival)
            plan = self.manager.schedule(
                self.pending, self.ready_decode, idle, self.clock
            )
            if not plan.prefill and not plan.decode and not plan.migrations:
                return
            self._execute_plan(plan)

    def _execute_plan(self, plan) -> None:
        # migrations (allocation-step KV moves — reactive, counted)
        mig_delay: Dict[int, float] = {}
        for m in plan.migrations:
            try:
                moved = self.pool.migrate_request(m.rid, m.src, m.dsts)
            except OutOfSlots:
                continue
            self.metrics.reactive_migration_bytes += moved
            t = self.sib.migration_time(m.n_tokens)
            mig_delay[m.src] = mig_delay.get(m.src, 0.0) + t

        # prefill batches
        for b in plan.prefill:
            for r in b.requests:
                if r in self.pending:
                    self.pending.remove(r)
                r.phase = Phase.PREFILL
                if r.prefill_start is None:
                    r.prefill_start = self.clock
            # drop annexed instances from stalled ready groups
            for g in self.ready_decode:
                g.instances = [i for i in g.instances if i not in b.instances]
            lens = [r.input_len for r in b.requests]
            dur = self.sib.prefill_time(b.dop, lens, b.instances)
            dur += max((mig_delay.get(i, 0.0) for i in b.instances), default=0.0)
            end = self.clock + dur
            self._occupy(b.instances, end)
            self.metrics.prefill_iters += 1
            self._push(end, "prefill_done", b)

        # decode batches (one iteration each; greedy execution emerges from
        # faster groups re-entering the queue sooner)
        launched = []
        soonest_end = min(self._running_decode_ends.values(), default=None)
        for g in plan.decode:
            if not g.instances:
                continue  # stalled (preempted) — retried next round
            sum_kv = sum(r.seq_len for r in g.requests)
            dur = self.sib.decode_time(
                g.dop, len(g.requests), sum_kv, g.instances
            )
            # batch-consolidation hold: if another decode group finishes
            # within a fraction of our iteration, wait and merge with it at
            # that boundary (shared weight read; zero-copy under multi-master)
            if (
                soonest_end is not None
                and soonest_end - self.clock < 0.3 * dur
            ):
                continue
            end = self.clock + dur
            self._occupy(g.instances, end)
            for r in g.requests:
                r.decode_exec_time += dur
            # q-broadcast volume (multi-master): q + partial returns
            self.metrics.q_broadcast_bytes += (
                2 * len(g.requests) * self.cfg.n_heads * self.cfg.head_dim
                * 2 * max(g.dop - 1, 0)
            )
            self.metrics.decode_iters += 1
            self._running_decode_ends[id(g)] = end
            self._push(end, "decode_done", g)
            launched.append(g)
        for g in launched:
            for rg in list(self.ready_decode):
                if set(r.rid for r in rg.requests) & set(
                    r.rid for r in g.requests
                ):
                    self.ready_decode.remove(rg)

    # --------------------------------------------------------- prefill done
    def _on_prefill_done(self, batch: PrefillBatch) -> None:
        # proactive scale-down: KV lands in the already-reserved slots of the
        # target group during the ring pass — ZERO migration bytes.
        if self.real:
            self._real_prefill(batch)
        for r in batch.requests:
            r.prefill_end = self.clock
            r.phase = Phase.DECODE
            r.generated += 1  # prefill emits the first token
            if not self.real:
                r.output_tokens.append(self._sample_token())
        done = [r for r in batch.requests if r.done]
        live = [r for r in batch.requests if not r.done]
        for r in done:
            self._finish_request(r)
            if r.norm_output_latency():
                self.manager.note_finished_decode(r.norm_output_latency())
        if live:
            masters = self.manager._assign_masters(live, batch.scale_down_to)
            self.ready_decode.append(
                DecodeBatch(live, list(batch.scale_down_to), masters)
            )

    # ---------------------------------------------------------- decode done
    def _on_decode_done(self, g: DecodeBatch) -> None:
        self._running_decode_ends.pop(id(g), None)
        if self.real:
            self._real_decode(g)
        done, live = [], []
        for r in g.requests:
            # the processed token's position (its KV is appended now)
            pos = r.seq_len - 1
            r.generated += 1
            if not self.real:
                r.output_tokens.append(self._sample_token())
            placed = False
            order = [g.masters.get(r.rid, g.instances[0])] + [
                i for i in g.instances if i != g.masters.get(r.rid)
            ] + [
                i for i in range(self.n)
                if i not in g.instances and i not in self.failed
            ]
            for inst in order:
                try:
                    self.pool.pools[inst].alloc(r.rid, [pos])
                    if self.real and r.rid in self._pending_kv:
                        k_new, v_new = self._pending_kv.pop(r.rid)
                        self.pool.pools[inst].fill(r.rid, [pos], k_new, v_new)
                    placed = True
                    break
                except OutOfSlots:
                    continue
            if not placed:
                # fleet-wide OOM: evict & requeue (counts as recompute)
                self.pool.free_request(r.rid)
                r.n_evictions += 1
                r.phase = Phase.PENDING
                r.input_len = r.seq_len
                r.prefill_end = None
                self.pending.append(r)
                continue
            (done if r.done else live).append(r)
        for r in done:
            self._finish_request(r)
            if r.norm_output_latency():
                self.manager.note_finished_decode(r.norm_output_latency())
            self._real_cache.pop(r.rid, None)
        if live:
            self.ready_decode.append(DecodeBatch(live, g.instances, g.masters))

    # ----------------------------------------------------------- real compute
    def _real_prefill(self, batch: PrefillBatch) -> None:
        import jax.numpy as jnp

        for r in batch.requests:
            toks = jnp.asarray(np.asarray(r.prompt, np.int32)[None])
            logits, cache = self.model.prefill(self.params, {"tokens": toks})
            r.output_tokens.append(self._sample_token(np.asarray(logits[0, -1])))
            if cache.k is not None:
                k = np.asarray(cache.k[:, 0], np.float32)  # [L, T, KVH, D]
                v = np.asarray(cache.v[:, 0], np.float32)
                assign = batch.placement[r.rid]
                for inst, positions in assign.items():
                    if positions:
                        self.pool.pools[inst].fill(
                            r.rid, positions, k[:, positions], v[:, positions]
                        )
            if cache.ssm is not None:
                self._real_cache[r.rid] = cache.ssm

    def _real_decode(self, g: DecodeBatch) -> None:
        if self._paged_impl is not None and self.pool.pools[0].store_values:
            return self._real_decode_paged(g)
        return self._real_decode_serial(g)

    def _device_kv(self, pool):
        """Incrementally-synced device mirror of one pool's (K, V, slot_pos)
        storage.  Steady-state decode uploads only the slots written since
        the last iteration (one per request), not the pool."""
        import jax
        import jax.numpy as jnp

        full, dirty = pool.consume_dirty()
        cur = self._kv_mirror.get(pool.instance_id)
        if cur is None or full:
            cur = (jnp.asarray(pool.k), jnp.asarray(pool.v),
                   jnp.asarray(pool.slot_pos))
        elif len(dirty):
            if self._kv_scatter is None:
                # donation keeps the scatter O(dirty) and allocation-free on
                # accelerators; CPU doesn't implement donation and falls back
                # to a copy
                donate = (0, 1, 2) if jax.default_backend() != "cpu" else ()
                self._kv_scatter = jax.jit(
                    lambda kd, vd, pd, idx, kn, vn, pn: (
                        kd.at[:, idx].set(kn), vd.at[:, idx].set(vn),
                        pd.at[idx].set(pn),
                    ),
                    donate_argnums=donate,
                )
            # pad the index vector to a power-of-two bucket (duplicating the
            # last slot is idempotent) so jit compiles one scatter per bucket
            # instead of one per distinct dirty count
            n = len(dirty)
            bucket = 1 << (n - 1).bit_length()
            idx = np.concatenate([dirty, np.full(bucket - n, dirty[-1])])
            cur = self._kv_scatter(
                cur[0], cur[1], cur[2], jnp.asarray(idx),
                jnp.asarray(pool.k[:, idx]), jnp.asarray(pool.v[:, idx]),
                jnp.asarray(pool.slot_pos[idx]),
            )
        self._kv_mirror[pool.instance_id] = cur
        return cur

    def _real_decode_paged(self, g: DecodeBatch) -> None:
        """Gather-free batched decode: ONE model step for the whole group;
        per layer, one paged-kernel launch per instance over the pool storage
        in place (block tables), partials LSE-merged multi-master style."""
        import jax.numpy as jnp

        from repro.core.paged_decode import PagedShard
        from repro.models.transformer import Cache

        rids = [r.rid for r in g.requests]
        n_cached = np.array([r.seq_len - 1 for r in g.requests], np.int32)
        shards, covered = [], np.zeros(len(rids), np.int64)
        for pool in self.pool.pools:
            if pool.instance_id in self.failed:
                continue
            table, lengths = pool.block_table(rids)
            if not lengths.any():
                continue
            covered += lengths
            kdev, vdev, posdev = self._device_kv(pool)
            paged_shape = (pool.n_attn, pool.n_pages, pool.page_size) + kdev.shape[2:]
            shards.append(PagedShard(
                k_pages=kdev.reshape(paged_shape),
                v_pages=vdev.reshape(paged_shape),
                table=jnp.asarray(table),
                lengths=jnp.asarray(lengths),
                # per-slot positions are only consumed by window masking
                pos=(posdev.reshape(pool.n_pages, pool.page_size)
                     if self.cfg.sliding_window else None),
            ))
        # cache holds tokens 0..seq_len-2; the processed token's KV is
        # produced by this step and appended at the master afterwards
        assert (covered == n_cached).all(), (covered, n_cached)
        toks = jnp.asarray([r.output_tokens[-1] for r in g.requests], jnp.int32)
        cache = Cache(length=jnp.asarray(n_cached))
        prev_impl = self.model.attn_impl
        self.model.attn_impl = self._paged_impl
        self._paged_impl.begin_step(shards)
        try:
            logits, _, kvs = self.model.decode(self.params, toks, cache)
        finally:
            self._paged_impl.end_step()
            self.model.attn_impl = prev_impl
        logits = np.asarray(logits)
        for b, r in enumerate(g.requests):
            r.output_tokens.append(self._sample_token(logits[b]))
            if kvs is not None:
                # stash; _on_decode_done fills it once the slot is allocated
                self._pending_kv[r.rid] = (
                    np.asarray(kvs[0][:, b], np.float32),  # [L, 1, KVH, D]
                    np.asarray(kvs[1][:, b], np.float32),
                )

    def _real_decode_serial(self, g: DecodeBatch) -> None:
        """Per-request fallback (recurrent/hybrid state or custom impls)."""
        import jax.numpy as jnp

        from repro.models.transformer import Cache

        for r in g.requests:
            positions, k, v = self.pool.gather_request(r.rid)
            # cache holds tokens 0..seq_len-2; the processed token's KV is
            # produced by this step and appended at the master afterwards
            n_cached = r.seq_len - 1
            if k is not None:
                assert len(positions) == n_cached, (len(positions), n_cached)
            cache = Cache(
                k=jnp.asarray(k[:, None].astype(self.model.dtype)) if k is not None else None,
                v=jnp.asarray(v[:, None].astype(self.model.dtype)) if v is not None else None,
                length=jnp.asarray([n_cached], jnp.int32),
                ssm=self._real_cache.get(r.rid),
            )
            last_tok = r.output_tokens[-1]
            logits, new_cache, kvs = self.model.decode(
                self.params, jnp.asarray([last_tok], jnp.int32), cache
            )
            r.output_tokens.append(self._sample_token(np.asarray(logits[0])))
            if new_cache.ssm is not None:
                self._real_cache[r.rid] = new_cache.ssm
            if kvs is not None:
                # stash; _on_decode_done fills it once the slot is allocated
                self._pending_kv[r.rid] = (
                    np.asarray(kvs[0][:, 0], np.float32),  # [L, 1, KVH, D]
                    np.asarray(kvs[1][:, 0], np.float32),
                )

    def _apply_failure(self, inst: int) -> None:
        super()._apply_failure(inst)
        # drop the failed instance's device KV mirror (a full pool-sized
        # copy) — it will be rebuilt from scratch if the instance rejoins
        self._kv_mirror.pop(inst, None)

    def _drop_request_state(self, rids) -> None:
        for rid in rids:
            self._real_cache.pop(rid, None)

    def _checkpoint_extra(self):
        return {"ready_decode": self.ready_decode}

    def _restore_extra(self, extra) -> None:
        if extra:
            self.ready_decode = extra["ready_decode"]

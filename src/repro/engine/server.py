"""Serving engines: event-driven iteration loop over elastic instances.

`BaseServingEngine` owns the clock, the event queue, the distributed KV pool,
the SIB and metrics; `LoongServeEngine` drives it with the four-step global
manager (ESP). Baselines (repro.baselines) subclass the same loop so the
comparison is apples-to-apples: identical cost model, pool accounting and
request lifecycle — only the policy differs.

Two compute modes:
  * sim  — tokens are synthetic; iteration durations come from the SIB
           analytical model (the paper's own scheduling signal). This scales
           to paper-sized workloads (Fig. 10-12) on CPU.
  * real — a reduced model actually prefills/decodes on CPU; KV tensors flow
           through the pools exactly as the plans dictate (used by tests and
           the runnable examples; also the source of SIB profiles).

Fault tolerance: `fail_instance` drops an instance and its KV shards.
Affected requests are SALVAGED where possible — surviving instances' KV
stays registered, only the dead rank's stripe is re-prefilled by a recovery
chain, and the request resumes at its cursor (elastic scale-down as the
fault path; `RecoveryState`/`_try_salvage`) — with full prefill recompute
as the fallback; `join_instance` adds fresh capacity; `checkpoint`/`restore`
snapshot the full serving state including in-flight unified chains.
Elasticity is the recovery mechanism (DESIGN.md §7).
"""
from __future__ import annotations

import heapq
import itertools
import pickle
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from repro.configs.base import ModelConfig
from repro.engine.request import Phase, Request
from repro.kvcache.distributed import DistributedKVPool
from repro.kvcache.pool import OutOfSlots
from repro.manager.scheduler import (
    DecodeBatch,
    GlobalManager,
    ManagerConfig,
    PrefillBatch,
    UnifiedWork,
)
from repro.manager.sib import SIB, HardwareSpec


@dataclass
class EngineMetrics:
    finished: List[Request] = field(default_factory=list)
    rejected: int = 0
    scaling_migration_bytes: int = 0  # ESP transitions: MUST stay 0
    reactive_migration_bytes: int = 0
    q_broadcast_bytes: int = 0
    prefill_iters: int = 0
    decode_iters: int = 0
    # degradation-path counters (observability for planner/pool divergence
    # and the chaos soak's determinism fingerprint)
    dropped_migrations: int = 0  # planner-requested moves the pool refused
    dispatch_retries: int = 0  # transient dispatch faults absorbed by retry
    dispatch_declared_failures: int = 0  # retry budget exhausted -> failure
    nan_quarantined: int = 0  # poisoned-logit requests requeued
    preemptions: int = 0  # decode-OOM evictions (victim or self)
    recomputed_tokens: int = 0  # previously-computed tokens lost + re-prefilled
    salvaged_tokens: int = 0  # computed tokens retained in place by fault salvage
    backpressure_deferrals: int = 0  # scheduling rounds that deferred admits

    def summary(self) -> Dict[str, float]:
        fin = [r for r in self.finished if r.finish_time is not None]
        out: Dict[str, float] = {
            "n_finished": len(fin),
            "rejected": self.rejected,
            "scaling_migration_bytes": self.scaling_migration_bytes,
            "reactive_migration_bytes": self.reactive_migration_bytes,
            "prefill_iters": self.prefill_iters,
            "decode_iters": self.decode_iters,
            "dropped_migrations": self.dropped_migrations,
            "dispatch_retries": self.dispatch_retries,
            "dispatch_declared_failures": self.dispatch_declared_failures,
            "nan_quarantined": self.nan_quarantined,
            "preemptions": self.preemptions,
            "recomputed_tokens": self.recomputed_tokens,
            "salvaged_tokens": self.salvaged_tokens,
            "backpressure_deferrals": self.backpressure_deferrals,
        }
        if fin:
            for name, fn in [
                ("norm_e2e", lambda r: r.norm_e2e_latency()),
                ("norm_input", lambda r: r.norm_input_latency()),
                ("norm_output", lambda r: r.norm_output_latency()),
            ]:
                vals = [fn(r) for r in fin if fn(r) is not None]
                if vals:
                    out[f"{name}_mean"] = float(np.mean(vals))
                    out[f"{name}_p90"] = float(np.percentile(vals, 90))
            span = max(r.finish_time for r in fin) - min(r.arrival for r in fin)
            toks = sum(r.seq_len for r in fin)
            out["throughput_tok_s"] = toks / max(span, 1e-9)
        return out

    def snapshot(self) -> Dict[str, float]:
        """`summary()` plus derived recovery efficiency: `salvage_ratio` is
        the fraction of failure-touched computed KV that was retained in
        place instead of re-prefilled (1.0 = every failure was absorbed by
        pure scale-down resume, 0.0 = every failure fell back to full
        recompute; 0.0 also when no failure touched any computed KV)."""
        out = self.summary()
        denom = self.salvaged_tokens + self.recomputed_tokens
        out["salvage_ratio"] = self.salvaged_tokens / denom if denom else 0.0
        return out


@dataclass
class RecoveryState:
    """Per-request elastic fault-recovery bookkeeping (DESIGN.md §7).

    A SALVAGING request keeps its surviving KV shards registered in the
    pools; ``spans`` are the dead rank's *computed* stripe runs, consumed
    front-to-back by the recovery chain's hole chunks (a span start is the
    chunk start, so positions below it are fully covered — the unified
    PREFIX partial reads the salvaged pages).  ``expected`` is the
    allocated-coverage target ({0..expected-1}; the lost positions are
    re-reserved on survivors at salvage time, so invariant I3 validates
    this declared coverage during the relaxation window).  When the spans
    drain, ``resume_decode`` requests re-enter DECODE at their cursor
    (RESUMING -> running, no token emitted); mid-prefill requests simply
    continue frontier chunking."""

    spans: List[Tuple[int, int]]
    expected: int
    resume_decode: bool
    salvaged: int


_event_seq = itertools.count()

#: bumped whenever the checkpoint layout changes incompatibly; `restore()`
#: refuses stamps it does not understand instead of dying mid-unpickle later
CHECKPOINT_FORMAT_VERSION = 1


class CheckpointError(RuntimeError):
    """A checkpoint could not be restored: missing file, truncated/corrupt
    pickle, or an incompatible format version.  The message always names the
    offending path (and both versions on a mismatch)."""


class BaseServingEngine:
    def __init__(
        self,
        cfg: ModelConfig,
        n_instances: int,
        capacity_per_instance: int,
        *,
        hw: Optional[HardwareSpec] = None,
        store_values: bool = False,
        model=None,
        params=None,
        seed: int = 0,
        page_size: int = 1,
        admission_watermark: float = 0.0,
        dispatch_max_retries: int = 3,
        dispatch_backoff: float = 1e-3,
    ):
        self.cfg = cfg
        self.n = n_instances
        self.capacity = capacity_per_instance
        self.page_size = page_size
        self.pool = DistributedKVPool(cfg, n_instances, capacity_per_instance,
                                      store_values, page_size)
        self.sib = SIB(cfg, hw)
        self.clock = 0.0
        self.pending: List[Request] = []
        self.events: List[Tuple[float, int, str, Any]] = []
        self.busy_until: Dict[int, float] = {i: 0.0 for i in range(n_instances)}
        self.failed: Set[int] = set()
        self.metrics = EngineMetrics()
        self.model = model
        self.params = params
        self.real = model is not None
        self.rng = np.random.default_rng(seed)
        self._req_index: Dict[int, Request] = {}
        # admission backpressure: defer NEW prefills while fleet-wide free
        # slots sit below this fraction of alive capacity (0 = disabled) —
        # decode keeps draining and frees slots instead of the scheduler
        # admitting prompts that would immediately OOM-preempt
        self.admission_watermark = admission_watermark
        # bounded retry-with-backoff on TransientDispatchError before the
        # dispatching instance is declared failed
        self.dispatch_max_retries = dispatch_max_retries
        self.dispatch_backoff = dispatch_backoff
        # dedicated deterministic stream for dispatch-backoff jitter: drawing
        # from `self.rng` would shift the sim token stream (and the chaos
        # monkey owns its own rng), so same-seed replay stays bit-for-bit
        self._backoff_rng = np.random.default_rng([seed, 0xBAC0FF])
        # rid -> RecoveryState for requests whose failure was absorbed by
        # KV salvage + scale-down resume instead of full recompute
        self._recovering: Dict[int, RecoveryState] = {}
        # observers called as hook(engine, kind, payload) after EVERY handled
        # event (chaos injection, invariant sanitizer, tracing)
        self.event_hooks: List[Any] = []
        # rids whose NEXT logits row is overwritten with NaN (chaos
        # injection); the value guard moves them into _quarantine
        self._logit_poison: Set[int] = set()
        # rids whose last logits were non-finite: requeued for recompute at
        # the next completion processing instead of emitting garbage
        self._quarantine: Set[int] = set()

    # ----------------------------------------------------------- submission
    def submit(self, req: Request, at: Optional[float] = None) -> None:
        t = req.arrival if at is None else at
        req.arrival = t
        cap_total = self.capacity * (self.n - len(self.failed))
        if req.max_total_len > cap_total:
            self.metrics.rejected += 1
            return
        self._push(t, "arrival", req)
        self._req_index[req.rid] = req

    def _push(self, t: float, kind: str, payload: Any) -> None:
        heapq.heappush(self.events, (t, next(_event_seq), kind, payload))

    # ------------------------------------------------------------ main loop
    def _has_live_work(self) -> bool:
        """Unfinished work that scheduling could still advance (subclasses
        extend with their own queues)."""
        return bool(self.pending)

    def _next_horizon(self) -> Optional[float]:
        """Earliest future time an alive instance frees up, or None.  Under
        normal operation every busy interval is backed by a queued completion
        event; this differs only when busy_until was inflated externally
        (straggler injection, backoff charges)."""
        ts = [
            t for i, t in self.busy_until.items()
            if i not in self.failed and t > self.clock and t != float("inf")
        ]
        return min(ts, default=None)

    def run(self, max_time: float = float("inf"), max_events: int = 2_000_000):
        n_ev = 0
        while n_ev < max_events:
            if not self.events:
                # liveness: the queue drained but live work remains (e.g. a
                # straggler-inflated busy_until with no completion event
                # behind it, or a stalled instance-less decode group).  Tick
                # forward to the next idle horizon and re-enter scheduling
                # instead of abandoning unfinished requests.
                t = self._next_horizon()
                if t is None or t > max_time or not self._has_live_work():
                    break
                self._push(t, "tick", None)
            t, seq, kind, payload = heapq.heappop(self.events)
            if t > max_time:
                # keep the event for a later run()/restore
                heapq.heappush(self.events, (t, seq, kind, payload))
                break
            self.clock = max(self.clock, t)
            self._handle(kind, payload)
            for hook in list(self.event_hooks):
                hook(self, kind, payload)
            n_ev += 1
        return self.metrics

    def _handle(self, kind: str, payload: Any) -> None:
        if kind == "arrival":
            self.pending.append(payload)
            payload.phase = Phase.PENDING
        elif kind == "prefill_done":
            self._on_prefill_done(payload)
        elif kind == "decode_done":
            self._on_decode_done(payload)
        elif kind == "unified_done":
            self._on_unified_done(payload)
        elif kind == "fail":
            self._apply_failure(payload)
        elif kind == "join":
            self._apply_join(payload)
        if (
            kind == "arrival"
            and self.events
            and self.events[0][0] <= self.clock
            and self.events[0][2] == "arrival"
        ):
            # same-instant arrival burst: defer planning until the last
            # arrival of the burst so the whole burst is admitted in ONE
            # scheduling pass (one prefill batch / one decode group) instead
            # of planning after each arrival with a partial view
            return
        self._try_schedule()

    # hooks ------------------------------------------------------------
    def _try_schedule(self) -> None:  # pragma: no cover - abstract
        raise NotImplementedError

    def _on_prefill_done(self, batch) -> None:  # pragma: no cover
        raise NotImplementedError

    def _on_decode_done(self, batch) -> None:  # pragma: no cover
        raise NotImplementedError

    def _on_unified_done(self, work) -> None:  # pragma: no cover
        raise NotImplementedError

    # ------------------------------------------------------------- helpers
    def idle_instances(self) -> List[int]:
        return [
            i
            for i in range(self.n)
            if i not in self.failed and self.busy_until[i] <= self.clock + 1e-12
        ]

    def _occupy(self, instances: Sequence[int], until: float) -> None:
        for i in instances:
            self.busy_until[i] = until

    def _finish_request(self, req: Request) -> None:
        req.phase = Phase.FINISHED
        req.finish_time = self.clock
        self.pool.free_request(req.rid)
        self.metrics.finished.append(req)

    def _sample_token(self, logits=None) -> int:
        if logits is None:
            return int(self.rng.integers(0, self.cfg.vocab_size))
        return int(np.argmax(logits))

    # -------------------------------------------------- fault tolerance API
    def fail_instance(self, inst: int, at: Optional[float] = None) -> None:
        self._push(at if at is not None else self.clock, "fail", inst)

    def join_instance(self, inst: int, at: Optional[float] = None) -> None:
        self._push(at if at is not None else self.clock, "join", inst)

    def _requeue_for_recompute(self, req: Request,
                               lost: Optional[int] = None) -> None:
        """Evicted-KV recovery: the request re-enters prefill over everything
        generated so far.  The emitted tokens become part of the new prompt
        (in real mode literally, so the recompute reproduces the exact
        sequence) and move from the generation budget into the input — KV
        accounting stays exact (seq_len == recomputed prompt + new tokens,
        no double count of the folded prefix).

        ``lost`` is the recompute charge: previously-COMPUTED tokens whose
        KV is being discarded.  Defaults to the full computed span —
        ``seq_len`` for decode-phase requests, the chunk cursor for
        mid-prefill ones — minus any spans a fault salvage already charged
        (its surviving `RecoveryState` holes were never recomputed)."""
        if lost is None:
            rec = self._recovering.get(req.rid)
            if rec is not None:
                base = rec.expected if rec.resume_decode else req.prefill_pos
                lost = max(base - sum(e - s for s, e in rec.spans), 0)
            elif req.phase is Phase.DECODE:
                lost = req.seq_len
            else:
                lost = req.prefill_pos
        self.metrics.recomputed_tokens += lost
        self._recovering.pop(req.rid, None)
        req.n_evictions += 1
        req.phase = Phase.PENDING
        if req.prompt is not None and len(req.prompt) < req.seq_len:
            need = req.seq_len - len(req.prompt)
            req.prompt = list(req.prompt) + list(req.output_tokens[-need:])
        req.input_len = req.seq_len  # recompute over everything so far
        req.max_new_tokens -= req.generated  # folded tokens are input now
        req.generated = 0
        req.prefill_end = None
        req.prefill_pos = 0  # unified chunk cursor restarts with the prefill

    def _apply_failure(self, inst: int) -> None:
        self.failed.add(inst)
        self.busy_until[inst] = float("inf")
        # KV shards on the instance are lost.  Elastic fault recovery first
        # (`_try_salvage`, engine-specific): survivors keep their shards of
        # an affected request registered and only the dead rank's stripe is
        # re-prefilled by a recovery chain.  Requests salvage cannot cover
        # fall back to full recompute (generated prefix becomes part of the
        # new prompt).
        affected = list(self.pool.pools[inst].requests())
        salvaged: List[Request] = []
        for rid in affected:
            req = self._req_index.get(rid)
            if (
                req is not None
                and req.phase is not Phase.FINISHED
                and self._try_salvage(req, inst)
            ):
                salvaged.append(req)
                continue
            self.pool.free_request(rid)
            if req is None or req.phase in (Phase.FINISHED,):
                continue
            self._requeue_for_recompute(req)
            if req not in self.pending:
                self.pending.append(req)
        keep = {r.rid for r in salvaged}
        self._drop_request_state([rid for rid in affected if rid not in keep])
        if salvaged:
            self._launch_recovery(salvaged)

    def _try_salvage(self, req: Request, inst: int) -> bool:
        """Attempt KV salvage + scale-down resume for one request affected
        by the failure of `inst`.  Base engines (the baselines) have no
        recovery chain — always full recompute."""
        return False

    def _launch_recovery(self, reqs: List[Request]) -> None:
        """Launch the recovery chain for this failure event's salvaged
        requests (engine-specific; unreachable while `_try_salvage` says
        no)."""
        raise NotImplementedError

    def _apply_join(self, inst: int) -> None:
        if inst in self.failed:
            self.failed.discard(inst)
            self.busy_until[inst] = self.clock
        elif inst >= self.n:  # truly new instance: grow the registry
            for j in range(self.n, inst + 1):
                self.pool.pools.append(
                    type(self.pool.pools[0])(
                        self.cfg, self.capacity, j,
                        self.pool.pools[0].store_values, self.page_size,
                    )
                )
                self.busy_until[j] = self.clock
            self.n = inst + 1

    def _drop_request_state(self, rids: Sequence[int]) -> None:
        """Subclasses drop any per-request runtime state for re-queued rids."""

    # ------------------------------------------------------- checkpointing
    def checkpoint(self, path: str) -> None:
        state = {
            "format_version": CHECKPOINT_FORMAT_VERSION,
            "clock": self.clock,
            "pending": self.pending,
            "events": self.events,
            "busy_until": self.busy_until,
            "failed": self.failed,
            "metrics": self.metrics,
            "req_index": self._req_index,
            "pool_state": [p.state_dict() for p in self.pool.pools],
            "extra": self._checkpoint_extra(),
        }
        with open(path, "wb") as f:
            pickle.dump(state, f)

    def restore(self, path: str) -> None:
        try:
            with open(path, "rb") as f:
                state = pickle.load(f)
        except FileNotFoundError as e:
            raise CheckpointError(f"checkpoint not found: {path}") from e
        except (pickle.UnpicklingError, EOFError, AttributeError,
                ImportError) as e:
            raise CheckpointError(
                f"checkpoint {path} is truncated or corrupt: {e}"
            ) from e
        if not isinstance(state, dict) or "format_version" not in state:
            raise CheckpointError(
                f"checkpoint {path} carries no format-version stamp "
                "(pre-versioned or foreign file) — refusing to restore"
            )
        got = state["format_version"]
        if got != CHECKPOINT_FORMAT_VERSION:
            raise CheckpointError(
                f"checkpoint {path} has format version {got}, this engine "
                f"supports {CHECKPOINT_FORMAT_VERSION}"
            )
        missing = {
            "clock", "pending", "events", "busy_until", "failed", "metrics",
            "req_index", "pool_state",
        } - set(state)
        if missing:
            raise CheckpointError(
                f"checkpoint {path} is missing keys {sorted(missing)}"
            )
        self.clock = state["clock"]
        self.pending = state["pending"]
        self.events = state["events"]
        self.busy_until = state["busy_until"]
        self.failed = state["failed"]
        self.metrics = state["metrics"]
        self._req_index = state["req_index"]
        for p, ps in zip(self.pool.pools, state["pool_state"]):
            p.load_state_dict(ps)
        # transient injection state never survives a restore
        self._logit_poison.clear()
        self._quarantine.clear()
        self._restore_extra(state.get("extra"))

    def _checkpoint_extra(self) -> Any:
        return None

    def _restore_extra(self, extra: Any) -> None:
        pass


# ======================================================================= ESP


class LoongServeEngine(BaseServingEngine):
    """The paper's system: ESP + four-step global manager.

    Real-mode compute is delegated to an executor (engine/executor.py):
    `LocalExecutor` (default) runs the in-process packed/paged paths;
    `MeshExecutor` (``executor="mesh"`` or an explicit ``mesh=``) runs the
    DoP>1 packed ring prefill AND the batched paged decode iteration as
    shard_map programs on a real ("data", "model") device mesh with
    per-instance KV mirrors bound to their own data-shard devices (the
    decode LSE-merge is a pmax+psum collective).  The engine itself holds
    NO kernel dispatch — only scheduling, lifecycle and accounting."""

    def __init__(self, *args, mcfg: Optional[ManagerConfig] = None,
                 executor: Optional[str] = None, mesh=None, **kwargs):
        super().__init__(*args, **kwargs)
        self.manager = GlobalManager(self.cfg, self.sib, self.pool,
                                     mcfg or ManagerConfig())
        self.ready_decode: List[DecodeBatch] = []
        self._real_cache: Dict[int, Any] = {}  # rid -> recurrent state (real)
        self._pending_kv: Dict[int, Any] = {}  # rid -> new kv awaiting alloc
        self._running_decode_ends: Dict[int, float] = {}  # gid -> end time
        self._decode_launch_seq: Dict[int, Dict[int, int]] = {}  # gid -> rid -> seq
        self._prefill_launch_epoch: Dict[int, Dict[int, int]] = {}  # bid -> rid -> n_evictions
        # rids currently riding an in-flight unified chain (prefill chunks
        # or interleaved decode rows): the scheduler must not launch them in
        # a parallel decode group while the chain owns their iteration
        self._in_unified: Set[int] = set()
        # in-flight chains (id(work) -> the UnifiedWork): decode groups
        # overlapping one wait in `ready_decode` for the chain's next chunk
        # boundary and ride the fused iteration instead of launching a
        # competing standalone iteration on the same instances; the failure
        # path reaches in-flight rider groups through it for sub-mesh
        # re-formation, and checkpoints round-trip it
        self._active_unified: Dict[int, UnifiedWork] = {}
        self.executor = None
        if self.real:
            from repro.engine.executor import LocalExecutor, MeshExecutor

            if mesh is not None or executor == "mesh":
                self.executor = MeshExecutor(self, mesh)
            else:
                assert executor in (None, "local"), executor
                self.executor = LocalExecutor(self)

    # ------------------------------------------------------------- schedule
    def _has_live_work(self) -> bool:
        return bool(self.pending) or bool(self.ready_decode)

    def _backpressured(self) -> bool:
        """Admission backpressure watermark: True while fleet-wide free KV
        slots sit below `admission_watermark` × alive capacity.  New prefills
        are deferred (the pending queue is hidden from the planner) so the
        decode fleet drains and frees slots, instead of admitting prompts
        that would immediately bounce off the pool and OOM-preempt running
        requests."""
        if self.admission_watermark <= 0.0:
            return False
        alive = [
            p for p in self.pool.pools if p.instance_id not in self.failed
        ]
        total = sum(p.capacity for p in alive)
        free = sum(p.free_slots for p in alive)
        return free < self.admission_watermark * total

    def _try_schedule(self) -> None:
        for _ in range(4):  # drain: admit more work onto leftover instances
            idle = [
                i
                for i in self.idle_instances()
                if not any(i in g.instances for g in self.ready_decode)
            ]
            if not idle and not self.ready_decode:
                return
            if not self.pending and not self.ready_decode:
                return
            self.pending.sort(key=lambda r: r.arrival)
            pending_view = self.pending
            if pending_view and self._backpressured():
                self.metrics.backpressure_deferrals += 1
                pending_view = []
                if not self.ready_decode:
                    return
            plan = self.manager.schedule(
                pending_view, self.ready_decode, idle, self.clock
            )
            if not plan.prefill and pending_view:
                # second-chance admission at the iteration boundary: groups
                # sitting in `ready_decode` are BETWEEN iterations right
                # now, so their instances are legal placement targets for a
                # pending prompt the strictly-idle pass could not admit
                # (iteration-level continuous batching).  The sequential
                # path stalls the stripped groups for the whole monolithic
                # prefill; the unified path fuses them into the chain as
                # riders instead.  Safe to discard the first plan: a plan
                # with no prefill batches reserved nothing in the pool.
                boundary = [
                    i for i in self.idle_instances() if i not in idle
                ]
                if boundary:
                    # delay-execution's premise ("wait for busy instances
                    # to free up") is already satisfied at the boundary —
                    # don't let it defer the retry a second time
                    saved = self.manager.mcfg.enable_delay_execution
                    self.manager.mcfg.enable_delay_execution = False
                    try:
                        retry = self.manager.schedule(
                            pending_view, self.ready_decode,
                            idle + boundary, self.clock,
                        )
                    finally:
                        self.manager.mcfg.enable_delay_execution = saved
                    if retry.prefill:
                        plan = retry
            if not plan.prefill and not plan.decode and not plan.migrations:
                return
            self._execute_plan(plan)

    def _execute_plan(self, plan) -> None:
        # migrations (allocation-step KV moves — reactive, counted)
        mig_delay: Dict[int, float] = {}
        for m in plan.migrations:
            try:
                moved = self.pool.migrate_request(m.rid, m.src, m.dsts)
            except OutOfSlots:
                # planner/pool divergence: the move it asked for no longer
                # fits — drop it (the request keeps serving from `src`) but
                # COUNT it so the divergence is observable in summary()
                self.metrics.dropped_migrations += 1
                continue
            self.metrics.reactive_migration_bytes += moved
            t = self.sib.migration_time(m.n_tokens)
            mig_delay[m.src] = mig_delay.get(m.src, 0.0) + t

        # prefill batches
        for b in plan.prefill:
            for r in b.requests:
                if r in self.pending:
                    self.pending.remove(r)
                r.phase = Phase.PREFILL
                r.prefill_pos = 0
                if r.prefill_start is None:
                    r.prefill_start = self.clock
            if self._unified_eligible(b):
                # unified continuous batching: instead of annexing the decode
                # groups' instances for one long prefill (stalling their
                # token flow), FUSE the groups that would stall — instance
                # overlap or already stalled — into a chain of chunked
                # prefill+decode iterations
                fused = [
                    g for g in self.ready_decode
                    if set(g.instances) & set(b.instances) or not g.instances
                ]
                for g in fused:
                    self.ready_decode.remove(g)
                for g in self.ready_decode:
                    g.instances = [
                        i for i in g.instances if i not in b.instances
                    ]
                mig = max(
                    (mig_delay.get(i, 0.0) for i in b.instances), default=0.0
                )
                self._launch_unified(UnifiedWork(b, fused), extra_delay=mig)
                continue
            # drop annexed instances from stalled ready groups
            for g in self.ready_decode:
                g.instances = [i for i in g.instances if i not in b.instances]
            lens = [r.input_len for r in b.requests]
            dur = self.sib.prefill_time(b.dop, lens, b.instances)
            dur += max((mig_delay.get(i, 0.0) for i in b.instances), default=0.0)
            end = self.clock + dur
            self._occupy(b.instances, end)
            self.metrics.prefill_iters += 1
            # launch-time eviction-epoch stamp: prefill_done uses it to drop
            # requests requeued (and possibly re-prefilled) by an in-flight
            # fail_instance — their reserved placement slots are gone
            self._prefill_launch_epoch[id(b)] = {
                r.rid: r.n_evictions for r in b.requests
            }
            self._push(end, "prefill_done", b)

        # decode batches (one iteration each; greedy execution emerges from
        # faster groups re-entering the queue sooner)
        launched = []
        soonest_end = min(self._running_decode_ends.values(), default=None)
        # instances a prefill batch of THIS plan occupies: the manager built
        # plan.decode before the annexation above stripped the ready groups,
        # so mirror the strip on the fresh plan copies — an annexed group
        # must stall (or ride the unified chain), not relaunch alongside
        # the prefill on the instances it just lost
        taken = {i for pb in plan.prefill for i in pb.instances}
        for g in plan.decode:
            if taken:
                g.instances = [i for i in g.instances if i not in taken]
            if not g.instances:
                continue  # stalled (preempted) — retried next round
            if any(r.rid in self._in_unified for r in g.requests):
                continue  # riding an in-flight unified chain this iteration
            if any(
                set(g.instances) & set(w.alive_instances(self.failed))
                for w in self._active_unified.values()
            ):
                # a unified chain owns (some of) these instances: hold the
                # group in ready_decode so the chain absorbs it at its next
                # chunk boundary instead of racing a standalone iteration
                continue
            sum_kv = sum(r.seq_len for r in g.requests)
            dur = self.sib.decode_time(
                g.dop, len(g.requests), sum_kv, g.instances
            )
            # batch-consolidation hold: if another decode group finishes
            # within a fraction of our iteration, wait and merge with it at
            # that boundary (shared weight read; zero-copy under multi-master)
            if (
                soonest_end is not None
                and soonest_end - self.clock < 0.3 * dur
            ):
                continue
            end = self.clock + dur
            self._occupy(g.instances, end)
            for r in g.requests:
                r.decode_exec_time += dur
            # q-broadcast volume (multi-master): q + partial returns
            self.metrics.q_broadcast_bytes += (
                2 * len(g.requests) * self.cfg.n_heads * self.cfg.head_dim
                * 2 * max(g.dop - 1, 0)
            )
            self.metrics.decode_iters += 1
            self._running_decode_ends[id(g)] = end
            # launch-time sequence stamp: decode_done uses it to tell "still
            # this iteration's request" from "requeued by a failure and
            # already recomputed into a new group" (seq_len is monotone and
            # only moves when a prefill/decode completion is processed)
            self._decode_launch_seq[id(g)] = {r.rid: r.seq_len for r in g.requests}
            self._push(end, "decode_done", g)
            launched.append(g)
        for g in launched:
            for rg in list(self.ready_decode):
                if set(r.rid for r in rg.requests) & set(
                    r.rid for r in g.requests
                ):
                    self.ready_decode.remove(rg)

    # ------------------------------------------------- dispatch fault paths
    def _dispatch_with_retry(self, fn, instances: List[int],
                             point: str) -> bool:
        """Run one executor dispatch with bounded retry-with-backoff on
        `TransientDispatchError` (chaos-injected or a genuinely flaky
        backend).  The raise happens at the dispatch guard BEFORE any compute
        or KV write, so retrying is side-effect-free.  Each retry charges
        exponential backoff to the group's instances in sim-clock time.  On
        budget exhaustion the first alive instance of the group is declared
        failed (routing through the normal `_apply_failure` requeue path) and
        False is returned — the caller requeues whatever that did not
        cover."""
        from repro.kernels import ops

        for attempt in range(self.dispatch_max_retries + 1):
            try:
                ops.check_fault(point + "_dispatch")
                fn()
                return True
            except ops.TransientDispatchError:
                if attempt == self.dispatch_max_retries:
                    break
                self.metrics.dispatch_retries += 1
                pause = self.dispatch_backoff * (2 ** attempt)
                for i in instances:
                    if i not in self.failed:
                        # seeded jitter in [0.5, 1.5) per instance so
                        # simultaneous retries across a group don't
                        # resynchronize into a retry storm; the dedicated
                        # stream keeps same-seed chaos replay bit-for-bit
                        jitter = 0.5 + self._backoff_rng.random()
                        self.busy_until[i] = (
                            max(self.busy_until[i], self.clock) + pause * jitter
                        )
        self.metrics.dispatch_declared_failures += 1
        victim = next((i for i in instances if i not in self.failed), None)
        if victim is not None:
            self._apply_failure(victim)
        return False

    def _drain_quarantine(self, requests: List[Request]) -> List[Request]:
        """Remove NaN-quarantined requests from `requests`, requeueing ONLY
        those for recompute (the rest of the batch is untouched).  Returns
        the surviving requests."""
        poisoned = [r for r in requests if r.rid in self._quarantine]
        if not poisoned:
            return requests
        for r in poisoned:
            self._quarantine.discard(r.rid)
            self.metrics.nan_quarantined += 1
            self._pending_kv.pop(r.rid, None)
            self.pool.free_request(r.rid)
            self._requeue_for_recompute(r)
            if r not in self.pending:
                self.pending.append(r)
        self._drop_request_state([r.rid for r in poisoned])
        return [r for r in requests if r.rid not in {
            p.rid for p in poisoned
        }]

    # --------------------------------------------------------- prefill done
    def _on_prefill_done(self, batch: PrefillBatch) -> None:
        # graceful in-flight failure (mirror of _on_decode_done): requests
        # requeued by a fail_instance between this batch's launch and now
        # lost their reserved placement slots — drop them (the epoch stamp
        # also catches ones already relaunched and back in PREFILL phase).
        epoch = self._prefill_launch_epoch.pop(id(batch), None)
        alive = []
        for r in batch.requests:
            if r.phase is not Phase.PREFILL or (
                epoch is not None and epoch.get(r.rid) != r.n_evictions
            ):
                continue
            if self._placement_lost(batch, r):
                # part of the reserved placement sits on a failed instance
                # (normally _apply_failure already requeued the request; this
                # catches the post-restore case where the epoch stamp was
                # dropped): scattering would silently skip the dead shard and
                # leave partial KV — requeue for recompute instead, mirroring
                # decode_done's stamp check.
                self.pool.free_request(r.rid)
                self._requeue_for_recompute(r)
                if r not in self.pending:
                    self.pending.append(r)
                continue
            alive.append(r)
        if len(alive) < len(batch.requests):
            batch.requests = alive
            batch.instances = [i for i in batch.instances if i not in self.failed]
            batch.scale_down_to = [
                i for i in batch.scale_down_to if i not in self.failed
            ]
            if not alive:
                return
        # proactive scale-down: KV lands in the already-reserved slots of the
        # target group during the ring pass — ZERO migration bytes.
        if self.real:
            ok = self._dispatch_with_retry(
                lambda: self._real_prefill(batch), batch.instances, "prefill"
            )
            if not ok:
                # the prefill never ran: its reserved placement holds no
                # written KV — requeue every request still in PREFILL (ones
                # whose slots sat on the declared-failed instance were
                # already requeued by _apply_failure)
                for r in batch.requests:
                    if r.phase is Phase.PREFILL:
                        self.pool.free_request(r.rid)
                        self._requeue_for_recompute(r)
                        if r not in self.pending:
                            self.pending.append(r)
                return
            # NaN guard tripped inside the executor: quarantined requests
            # got no sampled token — requeue ONLY them, keep the batch
            batch.requests = self._drain_quarantine(batch.requests)
            if not batch.requests:
                return
        for r in batch.requests:
            r.prefill_end = self.clock
            r.phase = Phase.DECODE
            r.generated += 1  # prefill emits the first token
            if not self.real:
                r.output_tokens.append(self._sample_token())
        done = [r for r in batch.requests if r.done]
        live = [r for r in batch.requests if not r.done]
        for r in done:
            self._finish_request(r)
            if r.norm_output_latency():
                self.manager.note_finished_decode(r.norm_output_latency())
        if live:
            # always drop failed instances: an instance can die mid-flight
            # while holding none of this batch's KV, in which case the
            # alive-filter above never rebuilt the instance list — a dead
            # member here would get prefill slots reserved on it next round
            insts = [i for i in batch.scale_down_to if i not in self.failed]
            masters = (
                self.manager._assign_masters(live, insts) if insts else {}
            )
            self.ready_decode.append(DecodeBatch(live, insts, masters))

    # -------------------------------------------- unified continuous batching
    def _unified_eligible(self, b: PrefillBatch) -> bool:
        """A prefill batch runs as a unified chunked chain when the knob is
        set, the executor has the fused path, and every prompt is
        materialized (chunk packing slices real token ids)."""
        return (
            self.real
            and self.manager.mcfg.prefill_chunk_tokens is not None
            and self.executor is not None
            and getattr(self.executor, "supports_unified", False)
            and all(
                r.prompt is not None and len(r.prompt) == r.input_len
                for r in b.requests
            )
        )

    def _pending_spans(self, r: Request) -> List[Tuple[int, int]]:
        """Ascending token spans this request still needs computed: a
        recovering request's lost holes first (each must fully fill before
        any later chunk runs, so prefix coverage below a chunk start stays
        complete), then — unless it resumes straight into decode — the
        normal prefill frontier ``[prefill_pos, input_len)``."""
        rec = self._recovering.get(r.rid)
        spans: List[Tuple[int, int]] = list(rec.spans) if rec is not None else []
        if (
            (rec is None or not rec.resume_decode)
            and r.prefill_pos < r.input_len
        ):
            spans.append((r.prefill_pos, r.input_len))
        return spans

    def _next_chunks(self, work: UnifiedWork) -> Dict[int, Tuple[int, int]]:
        """Chunk schedule for ONE chain link: walk the batch in order giving
        each unfinished prompt its next contiguous slice until the
        ``prefill_chunk_tokens`` budget runs out (the first prompt always
        gets at least one token, so the chain advances).  A recovering
        request's next slice comes from its first lost hole instead of the
        frontier cursor (at most one hole span per request per link).  A
        recovery chain on an engine without the chunking knob runs each
        span whole."""
        budget = self.manager.mcfg.prefill_chunk_tokens
        budget = max(int(budget), 1) if budget is not None else (1 << 30)
        chunks: Dict[int, Tuple[int, int]] = {}
        for r in work.batch.requests:
            spans = self._pending_spans(r)
            if not spans:
                continue
            if budget <= 0 and chunks:
                break
            start, end = spans[0]
            ln = min(end - start, max(budget, 1))
            chunks[r.rid] = (start, ln)
            budget -= ln
        return chunks

    def _launch_unified(self, work: UnifiedWork,
                        extra_delay: float = 0.0) -> None:
        """Launch one link of a unified chain: recompute the chunk schedule
        from the cursors, charge one fused iteration (chunked-prefill time +
        one decode iteration for the riders) to the union of instances, and
        stamp BOTH launch-consistency maps — the prefill eviction epochs and
        the decode seq stamps guard the same completion event."""
        work.chunks = self._next_chunks(work)
        b = work.batch
        insts = work.alive_instances(self.failed)
        dop = max(len(insts), 1)
        dur = extra_delay
        clens = [ln for _, ln in work.chunks.values()]
        if clens:
            dur += self.sib.prefill_time(dop, clens, insts)
        dreqs = [r for g in work.groups for r in g.requests]
        if dreqs:
            ddur = self.sib.decode_time(
                dop, len(dreqs), sum(r.seq_len for r in dreqs), insts
            )
            for r in dreqs:
                r.decode_exec_time += ddur
            dur += ddur
            self.metrics.decode_iters += 1
        end = self.clock + dur
        self._occupy(insts, end)
        self.metrics.prefill_iters += 1
        self._prefill_launch_epoch[id(work)] = {
            r.rid: r.n_evictions for r in b.requests
        }
        self._decode_launch_seq[id(work)] = {r.rid: r.seq_len for r in dreqs}
        self._running_decode_ends[id(work)] = end
        for r in b.requests:
            self._in_unified.add(r.rid)
        for r in dreqs:
            self._in_unified.add(r.rid)
        self._active_unified[id(work)] = work
        self._push(end, "unified_done", work)

    def _on_unified_done(self, work: UnifiedWork) -> None:
        """Completion of one chain link: run the fused executor step, apply
        BOTH sides' completion processing (prefill cursor advance + decode
        token placement), then either launch the next link (prompts still
        mid-prefill) or dissolve the chain back into `ready_decode`."""
        self._running_decode_ends.pop(id(work), None)
        self._active_unified.pop(id(work), None)
        launch_seq = self._decode_launch_seq.pop(id(work), None)
        epoch = self._prefill_launch_epoch.pop(id(work), None)
        for g in work.groups:
            for r in g.requests:
                self._in_unified.discard(r.rid)
        b = work.batch
        alive = []
        for r in b.requests:
            self._in_unified.discard(r.rid)
            # the same in-flight-failure filters as _on_prefill_done
            if r.phase is not Phase.PREFILL or (
                epoch is not None and epoch.get(r.rid) != r.n_evictions
            ):
                continue
            if self._placement_lost(b, r):
                self.pool.free_request(r.rid)
                self._requeue_for_recompute(r)
                if r not in self.pending:
                    self.pending.append(r)
                continue
            alive.append(r)
        b.requests = alive
        b.instances = [i for i in b.instances if i not in self.failed]
        b.scale_down_to = [i for i in b.scale_down_to if i not in self.failed]
        # the same stale-completion filters as _on_decode_done
        groups = []
        for g in work.groups:
            galive = [
                r for r in g.requests
                if r.phase is Phase.DECODE
                and (launch_seq is None or launch_seq.get(r.rid) == r.seq_len)
            ]
            if galive:
                groups.append(DecodeBatch(
                    galive, [i for i in g.instances if i not in self.failed],
                    g.masters,
                ))
        work.groups = groups
        work.chunks = {
            r.rid: work.chunks[r.rid]
            for r in b.requests if r.rid in work.chunks
        }
        if not b.requests and not groups:
            return
        insts = work.alive_instances(self.failed)
        # sim-mode chains exist only as recovery chains (salvage works on
        # pool bookkeeping alone); there is no executor to dispatch
        ok = True
        if self.real:
            ok = self._dispatch_with_retry(
                lambda: self._real_unified(work), insts, "unified"
            )
        if not ok:
            # the fused step never ran: requeue the chunked prompts for
            # recompute and send surviving riders back to the ready queue
            for r in b.requests:
                if r.phase is Phase.PREFILL:
                    self.pool.free_request(r.rid)
                    self._requeue_for_recompute(r)
                    if r not in self.pending:
                        self.pending.append(r)
            for g in groups:
                live = [r for r in g.requests if r.phase is Phase.DECODE]
                if live:
                    self.ready_decode.append(DecodeBatch(
                        live, [i for i in g.instances if i not in self.failed],
                        g.masters,
                    ))
            return
        # ---- prefill side: advance cursors; completed prompts join decode
        chunked = [r for r in b.requests if r.rid in work.chunks]
        survivors = self._drain_quarantine(chunked)
        completed = []
        recovered = []
        for r in survivors:
            start, ln = work.chunks[r.rid]
            rec = self._recovering.get(r.rid)
            if rec is not None and rec.spans and rec.spans[0][0] == start:
                # hole chunk: consume the lost span, not the frontier
                # cursor — salvaged KV above the hole is already in place
                _, e0 = rec.spans[0]
                if start + ln >= e0:
                    rec.spans.pop(0)
                else:
                    rec.spans[0] = (start + ln, e0)
                if not rec.spans:
                    self._recovering.pop(r.rid, None)
                    if rec.resume_decode:
                        # coverage is whole again: RESUMING -> running.
                        # The request re-enters decode AT its cursor; hole
                        # chunks never sample, so no token is emitted here
                        r.phase = Phase.DECODE
                        recovered.append(r)
                continue
            r.prefill_pos = start + ln
            if r.prefill_pos >= r.input_len:
                self._recovering.pop(r.rid, None)
                r.prefill_end = self.clock
                r.phase = Phase.DECODE
                r.generated += 1  # the fused step emitted the first token
                if not self.real:
                    r.output_tokens.append(self._sample_token())
                completed.append(r)
        for r in [q for q in completed if q.done]:
            self._finish_request(r)
            if r.norm_output_latency():
                self.manager.note_finished_decode(r.norm_output_latency())
        new_dec = [r for r in completed if not r.done]
        # ---- decode side: the standard completion epilogue, per group
        out_groups = []
        for g in groups:
            live = self._decode_epilogue(g)
            if live is not None:
                out_groups.append(live)
        if new_dec:
            insts_nd = [i for i in b.scale_down_to if i not in self.failed]
            masters = (
                self.manager._assign_masters(new_dec, insts_nd)
                if insts_nd else {}
            )
            out_groups.append(DecodeBatch(new_dec, insts_nd, masters))
        if recovered:
            # resumed decode requests re-form as a group on the surviving
            # sub-mesh (DoP-1): they ride the chain's next link as riders
            # or dissolve into `ready_decode` with it
            insts_rec = [i for i in b.instances if i not in self.failed]
            masters = (
                self.manager._assign_masters(recovered, insts_rec)
                if insts_rec else {}
            )
            out_groups.append(DecodeBatch(recovered, insts_rec, masters))
        # ---- continue the chain while any prompt is mid-prefill
        remaining = [r for r in b.requests if r.phase is Phase.PREFILL]
        if remaining:
            b.requests = remaining
            work.groups = [g for g in out_groups if g.requests]
            # continuous batching at the chunk boundary: decode groups that
            # became ready since the last link and would stall on (or
            # overlap) this chain's instances ride the next iteration
            insts = set(work.alive_instances(self.failed))
            for g in list(self.ready_decode):
                if set(g.instances) & insts or not g.instances:
                    self.ready_decode.remove(g)
                    work.groups.append(g)
            self._launch_unified(work)
        else:
            self.ready_decode.extend(g for g in out_groups if g.requests)

    # ---------------------------------------------------------- decode done
    def _placement_order(self, r: Request, g: DecodeBatch) -> List[int]:
        """KV-append probe order for one decoded token: the request's master
        first, then the rest of the decode group, then any other live
        instance — each instance exactly once (a rid missing from
        `g.masters` must not probe `g.instances[0]` twice)."""
        master = g.masters.get(r.rid, g.instances[0] if g.instances else None)
        order = [master] if master is not None else []
        order += [i for i in g.instances if i != master]
        order += [
            i for i in range(self.n)
            if i not in g.instances and i != master
        ]
        return [i for i in order if i not in self.failed]

    def _try_place_token(self, r: Request, g: DecodeBatch, pos: int) -> bool:
        """Append one decoded token's KV slot on the first instance in the
        request's placement order with room; real mode also writes the
        pending KV through."""
        for inst in self._placement_order(r, g):
            try:
                self.pool.pools[inst].alloc(r.rid, [pos])
            except OutOfSlots:
                continue
            if self.real and r.rid in self._pending_kv:
                k_new, v_new = self._pending_kv.pop(r.rid)
                self.pool.pools[inst].fill(r.rid, [pos], k_new, v_new)
            return True
        return False

    def _oom_victim(self, exclude: Set[int]) -> Optional[Request]:
        """Decode-OOM preemption policy: pick the DECODE-phase request that
        loses the least work — fewest generated tokens, youngest arrival and
        highest rid as tiebreaks — never one in `exclude`."""
        cands = [
            q for rid, q in self._req_index.items()
            if q.phase is Phase.DECODE and rid not in exclude
        ]
        if not cands:
            return None
        return min(cands, key=lambda q: (q.generated, -q.arrival, -q.rid))

    def _preempt_and_place(self, r: Request, g: DecodeBatch,
                           pos: int) -> bool:
        """Free pool space for `r`'s token append by evicting victims
        (lowest-progress first) and retrying placement.  Victims are never
        taken from the group currently being processed — their tokens for
        this iteration are already committed.  A victim mid-flight in
        another launched group is safe: its launch stamp no longer matches
        after recompute, so the stale completion is dropped."""
        exclude = {q.rid for q in g.requests}
        for _ in range(4):
            victim = self._oom_victim(exclude)
            if victim is None:
                return False
            exclude.add(victim.rid)
            self.metrics.preemptions += 1
            self._pending_kv.pop(victim.rid, None)
            self.pool.free_request(victim.rid)
            self._requeue_for_recompute(victim)
            if victim not in self.pending:
                self.pending.append(victim)
            self._drop_request_state([victim.rid])
            # purge the victim from waiting groups (mirrors _apply_failure)
            for gg in list(self.ready_decode):
                gg.requests = [
                    q for q in gg.requests if q.phase is Phase.DECODE
                ]
                if not gg.requests:
                    self.ready_decode.remove(gg)
            if self._try_place_token(r, g, pos):
                return True
        return False

    def _on_decode_done(self, g: DecodeBatch) -> None:
        self._running_decode_ends.pop(id(g), None)
        # graceful in-flight failure: a `fail_instance` landing between this
        # group's launch and now freed some requests' KV and re-queued them
        # to PENDING — skip those (and dead instances) instead of tripping
        # the decode paths' KV-coverage assert.  The launch-time seq stamp
        # additionally rejects requests that were requeued AND already
        # recomputed into a fresh group before this stale completion fired
        # (their seq_len moved on) — without it they would be decoded twice.
        launch_seq = self._decode_launch_seq.pop(id(g), None)
        alive = [
            r for r in g.requests
            if r.phase is Phase.DECODE
            and (launch_seq is None or launch_seq.get(r.rid) == r.seq_len)
        ]
        if len(alive) < len(g.requests):
            if not alive:
                return
            g = DecodeBatch(
                alive, [i for i in g.instances if i not in self.failed],
                g.masters,
            )
        if self.real:
            ok = self._dispatch_with_retry(
                lambda: self._real_decode(g), g.instances, "decode"
            )
            if not ok:
                # the iteration never ran (raise precedes any KV write):
                # surviving members simply go back to the ready queue — a
                # group left with no alive instances is revived by the
                # scheduler's stalled-group path
                live = [r for r in g.requests if r.phase is Phase.DECODE]
                insts = [i for i in g.instances if i not in self.failed]
                if live:
                    self.ready_decode.append(DecodeBatch(live, insts, g.masters))
                return
        else:
            # sim mode: poison short-circuits to the same quarantine path
            # the real-mode value guard feeds
            for r in g.requests:
                if r.rid in self._logit_poison:
                    self._logit_poison.discard(r.rid)
                    self._quarantine.add(r.rid)
        live = self._decode_epilogue(g)
        if live is not None:
            self.ready_decode.append(live)

    def _decode_epilogue(self, g: DecodeBatch) -> Optional[DecodeBatch]:
        """Post-compute half of a decode completion: quarantine drain, token
        accounting, per-token KV placement (with OOM preemption), finishes.
        Returns the surviving group for the caller to requeue — the plain
        decode path appends it to `ready_decode`; the unified chain carries
        it into its next fused iteration instead."""
        survivors = self._drain_quarantine(g.requests)
        if not survivors:
            return None
        if len(survivors) < len(g.requests):
            g = DecodeBatch(survivors, g.instances, g.masters)
        done, live = [], []
        for r in g.requests:
            # the processed token's position (its KV is appended now)
            pos = r.seq_len - 1
            r.generated += 1
            if not self.real:
                r.output_tokens.append(self._sample_token())
            if r.done:
                # the final token's KV is never attended — don't burn a slot
                # (and never requeue a finished request on fleet-wide OOM)
                self._pending_kv.pop(r.rid, None)
                done.append(r)
                continue
            placed = self._try_place_token(r, g, pos)
            if not placed:
                # fleet-wide OOM: preempt the youngest/lowest-progress decode
                # request(s) OUTSIDE this group and retry, so work already
                # deep into generation is not the one thrown away
                placed = self._preempt_and_place(r, g, pos)
            if not placed:
                # no preemptable victim either: self-evict & requeue
                self.metrics.preemptions += 1
                self._pending_kv.pop(r.rid, None)
                self.pool.free_request(r.rid)
                self._requeue_for_recompute(r)
                self.pending.append(r)
                continue
            (done if r.done else live).append(r)
        for r in done:
            self._finish_request(r)
            if r.norm_output_latency():
                self.manager.note_finished_decode(r.norm_output_latency())
            self._real_cache.pop(r.rid, None)
        if not live:
            return None
        # always re-filter failed instances (an instance that died
        # mid-flight holding none of this group's KV is not caught by
        # the alive-filter above)
        return DecodeBatch(
            live, [i for i in g.instances if i not in self.failed],
            g.masters,
        )

    # ----------------------------------------------------------- real compute
    # Thin dispatch only: the bodies live in engine/executor.py behind the
    # LocalExecutor/MeshExecutor seam.  The `_real_*` names are kept as the
    # stable probe points benchmarks and tests drive directly.
    def _real_prefill(self, batch: PrefillBatch) -> None:
        return self.executor.prefill(batch)

    def _real_prefill_packed(self, batch: PrefillBatch) -> None:
        return self.executor.prefill_packed(batch)

    def _real_prefill_serial(self, batch: PrefillBatch) -> None:
        return self.executor.prefill_serial(batch)

    def _real_decode(self, g: DecodeBatch) -> None:
        return self.executor.decode(g)

    def _real_decode_paged(self, g: DecodeBatch) -> None:
        return self.executor.decode_paged(g)

    def _real_decode_serial(self, g: DecodeBatch) -> None:
        return self.executor.decode_serial(g)

    def _real_unified(self, work: UnifiedWork) -> None:
        return self.executor.unified(work)

    @property
    def _prefill_programs(self):
        """Compiled packed-prefill program cache (owned by the executor;
        empty for sim-mode engines, which have no executor)."""
        return self.executor._prefill_programs if self.executor else {}

    def _placement_lost(self, batch: PrefillBatch, r: Request) -> bool:
        """True when part of the request's reserved KV placement sits on a
        failed instance — its prefill KV could only be scattered partially."""
        return any(
            pos_list and inst in self.failed
            for inst, pos_list in batch.placement.get(r.rid, {}).items()
        )

    def _apply_join(self, inst: int) -> None:
        super()._apply_join(inst)
        # newly-grown pools need their mirror pinned to a data-shard device
        # under the mesh executor (no-op for LocalExecutor)
        if self.executor is not None and hasattr(self.executor, "_bind_pool_devices"):
            self.executor._bind_pool_devices()

    # ------------------------------------------------ elastic fault recovery
    def _try_salvage(self, req: Request, inst: int) -> bool:
        """Elastic fault recovery (the paper's zero-migration scale-down
        repurposed as the failure path): keep the surviving instances' KV
        shards of `req` registered, re-reserve the dead rank's positions on
        the survivors, and register a `RecoveryState` whose lost *computed*
        spans the recovery chain re-prefills as hole chunks.  Recovery cost
        is proportional to the lost stripe, not the request length.

        Returns False — meaning the caller falls back to full recompute —
        when nothing computed survives, when the request is already
        mid-recovery (a double failure), when real mode lacks the unified
        chunk machinery that drives hole re-prefill, or when the survivors
        cannot hold the lost stripe."""
        rid = req.rid
        if rid in self._recovering:
            return False  # second failure mid-recovery: full recompute
        if req.phase is Phase.DECODE:
            expected = req.seq_len - 1  # stored KV: positions 0..seq_len-2
            cursor = expected
            resume_decode = True
        elif req.phase is Phase.PREFILL and req.prefill_pos > 0:
            expected = req.input_len
            cursor = req.prefill_pos  # positions >= cursor: reserved, unfilled
            resume_decode = False
        else:
            return False  # nothing computed yet: requeueing loses nothing
        if self.real and not (
            self.executor is not None
            and getattr(self.executor, "supports_unified", False)
            and req.prompt is not None
            and len(req.prompt) == req.input_len
        ):
            return False  # span re-prefill runs through the unified path
        plan = self.pool.salvage_placement(rid, expected, self.failed)
        filled = sum(int((p < cursor).sum()) for p in plan.coverage.values())
        if filled == 0:
            return False
        lost = [p for s, e in plan.lost_spans for p in range(s, e)]
        alive = [
            i for i in range(min(self.n, len(self.pool.pools)))
            if i not in self.failed
        ]
        try:
            repl = (
                self.pool.plan_placement(rid, lost, alive) if lost else None
            )
        except OutOfSlots:
            return False  # survivors can't absorb the stripe
        # ---- commit: the request is SALVAGING from here on
        self.pool.pools[inst].free_request(rid)
        if repl is not None:
            # immediate re-reservation keeps the allocated coverage exactly
            # {0..expected-1} throughout recovery (what relaxed I3 checks)
            self.pool.place_salvage(repl)
        self._detach_from_inflight(rid)
        holes = [(s, min(e, cursor)) for s, e in plan.lost_spans if s < cursor]
        self.metrics.salvaged_tokens += filled
        self.metrics.recomputed_tokens += sum(e - s for s, e in holes)
        req.phase = Phase.PREFILL
        self._recovering[rid] = RecoveryState(
            spans=holes, expected=expected,
            resume_decode=resume_decode, salvaged=filled,
        )
        return True

    def _detach_from_inflight(self, rid: int) -> None:
        """Hand ownership of `rid`'s next iteration to the recovery chain:
        delete it from every in-flight launch stamp so stale completions of
        already-queued links/groups drop it (`.get(rid)` mismatches) instead
        of advancing its cursor or decoding it a second time."""
        for stamp in itertools.chain(
            self._decode_launch_seq.values(),
            self._prefill_launch_epoch.values(),
        ):
            stamp.pop(rid, None)
        self._in_unified.discard(rid)
        self._pending_kv.pop(rid, None)

    def _launch_recovery(self, reqs: List[Request]) -> None:
        """One recovery chain per failure event: the salvaged requests
        re-form on the surviving sub-mesh (the union of instances still
        holding their KV — the old group minus the dead rank, DoP-1) and
        resume at their span/chunk cursors through the ordinary unified
        chain machinery.  The batch placement is the live coverage map, so
        hole-chunk KV scatters into the re-reserved slots."""
        placement = {
            r.rid: {
                i: pos.tolist()
                for i, pos in self.pool.coverage_map(
                    r.rid, self.failed
                ).items()
            }
            for r in reqs
        }
        insts = sorted({i for cov in placement.values() for i in cov})
        if not insts:  # unreachable while _try_salvage demands coverage
            for r in reqs:
                self.pool.free_request(r.rid)
                self._requeue_for_recompute(r)
                if r not in self.pending:
                    self.pending.append(r)
            return
        b = PrefillBatch(reqs, insts, insts, placement)
        # failure can land mid-iteration: queue the chain behind whatever
        # the surviving instances are already busy with
        extra = max(
            (max(0.0, self.busy_until[i] - self.clock) for i in insts),
            default=0.0,
        )
        self._launch_unified(UnifiedWork(b, []), extra_delay=extra)

    def _promote_masters(self, g: DecodeBatch) -> None:
        """Master promotion: requests whose KV-append master died get a
        fresh master among the group's surviving instances."""
        orphans = [
            r for r in g.requests if g.masters.get(r.rid) in self.failed
        ]
        if orphans and g.instances:
            g.masters.update(
                self.manager._assign_masters(orphans, g.instances)
            )

    def _apply_failure(self, inst: int) -> None:
        super()._apply_failure(inst)
        # drop the failed instance's device KV mirror (a full pool-sized
        # copy) — it will be rebuilt from scratch if the instance rejoins
        if inst < len(self.pool.pools):
            self.pool.pools[inst].drop_mirror()
        # evict compiled programs / mesh-cache entries that bake in the
        # dead instance: surviving groups re-form at DoP-1 and compile
        # fresh reduced-DoP programs on the sub-mesh
        if self.executor is not None:
            self.executor.on_instance_failed(inst)
        # purge requeued (now-PENDING/-PREFILL) requests and the dead
        # instance from waiting decode groups so they are not scheduled
        # with freed KV; promote masters the failure orphaned
        for g in list(self.ready_decode):
            g.requests = [r for r in g.requests if r.phase is Phase.DECODE]
            g.instances = [i for i in g.instances if i not in self.failed]
            if not g.requests:
                self.ready_decode.remove(g)
                continue
            self._promote_masters(g)
        # in-flight chains: rider groups re-form on the surviving sub-mesh
        # at their next link (the chain itself filters alive instances at
        # every launch)
        for w in self._active_unified.values():
            for g in w.groups:
                g.instances = [i for i in g.instances if i not in self.failed]
                self._promote_masters(g)

    def _drop_request_state(self, rids) -> None:
        for rid in rids:
            self._real_cache.pop(rid, None)

    def _checkpoint_extra(self):
        # launch-time consistency state is keyed by id() of the in-flight
        # payload objects; persist it keyed by the OBJECTS themselves — the
        # single pickle.dump shares identity with the copies inside
        # `events`, so `_restore_extra` can rebuild the id()-keyed maps
        # against the restored heap and an in-flight unified chain RESUMES
        # at its chunk cursors instead of restarting
        stamped = [
            p for _, _, kind, p in self.events
            if kind in ("prefill_done", "decode_done", "unified_done")
        ]
        return {
            "ready_decode": self.ready_decode,
            "in_unified": set(self._in_unified),
            "recovering": dict(self._recovering),
            "launch_stamps": [
                (
                    p,
                    self._prefill_launch_epoch.get(id(p)),
                    self._decode_launch_seq.get(id(p)),
                    self._running_decode_ends.get(id(p)),
                    id(p) in self._active_unified,
                )
                for p in stamped
            ],
        }

    def _restore_extra(self, extra) -> None:
        self._running_decode_ends = {}
        self._decode_launch_seq = {}
        self._prefill_launch_epoch = {}
        self._in_unified = set()
        self._active_unified = {}
        self._recovering = {}
        if not extra:
            return
        self.ready_decode = extra["ready_decode"]
        self._in_unified = set(extra.get("in_unified", ()))
        self._recovering = dict(extra.get("recovering", {}))
        for p, epoch, seq, end, active in extra.get("launch_stamps", ()):
            if epoch is not None:
                self._prefill_launch_epoch[id(p)] = epoch
            if seq is not None:
                self._decode_launch_seq[id(p)] = seq
            if end is not None:
                self._running_decode_ends[id(p)] = end
            if active:
                self._active_unified[id(p)] = p

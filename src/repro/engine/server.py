"""Serving engines: event-driven iteration loop over elastic instances.

`BaseServingEngine` owns the clock, the event queue, the distributed KV pool,
the SIB and metrics; `LoongServeEngine` drives it with the four-step global
manager (ESP). Baselines (repro.baselines) subclass the same loop so the
comparison is apples-to-apples: identical cost model, pool accounting and
request lifecycle — only the policy differs.

Two compute modes:
  * sim  — tokens are synthetic; iteration durations come from the SIB
           analytical model (the paper's own scheduling signal). This scales
           to paper-sized workloads (Fig. 10-12) on CPU.
  * real — a reduced model actually prefills/decodes on CPU; KV tensors flow
           through the pools exactly as the plans dictate (used by tests and
           the runnable examples; also the source of SIB profiles).

Fault tolerance: `fail_instance` drops an instance and its KV shards —
affected decode requests are re-queued for prefill recompute; `join_instance`
adds fresh capacity; `checkpoint`/`restore` snapshot the full serving state.
Elasticity is the recovery mechanism (DESIGN.md §7).
"""
from __future__ import annotations

import heapq
import itertools
import pickle
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from repro.configs.base import ModelConfig
from repro.engine.request import Phase, Request
from repro.kvcache.distributed import DistributedKVPool
from repro.kvcache.pool import OutOfSlots
from repro.manager.scheduler import (
    DecodeBatch,
    GlobalManager,
    ManagerConfig,
    PrefillBatch,
)
from repro.manager.sib import SIB, HardwareSpec


@dataclass
class EngineMetrics:
    finished: List[Request] = field(default_factory=list)
    rejected: int = 0
    scaling_migration_bytes: int = 0  # ESP transitions: MUST stay 0
    reactive_migration_bytes: int = 0
    q_broadcast_bytes: int = 0
    prefill_iters: int = 0
    decode_iters: int = 0

    def summary(self) -> Dict[str, float]:
        fin = [r for r in self.finished if r.finish_time is not None]
        out: Dict[str, float] = {
            "n_finished": len(fin),
            "rejected": self.rejected,
            "scaling_migration_bytes": self.scaling_migration_bytes,
            "reactive_migration_bytes": self.reactive_migration_bytes,
            "prefill_iters": self.prefill_iters,
            "decode_iters": self.decode_iters,
        }
        if fin:
            for name, fn in [
                ("norm_e2e", lambda r: r.norm_e2e_latency()),
                ("norm_input", lambda r: r.norm_input_latency()),
                ("norm_output", lambda r: r.norm_output_latency()),
            ]:
                vals = [fn(r) for r in fin if fn(r) is not None]
                if vals:
                    out[f"{name}_mean"] = float(np.mean(vals))
                    out[f"{name}_p90"] = float(np.percentile(vals, 90))
            span = max(r.finish_time for r in fin) - min(r.arrival for r in fin)
            toks = sum(r.seq_len for r in fin)
            out["throughput_tok_s"] = toks / max(span, 1e-9)
        return out


_event_seq = itertools.count()


class BaseServingEngine:
    def __init__(
        self,
        cfg: ModelConfig,
        n_instances: int,
        capacity_per_instance: int,
        *,
        hw: Optional[HardwareSpec] = None,
        store_values: bool = False,
        model=None,
        params=None,
        seed: int = 0,
        page_size: int = 1,
    ):
        self.cfg = cfg
        self.n = n_instances
        self.capacity = capacity_per_instance
        self.page_size = page_size
        self.pool = DistributedKVPool(cfg, n_instances, capacity_per_instance,
                                      store_values, page_size)
        self.sib = SIB(cfg, hw)
        self.clock = 0.0
        self.pending: List[Request] = []
        self.events: List[Tuple[float, int, str, Any]] = []
        self.busy_until: Dict[int, float] = {i: 0.0 for i in range(n_instances)}
        self.failed: Set[int] = set()
        self.metrics = EngineMetrics()
        self.model = model
        self.params = params
        self.real = model is not None
        self.rng = np.random.default_rng(seed)
        self._req_index: Dict[int, Request] = {}

    # ----------------------------------------------------------- submission
    def submit(self, req: Request, at: Optional[float] = None) -> None:
        t = req.arrival if at is None else at
        req.arrival = t
        cap_total = self.capacity * (self.n - len(self.failed))
        if req.max_total_len > cap_total:
            self.metrics.rejected += 1
            return
        self._push(t, "arrival", req)
        self._req_index[req.rid] = req

    def _push(self, t: float, kind: str, payload: Any) -> None:
        heapq.heappush(self.events, (t, next(_event_seq), kind, payload))

    # ------------------------------------------------------------ main loop
    def run(self, max_time: float = float("inf"), max_events: int = 2_000_000):
        n_ev = 0
        while self.events and n_ev < max_events:
            t, seq, kind, payload = heapq.heappop(self.events)
            if t > max_time:
                # keep the event for a later run()/restore
                heapq.heappush(self.events, (t, seq, kind, payload))
                break
            self.clock = max(self.clock, t)
            self._handle(kind, payload)
            n_ev += 1
        return self.metrics

    def _handle(self, kind: str, payload: Any) -> None:
        if kind == "arrival":
            self.pending.append(payload)
            payload.phase = Phase.PENDING
        elif kind == "prefill_done":
            self._on_prefill_done(payload)
        elif kind == "decode_done":
            self._on_decode_done(payload)
        elif kind == "fail":
            self._apply_failure(payload)
        elif kind == "join":
            self._apply_join(payload)
        self._try_schedule()

    # hooks ------------------------------------------------------------
    def _try_schedule(self) -> None:  # pragma: no cover - abstract
        raise NotImplementedError

    def _on_prefill_done(self, batch) -> None:  # pragma: no cover
        raise NotImplementedError

    def _on_decode_done(self, batch) -> None:  # pragma: no cover
        raise NotImplementedError

    # ------------------------------------------------------------- helpers
    def idle_instances(self) -> List[int]:
        return [
            i
            for i in range(self.n)
            if i not in self.failed and self.busy_until[i] <= self.clock + 1e-12
        ]

    def _occupy(self, instances: Sequence[int], until: float) -> None:
        for i in instances:
            self.busy_until[i] = until

    def _finish_request(self, req: Request) -> None:
        req.phase = Phase.FINISHED
        req.finish_time = self.clock
        self.pool.free_request(req.rid)
        self.metrics.finished.append(req)

    def _sample_token(self, logits=None) -> int:
        if logits is None:
            return int(self.rng.integers(0, self.cfg.vocab_size))
        return int(np.argmax(logits))

    # -------------------------------------------------- fault tolerance API
    def fail_instance(self, inst: int, at: Optional[float] = None) -> None:
        self._push(at if at is not None else self.clock, "fail", inst)

    def join_instance(self, inst: int, at: Optional[float] = None) -> None:
        self._push(at if at is not None else self.clock, "join", inst)

    def _requeue_for_recompute(self, req: Request) -> None:
        """Evicted-KV recovery: the request re-enters prefill over everything
        generated so far.  The emitted tokens become part of the new prompt
        (in real mode literally, so the recompute reproduces the exact
        sequence) and move from the generation budget into the input — KV
        accounting stays exact (seq_len == recomputed prompt + new tokens,
        no double count of the folded prefix)."""
        req.n_evictions += 1
        req.phase = Phase.PENDING
        if req.prompt is not None and len(req.prompt) < req.seq_len:
            need = req.seq_len - len(req.prompt)
            req.prompt = list(req.prompt) + list(req.output_tokens[-need:])
        req.input_len = req.seq_len  # recompute over everything so far
        req.max_new_tokens -= req.generated  # folded tokens are input now
        req.generated = 0
        req.prefill_end = None

    def _apply_failure(self, inst: int) -> None:
        self.failed.add(inst)
        self.busy_until[inst] = float("inf")
        # KV shards on the instance are lost: re-queue affected requests for
        # prefill recompute (generated prefix becomes part of the new prompt).
        affected = list(self.pool.pools[inst].requests())
        for rid in affected:
            req = self._req_index.get(rid)
            self.pool.free_request(rid)
            if req is None or req.phase in (Phase.FINISHED,):
                continue
            self._requeue_for_recompute(req)
            if req not in self.pending:
                self.pending.append(req)
        self._drop_request_state(affected)

    def _apply_join(self, inst: int) -> None:
        if inst in self.failed:
            self.failed.discard(inst)
            self.busy_until[inst] = self.clock
        elif inst >= self.n:  # truly new instance: grow the registry
            for j in range(self.n, inst + 1):
                self.pool.pools.append(
                    type(self.pool.pools[0])(
                        self.cfg, self.capacity, j,
                        self.pool.pools[0].store_values, self.page_size,
                    )
                )
                self.busy_until[j] = self.clock
            self.n = inst + 1

    def _drop_request_state(self, rids: Sequence[int]) -> None:
        """Subclasses drop any per-request runtime state for re-queued rids."""

    # ------------------------------------------------------- checkpointing
    def checkpoint(self, path: str) -> None:
        state = {
            "clock": self.clock,
            "pending": self.pending,
            "events": self.events,
            "busy_until": self.busy_until,
            "failed": self.failed,
            "metrics": self.metrics,
            "req_index": self._req_index,
            "pool_state": [p.state_dict() for p in self.pool.pools],
            "extra": self._checkpoint_extra(),
        }
        with open(path, "wb") as f:
            pickle.dump(state, f)

    def restore(self, path: str) -> None:
        with open(path, "rb") as f:
            state = pickle.load(f)
        self.clock = state["clock"]
        self.pending = state["pending"]
        self.events = state["events"]
        self.busy_until = state["busy_until"]
        self.failed = state["failed"]
        self.metrics = state["metrics"]
        self._req_index = state["req_index"]
        for p, ps in zip(self.pool.pools, state["pool_state"]):
            p.load_state_dict(ps)
        self._restore_extra(state["extra"])

    def _checkpoint_extra(self) -> Any:
        return None

    def _restore_extra(self, extra: Any) -> None:
        pass


# ======================================================================= ESP


class LoongServeEngine(BaseServingEngine):
    """The paper's system: ESP + four-step global manager."""

    def __init__(self, *args, mcfg: Optional[ManagerConfig] = None, **kwargs):
        super().__init__(*args, **kwargs)
        self.manager = GlobalManager(self.cfg, self.sib, self.pool,
                                     mcfg or ManagerConfig())
        self.ready_decode: List[DecodeBatch] = []
        self._real_cache: Dict[int, Any] = {}  # rid -> recurrent state (real)
        self._pending_kv: Dict[int, Any] = {}  # rid -> new kv awaiting alloc
        self._running_decode_ends: Dict[int, float] = {}  # gid -> end time
        self._decode_launch_seq: Dict[int, Dict[int, int]] = {}  # gid -> rid -> seq
        self._prefill_launch_epoch: Dict[int, Dict[int, int]] = {}  # bid -> rid -> n_evictions
        # batched paged decode: the multi-master paged attention impl is
        # swapped in only around a batched decode step (the model object is
        # caller-owned and may be shared between engines).  Pure-attention
        # families only: hybrids/ssm keep the serial per-request path, and
        # moe stays serial because expert-capacity dropping is batch-size
        # dependent (batching would change generated tokens).
        self._paged_impl = None
        # packed ragged prefill: one jitted model step per bucketed
        # (total_tokens, batch, max_len, dop) shape — O(log max_tokens)
        # programs per DoP instead of one per distinct prompt length.  DoP>1
        # ESP groups run the SAME packed step with the token axis striped
        # across the group and attention ring-fused (one packed chunk launch
        # per instance per ring step) — no serial fallback for scaled-up
        # groups.  Same family gating as the paged decode path (moe:
        # expert-capacity dropping is batch-size dependent, packing would
        # change generated tokens).
        self._packed_prefill_impl = None
        self._prefill_programs: Dict[Tuple[int, int, int, int], Any] = {}
        if self.real and self.cfg.family in ("dense", "vlm"):
            from repro.core.paged_decode import PagedDecodeAttnImpl
            from repro.core.paged_prefill import PackedPrefillAttnImpl
            from repro.models.transformer import DefaultAttnImpl

            if type(getattr(self.model, "attn_impl", None)) is DefaultAttnImpl:
                self._paged_impl = PagedDecodeAttnImpl()
                self._packed_prefill_impl = PackedPrefillAttnImpl()

    # ------------------------------------------------------------- schedule
    def _try_schedule(self) -> None:
        for _ in range(4):  # drain: admit more work onto leftover instances
            idle = [
                i
                for i in self.idle_instances()
                if not any(i in g.instances for g in self.ready_decode)
            ]
            if not idle and not self.ready_decode:
                return
            if not self.pending and not self.ready_decode:
                return
            self.pending.sort(key=lambda r: r.arrival)
            plan = self.manager.schedule(
                self.pending, self.ready_decode, idle, self.clock
            )
            if not plan.prefill and not plan.decode and not plan.migrations:
                return
            self._execute_plan(plan)

    def _execute_plan(self, plan) -> None:
        # migrations (allocation-step KV moves — reactive, counted)
        mig_delay: Dict[int, float] = {}
        for m in plan.migrations:
            try:
                moved = self.pool.migrate_request(m.rid, m.src, m.dsts)
            except OutOfSlots:
                continue
            self.metrics.reactive_migration_bytes += moved
            t = self.sib.migration_time(m.n_tokens)
            mig_delay[m.src] = mig_delay.get(m.src, 0.0) + t

        # prefill batches
        for b in plan.prefill:
            for r in b.requests:
                if r in self.pending:
                    self.pending.remove(r)
                r.phase = Phase.PREFILL
                if r.prefill_start is None:
                    r.prefill_start = self.clock
            # drop annexed instances from stalled ready groups
            for g in self.ready_decode:
                g.instances = [i for i in g.instances if i not in b.instances]
            lens = [r.input_len for r in b.requests]
            dur = self.sib.prefill_time(b.dop, lens, b.instances)
            dur += max((mig_delay.get(i, 0.0) for i in b.instances), default=0.0)
            end = self.clock + dur
            self._occupy(b.instances, end)
            self.metrics.prefill_iters += 1
            # launch-time eviction-epoch stamp: prefill_done uses it to drop
            # requests requeued (and possibly re-prefilled) by an in-flight
            # fail_instance — their reserved placement slots are gone
            self._prefill_launch_epoch[id(b)] = {
                r.rid: r.n_evictions for r in b.requests
            }
            self._push(end, "prefill_done", b)

        # decode batches (one iteration each; greedy execution emerges from
        # faster groups re-entering the queue sooner)
        launched = []
        soonest_end = min(self._running_decode_ends.values(), default=None)
        for g in plan.decode:
            if not g.instances:
                continue  # stalled (preempted) — retried next round
            sum_kv = sum(r.seq_len for r in g.requests)
            dur = self.sib.decode_time(
                g.dop, len(g.requests), sum_kv, g.instances
            )
            # batch-consolidation hold: if another decode group finishes
            # within a fraction of our iteration, wait and merge with it at
            # that boundary (shared weight read; zero-copy under multi-master)
            if (
                soonest_end is not None
                and soonest_end - self.clock < 0.3 * dur
            ):
                continue
            end = self.clock + dur
            self._occupy(g.instances, end)
            for r in g.requests:
                r.decode_exec_time += dur
            # q-broadcast volume (multi-master): q + partial returns
            self.metrics.q_broadcast_bytes += (
                2 * len(g.requests) * self.cfg.n_heads * self.cfg.head_dim
                * 2 * max(g.dop - 1, 0)
            )
            self.metrics.decode_iters += 1
            self._running_decode_ends[id(g)] = end
            # launch-time sequence stamp: decode_done uses it to tell "still
            # this iteration's request" from "requeued by a failure and
            # already recomputed into a new group" (seq_len is monotone and
            # only moves when a prefill/decode completion is processed)
            self._decode_launch_seq[id(g)] = {r.rid: r.seq_len for r in g.requests}
            self._push(end, "decode_done", g)
            launched.append(g)
        for g in launched:
            for rg in list(self.ready_decode):
                if set(r.rid for r in rg.requests) & set(
                    r.rid for r in g.requests
                ):
                    self.ready_decode.remove(rg)

    # --------------------------------------------------------- prefill done
    def _on_prefill_done(self, batch: PrefillBatch) -> None:
        # graceful in-flight failure (mirror of _on_decode_done): requests
        # requeued by a fail_instance between this batch's launch and now
        # lost their reserved placement slots — drop them (the epoch stamp
        # also catches ones already relaunched and back in PREFILL phase).
        epoch = self._prefill_launch_epoch.pop(id(batch), None)
        alive = []
        for r in batch.requests:
            if r.phase is not Phase.PREFILL or (
                epoch is not None and epoch.get(r.rid) != r.n_evictions
            ):
                continue
            if self._placement_lost(batch, r):
                # part of the reserved placement sits on a failed instance
                # (normally _apply_failure already requeued the request; this
                # catches the post-restore case where the epoch stamp was
                # dropped): scattering would silently skip the dead shard and
                # leave partial KV — requeue for recompute instead, mirroring
                # decode_done's stamp check.
                self.pool.free_request(r.rid)
                self._requeue_for_recompute(r)
                if r not in self.pending:
                    self.pending.append(r)
                continue
            alive.append(r)
        if len(alive) < len(batch.requests):
            batch.requests = alive
            batch.instances = [i for i in batch.instances if i not in self.failed]
            batch.scale_down_to = [
                i for i in batch.scale_down_to if i not in self.failed
            ]
            if not alive:
                return
        # proactive scale-down: KV lands in the already-reserved slots of the
        # target group during the ring pass — ZERO migration bytes.
        if self.real:
            self._real_prefill(batch)
        for r in batch.requests:
            r.prefill_end = self.clock
            r.phase = Phase.DECODE
            r.generated += 1  # prefill emits the first token
            if not self.real:
                r.output_tokens.append(self._sample_token())
        done = [r for r in batch.requests if r.done]
        live = [r for r in batch.requests if not r.done]
        for r in done:
            self._finish_request(r)
            if r.norm_output_latency():
                self.manager.note_finished_decode(r.norm_output_latency())
        if live:
            masters = self.manager._assign_masters(live, batch.scale_down_to)
            self.ready_decode.append(
                DecodeBatch(live, list(batch.scale_down_to), masters)
            )

    # ---------------------------------------------------------- decode done
    def _placement_order(self, r: Request, g: DecodeBatch) -> List[int]:
        """KV-append probe order for one decoded token: the request's master
        first, then the rest of the decode group, then any other live
        instance — each instance exactly once (a rid missing from
        `g.masters` must not probe `g.instances[0]` twice)."""
        master = g.masters.get(r.rid, g.instances[0] if g.instances else None)
        order = [master] if master is not None else []
        order += [i for i in g.instances if i != master]
        order += [
            i for i in range(self.n)
            if i not in g.instances and i != master
        ]
        return [i for i in order if i not in self.failed]

    def _on_decode_done(self, g: DecodeBatch) -> None:
        self._running_decode_ends.pop(id(g), None)
        # graceful in-flight failure: a `fail_instance` landing between this
        # group's launch and now freed some requests' KV and re-queued them
        # to PENDING — skip those (and dead instances) instead of tripping
        # the decode paths' KV-coverage assert.  The launch-time seq stamp
        # additionally rejects requests that were requeued AND already
        # recomputed into a fresh group before this stale completion fired
        # (their seq_len moved on) — without it they would be decoded twice.
        launch_seq = self._decode_launch_seq.pop(id(g), None)
        alive = [
            r for r in g.requests
            if r.phase is Phase.DECODE
            and (launch_seq is None or launch_seq.get(r.rid) == r.seq_len)
        ]
        if len(alive) < len(g.requests):
            if not alive:
                return
            g = DecodeBatch(
                alive, [i for i in g.instances if i not in self.failed],
                g.masters,
            )
        if self.real:
            self._real_decode(g)
        done, live = [], []
        for r in g.requests:
            # the processed token's position (its KV is appended now)
            pos = r.seq_len - 1
            r.generated += 1
            if not self.real:
                r.output_tokens.append(self._sample_token())
            if r.done:
                # the final token's KV is never attended — don't burn a slot
                # (and never requeue a finished request on fleet-wide OOM)
                self._pending_kv.pop(r.rid, None)
                done.append(r)
                continue
            placed = False
            for inst in self._placement_order(r, g):
                try:
                    self.pool.pools[inst].alloc(r.rid, [pos])
                    if self.real and r.rid in self._pending_kv:
                        k_new, v_new = self._pending_kv.pop(r.rid)
                        self.pool.pools[inst].fill(r.rid, [pos], k_new, v_new)
                    placed = True
                    break
                except OutOfSlots:
                    continue
            if not placed:
                # fleet-wide OOM: evict & requeue (counts as recompute)
                self._pending_kv.pop(r.rid, None)
                self.pool.free_request(r.rid)
                self._requeue_for_recompute(r)
                self.pending.append(r)
                continue
            (done if r.done else live).append(r)
        for r in done:
            self._finish_request(r)
            if r.norm_output_latency():
                self.manager.note_finished_decode(r.norm_output_latency())
            self._real_cache.pop(r.rid, None)
        if live:
            self.ready_decode.append(DecodeBatch(live, g.instances, g.masters))

    # ----------------------------------------------------------- real compute
    @staticmethod
    def _bucket(n: int, lo: int = 16) -> int:
        """Power-of-two padding bucket: O(log max) compiled shapes (shared
        formula with the pool's scatter-index bucketing)."""
        from repro.kvcache.pool import _pad_bucket

        return max(lo, _pad_bucket(n))

    @classmethod
    def _token_bucket(cls, n: int, lo: int = 16) -> int:
        """Packed-token-axis bucket: powers of two plus their 3/4 points
        (16, 24, 32, 48, 64, ...).  Still O(log max_tokens) compiled shapes
        — 2x the constant — but worst-case padding waste drops from ~2x to
        ~4/3 on the axis every attention launch scans."""
        b = cls._bucket(n, lo)
        mid = (b * 3) // 4
        return mid if (n <= mid and mid >= lo) else b

    def _real_prefill(self, batch: PrefillBatch) -> None:
        # fast-path guard: every instance holding a request's reserved
        # placement must still be alive — scattering would silently skip the
        # dead shard and leave partial KV on EITHER path, so such requests
        # are pruned and requeued for recompute (normally _on_prefill_done
        # already did this; the re-check covers direct callers) while the
        # rest of the batch keeps packed speed.
        lost = [r for r in batch.requests if self._placement_lost(batch, r)]
        if lost:
            batch.requests = [r for r in batch.requests if r not in lost]
            batch.instances = [
                i for i in batch.instances if i not in self.failed
            ]
            for r in lost:
                self.pool.free_request(r.rid)
                self._requeue_for_recompute(r)
                if r not in self.pending:
                    self.pending.append(r)
            if not batch.requests:
                return
        if self._packed_prefill_impl is not None and all(
            r.prompt is not None and len(r.prompt) == r.input_len
            for r in batch.requests
        ):
            return self._real_prefill_packed(batch)
        return self._real_prefill_serial(batch)

    def _placement_lost(self, batch: PrefillBatch, r: Request) -> bool:
        """True when part of the request's reserved KV placement sits on a
        failed instance — its prefill KV could only be scattered partially."""
        return any(
            pos_list and inst in self.failed
            for inst, pos_list in batch.placement.get(r.rid, {}).items()
        )

    def _packed_prefill_step(self, tb: int, bb: int, max_len_b: int, dop: int):
        """Jitted packed prefill program for one bucket tuple; cached so
        the compile count stays O(log max_tokens) per DoP."""
        key = (tb, bb, max_len_b, dop)
        fn = self._prefill_programs.get(key)
        if fn is None:
            import jax

            model, impl = self.model, self._packed_prefill_impl

            def step(params, tokens, positions, offsets, last_idx):
                impl.begin_step(offsets, max_len_b, dop=dop)
                try:
                    return model.prefill_packed(
                        params, {"tokens": tokens[None]}, positions, last_idx
                    )
                finally:
                    impl.end_step()

            fn = self._prefill_programs[key] = jax.jit(step)
        return fn

    def _real_prefill_packed(self, batch: PrefillBatch) -> None:
        """One packed model step for the WHOLE prefill batch: prompts are
        concatenated on a single (bucketed) token axis, attention is
        segment-masked by one ragged kernel launch per layer (DoP>1 groups:
        one ring-chunk launch per instance per ring step over the striped
        packed axis), first tokens are sampled from the packed logits, and
        the per-layer KV output is scattered straight into paged device
        storage at the slots the scheduler reserved (`pool.fill_packed`
        write-through — the decode mirror never re-uploads prefill KV)."""
        import jax.numpy as jnp

        reqs = batch.requests
        lens = [len(r.prompt) for r in reqs]
        total = sum(lens)
        # ring degree = the (alive) ESP group driving this batch; the token
        # bucket is a bucketed SHARD length x dop so the striped shards stay
        # block-aligned (dop=1 degenerates to plain token bucketing)
        dop = max(len([i for i in batch.instances if i not in self.failed]), 1)
        tb = self._token_bucket(-(-total // dop)) * dop
        bb = self._bucket(len(reqs), lo=1)
        max_len_b = self._bucket(max(lens))
        tokens = np.zeros(tb, np.int32)
        positions = np.zeros(tb, np.int32)
        offsets = np.full(bb + 1, total, np.int32)
        offsets[0] = 0
        last_idx = np.zeros(bb, np.int32)
        c = 0
        for b, r in enumerate(reqs):
            n = lens[b]
            tokens[c : c + n] = np.asarray(r.prompt, np.int32)
            positions[c : c + n] = np.arange(n)
            c += n
            offsets[b + 1] = c
            last_idx[b] = c - 1
        fn = self._packed_prefill_step(tb, bb, max_len_b, dop)
        prev_impl = self.model.attn_impl
        self.model.attn_impl = self._packed_prefill_impl
        try:
            logits, (k_packed, v_packed) = fn(
                self.params, jnp.asarray(tokens), jnp.asarray(positions),
                jnp.asarray(offsets), jnp.asarray(last_idx),
            )
        finally:
            self.model.attn_impl = prev_impl
        logits = np.asarray(logits)
        for b, r in enumerate(reqs):
            r.output_tokens.append(self._sample_token(logits[b]))
        if not self.pool.pools[0].store_values:
            return
        # direct-to-pool paged KV writes: per instance, gather the packed
        # columns this instance retains (striped placement from
        # batch.placement — ESP scale-down stays zero-migration) and
        # write-through into its mirror at the reserved block-table slots
        starts = np.concatenate([[0], np.cumsum(lens)])
        per_inst: Dict[int, Tuple[List[np.ndarray], List[np.ndarray]]] = {}
        for b, r in enumerate(reqs):
            for inst, pos_list in batch.placement.get(r.rid, {}).items():
                if not pos_list or inst in self.failed:
                    continue
                p = np.asarray(pos_list, np.int64)
                cols, slots = per_inst.setdefault(inst, ([], []))
                cols.append(starts[b] + p)
                slots.append(self.pool.pools[inst].slots_for(r.rid, p))
        for inst, (cols, slots) in per_inst.items():
            cidx = jnp.asarray(np.concatenate(cols))
            self.pool.pools[inst].fill_packed(
                np.concatenate(slots),
                jnp.take(k_packed, cidx, axis=1),
                jnp.take(v_packed, cidx, axis=1),
            )

    def _real_prefill_serial(self, batch: PrefillBatch) -> None:
        """Per-request fallback (recurrent/hybrid state, moe capacity)."""
        import jax.numpy as jnp

        from repro.kernels import ops

        for r in batch.requests:
            # dispatch-counted so tests/benches can assert the packed paths
            # (incl. DoP>1 ring fusion) never fall back to serial prefill
            ops.dispatch_counts["prefill_serial_model"] += 1
            toks = jnp.asarray(np.asarray(r.prompt, np.int32)[None])
            logits, cache = self.model.prefill(self.params, {"tokens": toks})
            r.output_tokens.append(self._sample_token(np.asarray(logits[0, -1])))
            if cache.k is not None:
                k = np.asarray(cache.k[:, 0], np.float32)  # [L, T, KVH, D]
                v = np.asarray(cache.v[:, 0], np.float32)
                assign = batch.placement[r.rid]
                for inst, positions in assign.items():
                    if positions and inst not in self.failed:
                        self.pool.pools[inst].fill(
                            r.rid, positions, k[:, positions], v[:, positions]
                        )
            if cache.ssm is not None:
                self._real_cache[r.rid] = cache.ssm

    def _real_decode(self, g: DecodeBatch) -> None:
        if self._paged_impl is not None and self.pool.pools[0].store_values:
            return self._real_decode_paged(g)
        return self._real_decode_serial(g)

    def _real_decode_paged(self, g: DecodeBatch) -> None:
        """Gather-free batched decode: ONE model step for the whole group;
        per layer, one paged-kernel launch per instance over the pool storage
        in place (block tables), partials LSE-merged multi-master style."""
        import jax.numpy as jnp

        from repro.core.paged_decode import PagedShard
        from repro.models.transformer import Cache

        rids = [r.rid for r in g.requests]
        n_cached = np.array([r.seq_len - 1 for r in g.requests], np.int32)
        shards, covered = [], np.zeros(len(rids), np.int64)
        for pool in self.pool.pools:
            if pool.instance_id in self.failed:
                continue
            table, lengths = pool.block_table(rids)
            if not lengths.any():
                continue
            covered += lengths
            # pool-owned incrementally-synced mirror: steady-state decode
            # uploads one slot per request; packed-prefill slots upload 0
            kdev, vdev, posdev = pool.device_kv()
            paged_shape = (pool.n_attn, pool.n_pages, pool.page_size) + kdev.shape[2:]
            shards.append(PagedShard(
                k_pages=kdev.reshape(paged_shape),
                v_pages=vdev.reshape(paged_shape),
                table=jnp.asarray(table),
                lengths=jnp.asarray(lengths),
                # per-slot positions are only consumed by window masking
                pos=(posdev.reshape(pool.n_pages, pool.page_size)
                     if self.cfg.sliding_window else None),
            ))
        # cache holds tokens 0..seq_len-2; the processed token's KV is
        # produced by this step and appended at the master afterwards
        assert (covered == n_cached).all(), (covered, n_cached)
        toks = jnp.asarray([r.output_tokens[-1] for r in g.requests], jnp.int32)
        cache = Cache(length=jnp.asarray(n_cached))
        prev_impl = self.model.attn_impl
        self.model.attn_impl = self._paged_impl
        self._paged_impl.begin_step(shards)
        try:
            logits, _, kvs = self.model.decode(self.params, toks, cache)
        finally:
            self._paged_impl.end_step()
            self.model.attn_impl = prev_impl
        logits = np.asarray(logits)
        for b, r in enumerate(g.requests):
            r.output_tokens.append(self._sample_token(logits[b]))
            if kvs is not None:
                # stash; _on_decode_done fills it once the slot is allocated
                self._pending_kv[r.rid] = (
                    np.asarray(kvs[0][:, b], np.float32),  # [L, 1, KVH, D]
                    np.asarray(kvs[1][:, b], np.float32),
                )

    def _real_decode_serial(self, g: DecodeBatch) -> None:
        """Per-request fallback (recurrent/hybrid state or custom impls)."""
        import jax.numpy as jnp

        from repro.models.transformer import Cache

        for r in g.requests:
            positions, k, v = self.pool.gather_request(r.rid)
            # cache holds tokens 0..seq_len-2; the processed token's KV is
            # produced by this step and appended at the master afterwards
            n_cached = r.seq_len - 1
            if k is not None:
                assert len(positions) == n_cached, (len(positions), n_cached)
            cache = Cache(
                k=jnp.asarray(k[:, None].astype(self.model.dtype)) if k is not None else None,
                v=jnp.asarray(v[:, None].astype(self.model.dtype)) if v is not None else None,
                length=jnp.asarray([n_cached], jnp.int32),
                ssm=self._real_cache.get(r.rid),
            )
            last_tok = r.output_tokens[-1]
            logits, new_cache, kvs = self.model.decode(
                self.params, jnp.asarray([last_tok], jnp.int32), cache
            )
            r.output_tokens.append(self._sample_token(np.asarray(logits[0])))
            if new_cache.ssm is not None:
                self._real_cache[r.rid] = new_cache.ssm
            if kvs is not None:
                # stash; _on_decode_done fills it once the slot is allocated
                self._pending_kv[r.rid] = (
                    np.asarray(kvs[0][:, 0], np.float32),  # [L, 1, KVH, D]
                    np.asarray(kvs[1][:, 0], np.float32),
                )

    def _apply_failure(self, inst: int) -> None:
        super()._apply_failure(inst)
        # drop the failed instance's device KV mirror (a full pool-sized
        # copy) — it will be rebuilt from scratch if the instance rejoins
        if inst < len(self.pool.pools):
            self.pool.pools[inst].drop_mirror()
        # purge requeued (now-PENDING) requests and the dead instance from
        # waiting decode groups so they are not scheduled with freed KV
        for g in list(self.ready_decode):
            g.requests = [r for r in g.requests if r.phase is Phase.DECODE]
            g.instances = [i for i in g.instances if i not in self.failed]
            if not g.requests:
                self.ready_decode.remove(g)

    def _drop_request_state(self, rids) -> None:
        for rid in rids:
            self._real_cache.pop(rid, None)

    def _checkpoint_extra(self):
        return {"ready_decode": self.ready_decode}

    def _restore_extra(self, extra) -> None:
        if extra:
            self.ready_decode = extra["ready_decode"]
        # transient launch-time state is keyed by id() of pre-restore batch
        # objects — drop it (in-flight completions fall back to the
        # phase-only liveness filter)
        self._running_decode_ends = {}
        self._decode_launch_seq = {}
        self._prefill_launch_epoch = {}

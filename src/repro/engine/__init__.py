"""Serving engine: elastic instances, request lifecycle, iteration loop."""
from repro.engine.request import Request, Phase  # noqa: F401

"""Model zoo: composable pure-JAX architectures driven by ModelConfig."""
from __future__ import annotations

from repro.configs.base import ModelConfig


def build_model(cfg: ModelConfig, **kwargs):
    """Factory: returns the right model class for the config family."""
    if cfg.is_encoder_decoder:
        from repro.models.encdec import EncDecModel

        return EncDecModel(cfg, **kwargs)
    from repro.models.transformer import Model

    return Model(cfg, **kwargs)

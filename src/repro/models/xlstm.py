"""xLSTM blocks: mLSTM (matrix memory, chunkwise-parallel prefill) and sLSTM
(scalar memory, inherently sequential scan — xLSTM paper §2.3).

The mLSTM chunkwise form mirrors the TFLA formulation with max-stabilized
exponential gating; the chunk-final (C, n, m) state is the sequence-parallel
handoff object (core/ring.py). Decode is an O(1) recurrent step for both.

Simplifications vs. the reference implementation (noted in DESIGN.md): no
causal conv preceding q/k, single projection block wrapper for both cell
types. Numerics (stabilizers, gating) follow the paper.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers


class MLSTMState(NamedTuple):
    c: jnp.ndarray  # [B, H, Dv, Dk] f32 matrix memory
    n: jnp.ndarray  # [B, H, Dk] f32 normalizer
    m: jnp.ndarray  # [B, H] f32 stabilizer


class SLSTMState(NamedTuple):
    c: jnp.ndarray  # [B, D_in] f32
    n: jnp.ndarray  # [B, D_in]
    h: jnp.ndarray  # [B, D_in]
    m: jnp.ndarray  # [B, D_in]


def _d_inner(cfg) -> int:
    return int(cfg.xlstm_proj_factor * cfg.d_model)


# ------------------------------------------------------------------ mLSTM


def init_mlstm(key, cfg, dtype) -> dict:
    d, d_in = cfg.d_model, _d_inner(cfg)
    h = cfg.n_heads
    ks = layers.split_keys(key, 8)
    return {
        "w_up": layers.normal_init(ks[0], (d, 2 * d_in), dtype),
        "w_q": layers.normal_init(ks[1], (d_in, d_in), dtype),
        "w_k": layers.normal_init(ks[2], (d_in, d_in), dtype),
        "w_v": layers.normal_init(ks[3], (d_in, d_in), dtype),
        "w_o": layers.normal_init(ks[4], (d_in, d_in), dtype),
        "w_if": layers.normal_init(ks[5], (d_in, 2 * h), dtype, scale=0.1),
        "b_i": jnp.zeros((h,), jnp.float32),
        "b_f": jnp.full((h,), 3.0, jnp.float32),  # forget-bias init
        "w_down": layers.normal_init(ks[6], (d_in, d), dtype),
    }


def _mlstm_qkvif(p, x, cfg):
    d_in = _d_inner(cfg)
    h = cfg.n_heads
    dh = d_in // h
    up = jnp.einsum("btd,de->bte", x, p["w_up"])
    xm, z = up[..., :d_in], up[..., d_in:]
    q = jnp.einsum("bte,ef->btf", xm, p["w_q"]).reshape(*x.shape[:2], h, dh)
    k = jnp.einsum("bte,ef->btf", xm, p["w_k"]).reshape(*x.shape[:2], h, dh)
    v = jnp.einsum("bte,ef->btf", xm, p["w_v"]).reshape(*x.shape[:2], h, dh)
    o = jax.nn.sigmoid(jnp.einsum("bte,ef->btf", xm, p["w_o"]))
    gif = jnp.einsum("bte,eg->btg", xm, p["w_if"]).astype(jnp.float32)
    ig = gif[..., :h] + p["b_i"]
    fg = gif[..., h:] + p["b_f"]
    return q, k, v, o, ig, fg, z, dh


def mlstm_chunkwise(
    q, k, v, ig, fg, chunk: int, state: Optional[MLSTMState] = None
) -> Tuple[jnp.ndarray, MLSTMState]:
    """q,k,v: [B,T,H,Dh]; ig,fg: [B,T,H] raw gates. Returns ([B,T,H,Dh], state)."""
    bsz, t_orig, h, dh = q.shape
    # pad to chunk multiple: i-gate -> -inf (no contribution), f-gate -> +40
    # (log sigmoid ~ 0, state passes through unchanged).
    pad = (-t_orig) % chunk
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        ig = jnp.pad(ig, ((0, 0), (0, pad), (0, 0)), constant_values=-1e9)
        fg = jnp.pad(fg, ((0, 0), (0, pad), (0, 0)), constant_values=40.0)
    t = t_orig + pad
    nc = t // chunk
    scale = dh**-0.5
    qf = q.astype(jnp.float32) * scale
    kf, vf = k.astype(jnp.float32), v.astype(jnp.float32)
    logf = jax.nn.log_sigmoid(fg)  # [B,T,H]

    def rs(a):  # [B,T,...] -> [nc, B, L, ...]
        return jnp.moveaxis(a.reshape(bsz, nc, chunk, *a.shape[2:]), 1, 0)

    qc, kc, vc = rs(qf), rs(kf), rs(vf)
    gc, lfc = rs(ig.astype(jnp.float32)), rs(logf)

    if state is None:
        state = init_mlstm_state_raw(bsz, h, dh, dh)
    ii = jnp.arange(chunk)
    tri = ii[:, None] >= ii[None, :]  # causal within chunk

    def body(carry, inputs):
      with jax.named_scope("mlstm_chunk_body"):
        c_prev, n_prev, m_prev = carry
        qk_, kk_, vk_, gk_, lfk_ = inputs  # [B,L,H,dh] / [B,L,H]
        b = jnp.cumsum(lfk_, axis=1)  # [B,L,H] inclusive cumsum of logf
        # stabilizers
        gmb = gk_ - b  # g_j - b_j
        m_intra = b + jax.lax.cummax(gmb, axis=1)  # [B,L,H]
        m_inter = b + m_prev[:, None, :]
        m_i = jnp.maximum(m_intra, m_inter)  # [B,L,H]
        # inter-chunk contribution
        w_inter = jnp.exp(m_inter - m_i)  # [B,L,H]
        num_inter = jnp.einsum("blhk,bhvk->blhv", qk_, c_prev) * w_inter[..., None]
        den_inter = jnp.einsum("blhk,bhk->blh", qk_, n_prev) * w_inter
        # intra-chunk scores
        s = jnp.einsum("bihk,bjhk->bijh", qk_, kk_)  # [B,L,L,H]
        dmat = b[:, :, None, :] - b[:, None, :, :] + gk_[:, None, :, :] - m_i[:, :, None, :]
        s = s * jnp.where(tri[None, :, :, None], jnp.exp(dmat), 0.0)
        num = num_inter + jnp.einsum("bijh,bjhv->bihv", s, vk_)
        den = den_inter + jnp.sum(s, axis=2)  # [B,L,H]
        hshape = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_i))[..., None]
        # chunk-final state
        btot = b[:, -1, :]  # [B,H]
        m_loc = jnp.max(btot[:, None, :] - b + gk_, axis=1)  # [B,H]
        m_new = jnp.maximum(btot + m_prev, m_loc)
        wj = jnp.exp(btot[:, None, :] - b + gk_ - m_new[:, None, :])  # [B,L,H]
        c_new = c_prev * jnp.exp(btot + m_prev - m_new)[:, :, None, None] + jnp.einsum(
            "blh,blhv,blhk->bhvk", wj, vk_, kk_
        )
        n_new = n_prev * jnp.exp(btot + m_prev - m_new)[:, :, None] + jnp.einsum(
            "blh,blhk->bhk", wj, kk_
        )
        return (c_new, n_new, m_new), hshape

    (c, n, m), hs = jax.lax.scan(body, (state.c, state.n, state.m), (qc, kc, vc, gc, lfc))
    out = jnp.moveaxis(hs, 0, 1).reshape(bsz, t, h, dh)[:, :t_orig]
    return out.astype(q.dtype), MLSTMState(c, n, m)


def mlstm_state_only(
    k, v, ig, fg, chunk: int, state: Optional[MLSTMState] = None
) -> Tuple[MLSTMState, jnp.ndarray]:
    """Segment-state fold for sequence parallelism: chunk-final (C, n, m)
    from `state` (default zero/-inf identity) plus the segment's total
    log-forget mass btot [B,H]. Skips all output math."""
    bsz, t_orig, h, dh = k.shape
    pad = (-t_orig) % chunk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        ig = jnp.pad(ig, ((0, 0), (0, pad), (0, 0)), constant_values=-1e9)
        fg = jnp.pad(fg, ((0, 0), (0, pad), (0, 0)), constant_values=40.0)
    t = t_orig + pad
    nc = t // chunk
    kf, vf = k.astype(jnp.float32), v.astype(jnp.float32)
    logf = jax.nn.log_sigmoid(fg.astype(jnp.float32))

    def rs(a):
        return jnp.moveaxis(a.reshape(bsz, nc, chunk, *a.shape[2:]), 1, 0)

    kc, vc, gc, lfc = rs(kf), rs(vf), rs(ig.astype(jnp.float32)), rs(logf)
    if state is None:
        state = init_mlstm_state_raw(bsz, h, dh, dh)

    def body(carry, inputs):
        c_prev, n_prev, m_prev, bacc = carry
        kk_, vk_, gk_, lfk_ = inputs
        b = jnp.cumsum(lfk_, axis=1)
        btot = b[:, -1, :]
        m_loc = jnp.max(btot[:, None, :] - b + gk_, axis=1)
        m_new = jnp.maximum(btot + m_prev, m_loc)
        wj = jnp.exp(btot[:, None, :] - b + gk_ - m_new[:, None, :])
        scale = jnp.exp(btot + m_prev - m_new)
        c_new = c_prev * scale[:, :, None, None] + jnp.einsum(
            "blh,blhv,blhk->bhvk", wj, vk_, kk_
        )
        n_new = n_prev * scale[:, :, None] + jnp.einsum("blh,blhk->bhk", wj, kk_)
        return (c_new, n_new, m_new, bacc + btot), None

    (c, n, m, btot), _ = jax.lax.scan(
        body,
        (state.c, state.n, state.m, jnp.zeros((bsz, h), jnp.float32)),
        (kc, vc, gc, lfc),
    )
    return MLSTMState(c, n, m), btot


def mlstm_combine_states(
    s1: MLSTMState, s2: MLSTMState, btot2: jnp.ndarray
) -> MLSTMState:
    """Monoid combine: s1 followed by a segment with state s2 / log-forget
    mass btot2 (max-stabilized log-space)."""
    m = jnp.maximum(s1.m + btot2, s2.m)
    w1 = jnp.where(jnp.isinf(s1.m), 0.0, jnp.exp(s1.m + btot2 - jnp.where(jnp.isinf(m), 0.0, m)))
    w2 = jnp.where(jnp.isinf(s2.m), 0.0, jnp.exp(s2.m - jnp.where(jnp.isinf(m), 0.0, m)))
    return MLSTMState(
        c=s1.c * w1[..., None, None] + s2.c * w2[..., None, None],
        n=s1.n * w1[..., None] + s2.n * w2[..., None],
        m=m,
    )


def mlstm_step(q, k, v, ig, fg, state: MLSTMState) -> Tuple[jnp.ndarray, MLSTMState]:
    """One decode step. q,k,v [B,H,Dh]; ig,fg [B,H]."""
    dh = q.shape[-1]
    qf = q.astype(jnp.float32) * dh**-0.5
    kf, vf = k.astype(jnp.float32), v.astype(jnp.float32)
    logf = jax.nn.log_sigmoid(fg.astype(jnp.float32))
    igf = ig.astype(jnp.float32)
    m_new = jnp.maximum(logf + state.m, igf)
    fprime = jnp.exp(logf + state.m - m_new)
    iprime = jnp.exp(igf - m_new)
    c = state.c * fprime[..., None, None] + iprime[..., None, None] * jnp.einsum(
        "bhv,bhk->bhvk", vf, kf
    )
    n = state.n * fprime[..., None] + iprime[..., None] * kf
    num = jnp.einsum("bhk,bhvk->bhv", qf, c)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", qf, n)), jnp.exp(-m_new))
    return (num / den[..., None]).astype(q.dtype), MLSTMState(c, n, m_new)


def mlstm_block_forward(p, x, cfg, state=None, *, chunk: Optional[int] = None):
    """x: [B,T,d] (post-norm). Returns (out [B,T,d], MLSTMState)."""
    q, k, v, o, ig, fg, z, dh = _mlstm_qkvif(p, x, cfg)
    ck = chunk or (cfg.ssm_chunk if cfg.ssm_chunk else 64)
    ck = min(ck, x.shape[1])
    htilde, st = mlstm_chunkwise(q, k, v, ig, fg, ck, state)
    h = htilde.reshape(*x.shape[:2], -1) * o
    h = h * jax.nn.silu(z)
    return jnp.einsum("bte,ed->btd", h, p["w_down"]), st


def mlstm_block_step(p, x, cfg, state: MLSTMState):
    """x: [B,1,d]."""
    q, k, v, o, ig, fg, z, dh = _mlstm_qkvif(p, x, cfg)
    htilde, st = mlstm_step(q[:, 0], k[:, 0], v[:, 0], ig[:, 0], fg[:, 0], state)
    h = htilde.reshape(x.shape[0], 1, -1) * o
    h = h * jax.nn.silu(z)
    return jnp.einsum("bte,ed->btd", h, p["w_down"]), st


def init_mlstm_state_raw(b, h, dv, dk) -> MLSTMState:
    return MLSTMState(
        c=jnp.zeros((b, h, dv, dk), jnp.float32),
        n=jnp.zeros((b, h, dk), jnp.float32),
        m=jnp.full((b, h), -jnp.inf, jnp.float32),
    )


def init_mlstm_state(cfg, batch: int) -> MLSTMState:
    dh = _d_inner(cfg) // cfg.n_heads
    return init_mlstm_state_raw(batch, cfg.n_heads, dh, dh)


# ------------------------------------------------------------------ sLSTM


def init_slstm(key, cfg, dtype) -> dict:
    d, d_in = cfg.d_model, _d_inner(cfg)
    h = cfg.n_heads
    dh = d_in // h
    ks = layers.split_keys(key, 8)
    return {
        "w_up": layers.normal_init(ks[0], (d, 2 * d_in), dtype),
        "w_zifo": layers.normal_init(ks[1], (d_in, 4 * d_in), dtype),
        "r_zifo": layers.normal_init(ks[2], (4, h, dh, dh), dtype, scale=0.05),
        "b_zifo": jnp.zeros((4 * d_in,), jnp.float32),
        "w_down": layers.normal_init(ks[3], (d_in, d), dtype),
    }


def slstm_scan(p, xm, cfg, state: SLSTMState) -> Tuple[jnp.ndarray, SLSTMState]:
    """xm: [B,T,d_in] pre-activations input; sequential over T."""
    d_in = _d_inner(cfg)
    h = cfg.n_heads
    dh = d_in // h
    wx = jnp.einsum("bte,ef->btf", xm, p["w_zifo"]).astype(jnp.float32)  # [B,T,4*d_in]

    def body(carry, wxt):
      with jax.named_scope("slstm_step_body"):
        c, n, hid, m = carry
        hh = hid.reshape(-1, h, dh)
        rec = jnp.einsum("bhd,ghde->bghe", hh, p["r_zifo"].astype(jnp.float32))
        rec = rec.reshape(-1, 4 * d_in)
        pre = wxt + rec + p["b_zifo"]
        zt = jnp.tanh(pre[:, :d_in])
        it = pre[:, d_in : 2 * d_in]
        ft = pre[:, 2 * d_in : 3 * d_in]
        ot = jax.nn.sigmoid(pre[:, 3 * d_in :])
        m_new = jnp.maximum(ft + m, it)
        iprime = jnp.exp(it - m_new)
        fprime = jnp.exp(ft + m - m_new)
        c_new = fprime * c + iprime * zt
        n_new = fprime * n + iprime
        h_new = ot * (c_new / n_new)
        return (c_new, n_new, h_new, m_new), h_new

    xs = jnp.moveaxis(wx, 1, 0)  # [T,B,4d_in]
    (c, n, hid, m), hs = jax.lax.scan(body, tuple(state), xs)
    out = jnp.moveaxis(hs, 0, 1)  # [B,T,d_in]
    return out.astype(xm.dtype), SLSTMState(c, n, hid, m)


def slstm_block_forward(p, x, cfg, state=None):
    d_in = _d_inner(cfg)
    up = jnp.einsum("btd,de->bte", x, p["w_up"])
    xm, z = up[..., :d_in], up[..., d_in:]
    if state is None:
        state = init_slstm_state(cfg, x.shape[0])
    hseq, st = slstm_scan(p, xm, cfg, state)
    h = hseq * jax.nn.silu(z)
    return jnp.einsum("bte,ed->btd", h, p["w_down"]), st


def slstm_block_step(p, x, cfg, state: SLSTMState):
    return slstm_block_forward(p, x, cfg, state)


def init_slstm_state(cfg, batch: int) -> SLSTMState:
    d_in = _d_inner(cfg)
    return SLSTMState(
        c=jnp.zeros((batch, d_in), jnp.float32),
        n=jnp.full((batch, d_in), 1e-6, jnp.float32),
        h=jnp.zeros((batch, d_in), jnp.float32),
        m=jnp.zeros((batch, d_in), jnp.float32),
    )

"""Mixture-of-Experts layer: top-k router + sort-based capacity dispatch.

Dispatch is gather/scatter (argsort) based, NOT one-hot-einsum based, so the
compiled FLOP count reflects only *active* expert compute (top_k/E of dense),
which keeps roofline accounting honest, and the [E, C, d] grouped layout maps
directly onto expert-parallel sharding (experts over `model`, expert-hidden
over `data` for arctic; per-expert TP for mixtral). See DESIGN.md §3.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models import layers


class MoEOutput(NamedTuple):
    out: jnp.ndarray
    aux_loss: jnp.ndarray  # load-balancing loss (scalar, f32)
    dropped_frac: jnp.ndarray  # fraction of assignments dropped by capacity


def init_moe(key, d: int, f: int, n_experts: int, ffn_kind: str, dtype) -> dict:
    ks = layers.split_keys(key, 4)
    p = {
        "router": layers.normal_init(ks[0], (d, n_experts), dtype, scale=0.02),
        "w_up": layers.normal_init(ks[1], (n_experts, d, f), dtype),
        "w_down": layers.normal_init(ks[2], (n_experts, f, d), dtype),
    }
    if ffn_kind == "swiglu":
        p["w_gate"] = layers.normal_init(ks[3], (n_experts, d, f), dtype)
    return p


def capacity(n_tokens: int, n_experts: int, top_k: int, factor: float) -> int:
    c = int(n_tokens * top_k * factor / n_experts) + 1
    return max(8, -(-c // 8) * 8)  # round up to multiple of 8


def apply_moe(
    p: dict,
    x: jnp.ndarray,  # [T, d] flat tokens
    *,
    top_k: int,
    capacity_factor: float,
    ffn_kind: str,
    constrain=None,  # optional fn(tensor, kind) -> tensor for sharding hints
) -> MoEOutput:
    t, d = x.shape
    e = p["router"].shape[1]
    cap = capacity(t, e, top_k, capacity_factor)
    cid = constrain or (lambda a, _k: a)

    logits = jnp.einsum(
        "td,de->te", x, p["router"], preferred_element_type=jnp.float32
    )
    probs = jax.nn.softmax(logits, axis=-1)  # [T, E] f32
    top_p, top_i = jax.lax.top_k(probs, top_k)  # [T, k]
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)  # renormalize

    # ---- sort-based dispatch ----
    flat_e = top_i.reshape(-1)  # [T*k]
    flat_w = top_p.reshape(-1)
    flat_t = jnp.arange(t * top_k) // top_k  # owning token of each slot
    order = jnp.argsort(flat_e)  # stable
    se, sw, st = flat_e[order], flat_w[order], flat_t[order]
    counts = jnp.bincount(flat_e, length=e)  # [E]
    start = jnp.cumsum(counts) - counts  # exclusive prefix
    pos = jnp.arange(t * top_k) - start[se]  # position within expert bucket
    keep = pos < cap
    dropped = 1.0 - jnp.mean(keep.astype(jnp.float32))

    # scatter tokens into the [E, C, d] grouped buffer
    xin = jnp.where(keep[:, None], x[st], 0).astype(x.dtype)
    buf = jnp.zeros((e, cap, d), x.dtype)
    buf = buf.at[se, jnp.where(keep, pos, 0)].add(xin, mode="drop")
    buf = cid(buf, "moe_group")  # [E, C, d] - EP sharding hint

    # ---- expert FFN on grouped tokens ----
    up = jnp.einsum("ecd,edf->ecf", buf, p["w_up"])
    if ffn_kind == "swiglu":
        gate = jnp.einsum("ecd,edf->ecf", buf, p["w_gate"])
        h = jax.nn.silu(gate) * up
    elif ffn_kind == "relu2":
        r = jax.nn.relu(up)
        h = r * r
    else:
        h = jax.nn.gelu(up)
    h = cid(h, "moe_hidden")
    eout = jnp.einsum("ecf,efd->ecd", h, p["w_down"])  # [E, C, d]
    eout = cid(eout, "moe_group")

    # ---- combine back (weighted scatter-add into tokens) ----
    contrib = eout[se, jnp.where(keep, pos, 0)]  # [T*k, d]
    contrib = contrib * (sw * keep).astype(contrib.dtype)[:, None]
    out = jnp.zeros((t, d), contrib.dtype).at[st].add(contrib)

    # Switch-transformer load-balance aux: E * sum(frac_tokens * frac_prob)
    frac_tokens = counts.astype(jnp.float32) / (t * top_k)
    frac_prob = jnp.mean(probs, axis=0)
    aux = e * jnp.sum(frac_tokens * frac_prob)
    return MoEOutput(out.astype(x.dtype), aux, dropped)

"""Shared primitive layers: norms, RoPE, FFN variants, embeddings, init.

Everything is a pure function over explicit param pytrees (dicts of jnp
arrays). No framework dependency; `jax.lax.scan` over stacked layer params is
used by the model builders so HLO size is independent of depth.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------- init utils


def normal_init(key, shape, dtype, scale: float = 0.02):
    return (scale * jax.random.normal(key, shape)).astype(dtype)


def zeros_init(_key, shape, dtype):
    return jnp.zeros(shape, dtype)


def ones_init(_key, shape, dtype):
    return jnp.ones(shape, dtype)


def split_keys(key, n):
    return list(jax.random.split(key, n))


# ---------------------------------------------------------------- norms


def init_norm(key, d: int, kind: str, dtype) -> dict:
    p = {"scale": jnp.ones((d,), dtype)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((d,), dtype)
    return p


def apply_norm(p: dict, x: jnp.ndarray, kind: str, eps: float = 1e-5) -> jnp.ndarray:
    """RMSNorm / LayerNorm with f32 statistics, output in x.dtype."""
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + eps)
    elif kind == "layernorm":
        mean = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mean) * jax.lax.rsqrt(var + eps)
    else:  # pragma: no cover
        raise ValueError(kind)
    y = y * p["scale"].astype(jnp.float32)
    if "bias" in p:
        y = y + p["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------- RoPE


def rope_cos_sin(positions: jnp.ndarray, d_rot: int, theta: float):
    """cos/sin tables for rotary embedding.

    positions: int array [...]; returns (cos, sin) of shape [..., d_rot//2],
    float32.
    """
    inv_freq = 1.0 / (
        theta ** (jnp.arange(0, d_rot, 2, dtype=jnp.float32) / d_rot)
    )
    ang = positions.astype(jnp.float32)[..., None] * inv_freq  # [..., d_rot/2]
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray, d_rot: int):
    """Rotate the first `d_rot` features of the last dim of x.

    x: [..., S, H, D]; cos/sin: [..., S, d_rot//2] (broadcast over H).
    Uses the interleaved-pair ("GPT-NeoX half-split") convention.
    """
    if d_rot == 0:
        return x
    rot, rest = x[..., :d_rot], x[..., d_rot:]
    x1, x2 = rot[..., : d_rot // 2], rot[..., d_rot // 2 :]
    c = cos[..., None, :].astype(x.dtype)  # broadcast over head dim
    s = sin[..., None, :].astype(x.dtype)
    r1 = x1 * c - x2 * s
    r2 = x2 * c + x1 * s
    return jnp.concatenate([r1, r2, rest], axis=-1)


def sinusoidal_positions(n: int, d: int) -> jnp.ndarray:
    """Whisper-style fixed sinusoidal embeddings [n, d] (float32)."""
    half = d // 2
    log_timescale = math.log(10000.0) / max(half - 1, 1)
    inv = jnp.exp(-log_timescale * jnp.arange(half, dtype=jnp.float32))
    scaled = jnp.arange(n, dtype=jnp.float32)[:, None] * inv[None, :]
    return jnp.concatenate([jnp.sin(scaled), jnp.cos(scaled)], axis=1)


# ---------------------------------------------------------------- FFN


def init_ffn(key, d: int, f: int, kind: str, dtype) -> dict:
    ks = split_keys(key, 3)
    if kind == "swiglu":
        return {
            "w_gate": normal_init(ks[0], (d, f), dtype),
            "w_up": normal_init(ks[1], (d, f), dtype),
            "w_down": normal_init(ks[2], (f, d), dtype),
        }
    return {
        "w_up": normal_init(ks[0], (d, f), dtype),
        "w_down": normal_init(ks[1], (f, d), dtype),
    }


def apply_ffn(p: dict, x: jnp.ndarray, kind: str) -> jnp.ndarray:
    if kind == "swiglu":
        g = jnp.einsum("...d,df->...f", x, p["w_gate"])
        u = jnp.einsum("...d,df->...f", x, p["w_up"])
        h = jax.nn.silu(g) * u
    elif kind == "relu2":
        u = jnp.einsum("...d,df->...f", x, p["w_up"])
        r = jax.nn.relu(u)
        h = r * r
    elif kind == "gelu":
        u = jnp.einsum("...d,df->...f", x, p["w_up"])
        h = jax.nn.gelu(u)
    else:  # pragma: no cover
        raise ValueError(kind)
    return jnp.einsum("...f,fd->...d", h, p["w_down"])


# ---------------------------------------------------------------- embeddings


def init_embed(key, vocab: int, d: int, dtype) -> jnp.ndarray:
    return normal_init(key, (vocab, d), dtype)


def embed_lookup(table: jnp.ndarray, ids: jnp.ndarray) -> jnp.ndarray:
    # one_hot-free gather; XLA turns this into a dynamic-gather.
    return jnp.take(table, ids, axis=0)


def lm_head_logits(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """[..., d] x [d, vocab] -> f32 logits (softmax stability)."""
    return jnp.einsum(
        "...d,dv->...v", x, w, preferred_element_type=jnp.float32
    )

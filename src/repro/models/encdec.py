"""Whisper-style encoder-decoder model (audio family).

The conv/audio frontend is a STUB per the brief: `batch["frames"]` carries
precomputed frame embeddings [B, encoder_seq, d_model]. Encoder = bidirectional
attention stack; decoder = causal self-attn (KV-cached, ESP-managed) +
cross-attn over the encoder output (static KV, sharded once — no ring needed,
DESIGN.md §4).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import layers
from repro.models.transformer import Cache, DefaultAttnImpl, _id_constrain


class EncDecModel:
    def __init__(self, cfg: ModelConfig, attn_impl=None, constrain=None,
                 remat: bool = False):
        assert cfg.is_encoder_decoder
        self.cfg = cfg
        self.attn_impl = attn_impl or DefaultAttnImpl()
        self.constrain = constrain or _id_constrain
        self.remat = remat
        self.dtype = jnp.dtype(cfg.dtype)

    # ------------------------------------------------------------------ init
    def _init_attn(self, key, kv_from_d: Optional[int] = None) -> dict:
        cfg, dt = self.cfg, self.dtype
        hd = cfg.head_dim
        ks = layers.split_keys(key, 4)
        return {
            "wq": layers.normal_init(ks[0], (cfg.d_model, cfg.n_heads, hd), dt),
            "wk": layers.normal_init(ks[1], (kv_from_d or cfg.d_model, cfg.n_kv_heads, hd), dt),
            "wv": layers.normal_init(ks[2], (kv_from_d or cfg.d_model, cfg.n_kv_heads, hd), dt),
            "wo": layers.normal_init(ks[3], (cfg.n_heads, hd, cfg.d_model), dt),
        }

    def _init_enc_layer(self, key) -> dict:
        cfg, dt = self.cfg, self.dtype
        ks = layers.split_keys(key, 4)
        return {
            "attn": self._init_attn(ks[0]),
            "norm1": layers.init_norm(ks[1], cfg.d_model, cfg.norm_kind, dt),
            "ffn": layers.init_ffn(ks[2], cfg.d_model, cfg.d_ff, cfg.ffn_kind, dt),
            "norm2": layers.init_norm(ks[3], cfg.d_model, cfg.norm_kind, dt),
        }

    def _init_dec_layer(self, key) -> dict:
        cfg, dt = self.cfg, self.dtype
        ks = layers.split_keys(key, 6)
        return {
            "self_attn": self._init_attn(ks[0]),
            "cross_attn": self._init_attn(ks[1]),
            "norm1": layers.init_norm(ks[2], cfg.d_model, cfg.norm_kind, dt),
            "norm2": layers.init_norm(ks[3], cfg.d_model, cfg.norm_kind, dt),
            "norm3": layers.init_norm(ks[4], cfg.d_model, cfg.norm_kind, dt),
            "ffn": layers.init_ffn(ks[5], cfg.d_model, cfg.d_ff, cfg.ffn_kind, dt),
        }

    def init(self, key) -> Dict[str, Any]:
        cfg, dt = self.cfg, self.dtype
        ks = layers.split_keys(key, 6)
        enc_keys = jax.random.split(ks[0], cfg.n_encoder_layers)
        dec_keys = jax.random.split(ks[1], cfg.n_layers)
        return {
            "embed": layers.init_embed(ks[2], cfg.vocab_size, cfg.d_model, dt),
            "pos_embed": layers.normal_init(
                ks[3], (cfg.max_seq_len, cfg.d_model), dt, scale=0.01
            ),
            "enc_layers": jax.vmap(self._init_enc_layer)(enc_keys),
            "dec_layers": jax.vmap(self._init_dec_layer)(dec_keys),
            "enc_norm": layers.init_norm(ks[4], cfg.d_model, cfg.norm_kind, dt),
            "final_norm": layers.init_norm(ks[5], cfg.d_model, cfg.norm_kind, dt),
            "lm_head": layers.normal_init(ks[2], (cfg.d_model, cfg.vocab_size), dt),
        }

    # ------------------------------------------------------------- attention
    def _qkv(self, p, xq, xkv):
        cfg = self.cfg
        q = jnp.einsum("btd,dhk->bthk", xq, p["wq"])
        k = jnp.einsum("btd,dhk->bthk", xkv, p["wk"])
        v = jnp.einsum("btd,dhk->bthk", xkv, p["wv"])
        return self.constrain(q, "q"), self.constrain(k, "kv"), self.constrain(v, "kv")

    # --------------------------------------------------------------- encoder
    def encode(self, params, frames: jnp.ndarray) -> jnp.ndarray:
        cfg = self.cfg
        x = frames.astype(self.dtype)
        x = x + layers.sinusoidal_positions(x.shape[1], cfg.d_model).astype(self.dtype)
        x = self.constrain(x, "enc_act")

        def body(x, lp):
            h = layers.apply_norm(lp["norm1"], x, cfg.norm_kind, cfg.norm_eps)
            q, k, v = self._qkv(lp["attn"], h, h)
            # encoder attention is dense/local (fixed 1500-frame sequence,
            # batch-sharded): no ESP ring needed (DESIGN.md §4)
            o = attn.full_attention(q, k, v, causal=False)
            x = x + jnp.einsum("bthk,hkd->btd", o, lp["attn"]["wo"])
            h = layers.apply_norm(lp["norm2"], x, cfg.norm_kind, cfg.norm_eps)
            x = x + layers.apply_ffn(lp["ffn"], h, cfg.ffn_kind)
            return self.constrain(x, "enc_act"), None

        fn = jax.checkpoint(body) if self.remat else body
        x, _ = jax.lax.scan(fn, x, params["enc_layers"])
        return layers.apply_norm(params["enc_norm"], x, cfg.norm_kind, cfg.norm_eps)

    # --------------------------------------------------------------- decoder
    def _decoder_stack(self, params, x, enc_out, positions, *, return_kv,
                       k_caches=None, v_caches=None, cross_k=None, cross_v=None,
                       cache_len=None, decode=False):
        cfg = self.cfg

        def body(x, lp, kc=None, vc=None, ck=None, cv=None):
            if decode:
                pass
            # self attention
            h = layers.apply_norm(lp["norm1"], x, cfg.norm_kind, cfg.norm_eps)
            if decode:
                b = x.shape[0]
                cl = jnp.broadcast_to(jnp.asarray(cache_len), (b,))
                q, k_new, v_new = self._qkv(lp["self_attn"], h, h)
                o = self.attn_impl.decode_attn(
                    q, kc, vc, k_new, v_new, cl, window=None, softcap=None
                )
                kv = (k_new, v_new)
            else:
                q, k, v = self._qkv(lp["self_attn"], h, h)
                o = self.attn_impl.prefill_attn(
                    q, k, v, positions, positions, causal=True, window=None,
                    softcap=None,
                )
                kv = (k, v) if return_kv else None
            x = self.constrain(
                x + jnp.einsum("bthk,hkd->btd", o, lp["self_attn"]["wo"]), "act"
            )
            # cross attention (static encoder KV)
            h = layers.apply_norm(lp["norm2"], x, cfg.norm_kind, cfg.norm_eps)
            if decode:
                q = jnp.einsum("btd,dhk->bthk", h, lp["cross_attn"]["wq"])
                o = attn.full_attention(q, ck, cv, causal=False)
                cross_kv = None
            else:
                q, ck_, cv_ = self._qkv(lp["cross_attn"], h, enc_out)
                o = attn.full_attention(q, ck_, cv_, causal=False)
                cross_kv = (ck_, cv_) if return_kv else None
            x = self.constrain(
                x + jnp.einsum("bthk,hkd->btd", o, lp["cross_attn"]["wo"]), "act"
            )
            h = layers.apply_norm(lp["norm3"], x, cfg.norm_kind, cfg.norm_eps)
            x = self.constrain(x + layers.apply_ffn(lp["ffn"], h, cfg.ffn_kind), "act")
            return x, (kv, cross_kv)

        if decode:
            # static python loop (see transformer._dense_stack decode note)
            kv_list = []
            for li in range(k_caches.shape[0]):
                lp = jax.tree.map(lambda a: a[li], params["dec_layers"])
                x, (kv, _) = body(x, lp, k_caches[li], v_caches[li],
                                  cross_k[li], cross_v[li])
                kv_list.append(kv)
            kvs = jax.tree.map(lambda *xs: jnp.stack(xs), *kv_list)
            return x, kvs, None

        def scan_body(x, lp):
            return body(x, lp)

        fn = jax.checkpoint(scan_body) if self.remat else scan_body
        x, (kvs, cross_kvs) = jax.lax.scan(fn, x, params["dec_layers"])
        return x, kvs, cross_kvs

    def _embed_tokens(self, params, tokens, positions):
        x = layers.embed_lookup(params["embed"], tokens).astype(self.dtype)
        pe = jnp.take(params["pos_embed"], positions, axis=0).astype(self.dtype)
        if pe.ndim == 2:
            pe = pe[None]
        return self.constrain(x + pe, "act")

    # ---------------------------------------------------------------- public
    def hidden(self, params, batch, positions=None):
        """Pre-unembed decoder hidden states (chunked-loss training path)."""
        enc_out = self.constrain(self.encode(params, batch["frames"]), "enc_out")
        t = batch["tokens"].shape[1]
        if positions is None:
            positions = jnp.arange(t)
        x = self._embed_tokens(params, batch["tokens"], positions)
        x, _, _ = self._decoder_stack(
            params, x, enc_out, positions, return_kv=False
        )
        x = layers.apply_norm(params["final_norm"], x, self.cfg.norm_kind,
                              self.cfg.norm_eps)
        return x, jnp.float32(0.0)

    def unembed(self, params, x):
        return self.constrain(
            layers.lm_head_logits(x, params["lm_head"]), "logits"
        )

    def forward(self, params, batch, positions=None):
        """Teacher-forced training forward. batch: {frames, tokens}."""
        x, aux = self.hidden(params, batch, positions)
        return self.unembed(params, x), aux

    def prefill(self, params, batch, positions=None, *, last_logit_only=False):
        enc_out = self.constrain(self.encode(params, batch["frames"]), "enc_out")
        b, t = batch["tokens"].shape
        if positions is None:
            positions = jnp.arange(t)
        x = self._embed_tokens(params, batch["tokens"], positions)
        x, kvs, cross_kvs = self._decoder_stack(
            params, x, enc_out, positions, return_kv=True
        )
        x = layers.apply_norm(params["final_norm"], x, self.cfg.norm_kind,
                              self.cfg.norm_eps)
        if last_logit_only:
            pos = jnp.broadcast_to(jnp.asarray(positions), (t,))
            sel = (pos == jnp.max(pos)).astype(x.dtype)
            x = jnp.einsum("bsd,s->bd", x, sel)[:, None, :]
        logits = layers.lm_head_logits(x, params["lm_head"])
        k, v = kvs
        ck, cv = cross_kvs
        cache = Cache(
            k=k, v=v, length=jnp.full((b,), t, jnp.int32), cross_k=ck, cross_v=cv
        )
        return logits, cache

    def decode(self, params, tokens, cache: Cache):
        cfg = self.cfg
        if tokens.ndim == 1:
            tokens = tokens[:, None]
        b = tokens.shape[0]
        cl = jnp.broadcast_to(jnp.asarray(cache.length), (b,))
        x = self._embed_tokens(params, tokens, cl[:, None])
        x, kvs, _ = self._decoder_stack(
            params, x, None, None, return_kv=False, k_caches=cache.k,
            v_caches=cache.v, cross_k=cache.cross_k, cross_v=cache.cross_v,
            cache_len=cache.length, decode=True,
        )
        x = layers.apply_norm(params["final_norm"], x, cfg.norm_kind, cfg.norm_eps)
        logits = layers.lm_head_logits(x, params["lm_head"])[:, 0]
        new_cache = Cache(
            k=cache.k, v=cache.v, length=cache.length + 1,
            cross_k=cache.cross_k, cross_v=cache.cross_v,
        )
        return logits, new_cache, kvs

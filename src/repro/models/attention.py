"""Attention math: masks, GQA, full/partial (flash-style) attention.

`partial_attention` returns *unnormalized* output + (max, sum-exp) statistics
so that partial results over disjoint KV shards can be combined exactly —
this is the primitive both the striped ESP ring (prefill) and multi-master
distributed decode (LoongServe §4.2 / FlashDecoding-style) are built on.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp

NEG_INF = -1e30

# When True (default) attention dots request f32 accumulation — numerically
# right, and free on TPU (MXU bf16xbf16->f32 is native). XLA:CPU however
# materializes full f32 CONVERTS of the operands, which inflates the dry-run's
# memory_analysis with buffers that do not exist on the target hardware; the
# dry-run flips this off (bf16 dots, f32 softmax stats on the small scores).
_DOT_ACCUM_F32 = True


def set_dot_accum_f32(value: bool) -> None:
    global _DOT_ACCUM_F32
    _DOT_ACCUM_F32 = value


class Partial(NamedTuple):
    """Unnormalized attention partial over one KV shard."""

    o: jnp.ndarray  # [B, Sq, H, D] f32, sum_j exp(s_j - m) v_j
    m: jnp.ndarray  # [B, Sq, H] f32 running max of logits
    l: jnp.ndarray  # [B, Sq, H] f32 sum of exp(s - m)


def empty_partial(b: int, sq: int, h: int, d: int) -> Partial:
    """Partial over an empty KV shard: a no-op under merge_partial
    (m=-inf carries zero weight)."""
    return Partial(
        o=jnp.zeros((b, sq, h, d), jnp.float32),
        m=jnp.full((b, sq, h), -jnp.inf, jnp.float32),
        l=jnp.zeros((b, sq, h), jnp.float32),
    )


def gqa_expand(kv: jnp.ndarray, q_per_kv: int) -> jnp.ndarray:
    """[B, S, KVH, D] -> [B, S, KVH*q_per_kv, D] by repetition."""
    if q_per_kv == 1:
        return kv
    b, s, h, d = kv.shape
    return jnp.broadcast_to(kv[:, :, :, None, :], (b, s, h, q_per_kv, d)).reshape(
        b, s, h * q_per_kv, d
    )


def packed_segment_ids(seq_offsets: jnp.ndarray, t: int) -> jnp.ndarray:
    """Segment id per packed token index for a ragged batch concatenated on
    one token axis: request b owns ``[seq_offsets[b], seq_offsets[b+1])``.
    Tokens past ``seq_offsets[-1]`` (bucket padding) get segment id B and
    never interact with real rows."""
    off = jnp.asarray(seq_offsets, jnp.int32)
    ti = jnp.arange(t, dtype=jnp.int32)
    return jnp.sum(ti[:, None] >= off[None, 1:], axis=1).astype(jnp.int32)


def mask_from_positions(
    q_pos: jnp.ndarray,  # [Sq] or [B, Sq] int32 global positions
    k_pos: jnp.ndarray,  # [Sk] or [B, Sk]
    *,
    causal: bool = True,
    window: Optional[int] = None,
    k_valid: Optional[jnp.ndarray] = None,  # [Sk] or [B, Sk] bool
) -> jnp.ndarray:
    """Boolean mask [.., Sq, Sk]; True = attend. Position-based so it is
    correct under *any* sequence permutation (striped layout)."""
    qp = q_pos[..., :, None]
    kp = k_pos[..., None, :]
    m = jnp.ones(jnp.broadcast_shapes(qp.shape, kp.shape), bool)
    if causal:
        m = m & (qp >= kp)
    if window is not None:
        m = m & (qp - kp < window)
    if k_valid is not None:
        m = m & k_valid[..., None, :]
    return m


def partial_attention(
    q: jnp.ndarray,  # [B, Sq, H, D]
    k: jnp.ndarray,  # [B, Sk, KVH, D]
    v: jnp.ndarray,  # [B, Sk, KVH, D]
    mask: Optional[jnp.ndarray],  # [Sq, Sk] or [B, Sq, Sk] or None
    scale: Optional[float] = None,
    softcap: Optional[float] = None,
) -> Partial:
    with jax.named_scope("esp_partial_attention"):
        return _partial_attention(q, k, v, mask, scale, softcap)


def _partial_attention(q, k, v, mask, scale=None, softcap=None) -> Partial:
    b, sq, h, d = q.shape
    kvh = k.shape[2]
    k = gqa_expand(k, h // kvh)
    v = gqa_expand(v, h // kvh)
    scale = scale if scale is not None else 1.0 / (d**0.5)
    if _DOT_ACCUM_F32:
        s = jnp.einsum(
            "bqhd,bkhd->bhqk", q, k, preferred_element_type=jnp.float32
        )
    else:
        s = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32)
    s = s * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    if mask is not None:
        if mask.ndim == 2:
            mask = mask[None, None]
        elif mask.ndim == 3:
            mask = mask[:, None]
        s = jnp.where(mask, s, NEG_INF)
    m = jnp.max(s, axis=-1)  # [B,H,Sq]
    # guard fully-masked rows: exp(NEG_INF - NEG_INF)=1 would pollute l; use
    # a masked max floor instead.
    m_safe = jnp.maximum(m, -1e29)
    p = jnp.exp(s - m_safe[..., None])
    if mask is not None:
        p = jnp.where(mask, p, 0.0)
    l = jnp.sum(p, axis=-1)  # [B,H,Sq]
    if _DOT_ACCUM_F32:
        o = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    else:
        o = jnp.einsum(
            "bhqk,bkhd->bqhd", p.astype(v.dtype), v
        ).astype(jnp.float32)
    m_out = jnp.where(m <= NEG_INF / 2, -jnp.inf, m_safe)
    return Partial(
        o=o,
        m=jnp.transpose(m_out, (0, 2, 1)),
        l=jnp.transpose(l, (0, 2, 1)),
    )


def combine_partials(parts: Sequence[Partial]) -> jnp.ndarray:
    """Exact combination of partials over disjoint KV shards -> [B,Sq,H,D]."""
    o, m, l = parts[0]
    for p in parts[1:]:
        o, m, l = merge_partial((o, m, l), p)
    return finalize_partial(Partial(o, m, l))


def merge_partial(a, b) -> Partial:
    ao, am, al = a
    bo, bm, bl = b
    m = jnp.maximum(am, bm)
    m_safe = jnp.where(jnp.isinf(m), 0.0, m)
    wa = jnp.where(jnp.isinf(am), 0.0, jnp.exp(am - m_safe))
    wb = jnp.where(jnp.isinf(bm), 0.0, jnp.exp(bm - m_safe))
    return Partial(
        o=ao * wa[..., None] + bo * wb[..., None],
        m=m,
        l=al * wa + bl * wb,
    )


def finalize_partial(p: Partial) -> jnp.ndarray:
    denom = jnp.where(p.l == 0.0, 1.0, p.l)
    return p.o / denom[..., None]


def full_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    q_pos: Optional[jnp.ndarray] = None,
    k_pos: Optional[jnp.ndarray] = None,
    causal: bool = True,
    window: Optional[int] = None,
    k_valid: Optional[jnp.ndarray] = None,
    softcap: Optional[float] = None,
) -> jnp.ndarray:
    """Dense reference attention. Returns [B, Sq, H, D] in q.dtype."""
    sq, sk = q.shape[1], k.shape[1]
    if q_pos is None:
        q_pos = jnp.arange(sq)
    if k_pos is None:
        k_pos = jnp.arange(sk)
    need_mask = causal or window is not None or k_valid is not None
    mask = (
        mask_from_positions(q_pos, k_pos, causal=causal, window=window, k_valid=k_valid)
        if need_mask
        else None
    )
    out = finalize_partial(partial_attention(q, k, v, mask, softcap=softcap))
    return out.astype(q.dtype)


def decode_attention(
    q: jnp.ndarray,  # [B, 1, H, D] (or [B, Sq_new, H, D])
    k_cache: jnp.ndarray,  # [B, S, KVH, D]
    v_cache: jnp.ndarray,
    cache_len: jnp.ndarray,  # [] or [B] int32 - number of valid cached tokens
    *,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
) -> jnp.ndarray:
    """Single-step decode over a (padded) KV cache; the new token's KV must
    already be written at position cache_len-1 (or passed inside the cache)."""
    b, s = k_cache.shape[0], k_cache.shape[1]
    pos = jnp.arange(s)
    cl = jnp.asarray(cache_len)
    if cl.ndim == 0:
        cl = jnp.broadcast_to(cl, (b,))
    k_valid = pos[None, :] < cl[:, None]  # [B, S]
    q_pos = (cl - 1)[:, None]  # [B, 1]
    mask = mask_from_positions(
        q_pos, jnp.broadcast_to(pos, (b, s)), causal=True, window=window, k_valid=k_valid
    )
    out = finalize_partial(partial_attention(q, k_cache, v_cache, mask, softcap=softcap))
    return out.astype(q.dtype)

"""Mamba2 (SSD) layer: chunked-parallel prefill scan + O(1) recurrent decode.

The chunked form precomputes all intra-chunk work in parallel (MXU-friendly
einsums over [n_chunks, L, ...]) and runs a cheap `lax.scan` only for the
inter-chunk state recurrence, which is also the handoff point for sequence
parallelism (core/ring.py passes the chunk-final state across devices with a
log-step device scan).

State per layer: h [B, H, P, N] (heads, head_dim, state) + conv ring buffer.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers


class SSMState(NamedTuple):
    h: jnp.ndarray  # [B, H, P, N] f32
    conv: jnp.ndarray  # [B, W-1, conv_dim] last inputs for causal conv


def init_mamba2(key, d: int, *, expand: int, head_dim: int, state: int,
                conv_width: int, dtype) -> dict:
    d_in = expand * d
    n_heads = d_in // head_dim
    conv_dim = d_in + 2 * state
    ks = layers.split_keys(key, 6)
    return {
        # in_proj -> [z, x, B, C, dt]
        "w_in": layers.normal_init(ks[0], (d, 2 * d_in + 2 * state + n_heads), dtype),
        "conv_w": layers.normal_init(ks[1], (conv_width, conv_dim), dtype, scale=0.5),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.log(
            jax.random.uniform(ks[2], (n_heads,), jnp.float32, 1.0, 16.0)
        ),
        "D": jnp.ones((n_heads,), jnp.float32),
        "dt_bias": jnp.log(
            jnp.exp(jax.random.uniform(ks[3], (n_heads,), jnp.float32, 1e-3, 0.1))
            - 1.0
        ),  # inverse softplus
        "norm_scale": jnp.ones((d_in,), dtype),
        "w_out": layers.normal_init(ks[4], (d_in, d), dtype),
    }


def _split_proj(p, zxbcdt, d_in, state, n_heads):
    z = zxbcdt[..., :d_in]
    x = zxbcdt[..., d_in : 2 * d_in]
    b = zxbcdt[..., 2 * d_in : 2 * d_in + state]
    c = zxbcdt[..., 2 * d_in + state : 2 * d_in + 2 * state]
    dt = zxbcdt[..., 2 * d_in + 2 * state :]
    return z, x, b, c, dt


def _causal_conv(xbc: jnp.ndarray, w: jnp.ndarray, bias: jnp.ndarray,
                 init: Optional[jnp.ndarray] = None):
    """Depthwise causal conv over time. xbc [B,T,C], w [W,C]. Returns
    (out [B,T,C], new_tail [B,W-1,C])."""
    width = w.shape[0]
    if init is None:
        init = jnp.zeros((xbc.shape[0], width - 1, xbc.shape[2]), xbc.dtype)
    padded = jnp.concatenate([init.astype(xbc.dtype), xbc], axis=1)
    out = sum(
        padded[:, i : i + xbc.shape[1], :] * w[i][None, None, :]
        for i in range(width)
    )
    out = out + bias[None, None, :]
    tail = padded[:, padded.shape[1] - (width - 1) :, :]
    return jax.nn.silu(out), tail


def _gated_norm(y, z, scale, eps=1e-5):
    yf = y.astype(jnp.float32)
    var = jnp.mean(jnp.square(yf), axis=-1, keepdims=True)
    yn = yf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
    return (yn * jax.nn.silu(z.astype(jnp.float32))).astype(y.dtype)


def ssd_chunk_scan(
    x: jnp.ndarray,  # [B, T, H, P]
    dt: jnp.ndarray,  # [B, T, H] f32 (post softplus)
    a: jnp.ndarray,  # [H] f32 negative
    b: jnp.ndarray,  # [B, T, N]
    c: jnp.ndarray,  # [B, T, N]
    chunk: int,
    h_init: Optional[jnp.ndarray] = None,  # [B, H, P, N] f32
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Chunked SSD. Returns (y [B,T,H,P], h_final [B,H,P,N])."""
    bsz, t_orig, h, pdim = x.shape
    n = b.shape[-1]
    # pad to a chunk multiple; dt=0 at padded steps => state passes through
    # unchanged and padded positions contribute nothing.
    pad = (-t_orig) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0)))
    t = t_orig + pad
    nc = t // chunk
    xf = x.astype(jnp.float32)
    bf, cf = b.astype(jnp.float32), c.astype(jnp.float32)

    da = dt * a[None, None, :]  # [B,T,H] negative
    # reshape to chunks
    xc = xf.reshape(bsz, nc, chunk, h, pdim)
    bc = bf.reshape(bsz, nc, chunk, n)
    cc = cf.reshape(bsz, nc, chunk, n)
    dac = da.reshape(bsz, nc, chunk, h)
    dtc = dt.reshape(bsz, nc, chunk, h)
    cum = jnp.cumsum(dac, axis=2)  # [B,nc,L,H], decreasing (<=0 increments)

    # ---- intra-chunk (parallel over all chunks) ----
    g = jnp.einsum("bcin,bcjn->bcij", cc, bc)  # [B,nc,L,L]
    # decay_ij = exp(cum_i - cum_j) for j<=i
    dd = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # [B,nc,L,L,H]
    ii = jnp.arange(chunk)
    causal = (ii[:, None] >= ii[None, :])[None, None, :, :, None]
    m = jnp.where(causal, jnp.exp(dd), 0.0) * g[..., None] * dtc[:, :, None, :, :]
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", m, xc)

    # ---- chunk-local final state + total decay ----
    w = jnp.exp(cum[:, :, -1:, :] - cum) * dtc  # [B,nc,L,H]
    h_loc = jnp.einsum("bcjh,bcjn,bcjhp->bchpn", w, bc, xc)  # [B,nc,H,P,N]
    decay_tot = jnp.exp(cum[:, :, -1, :])  # [B,nc,H]

    # ---- inter-chunk recurrence (cheap scan) ----
    h0 = (
        h_init.astype(jnp.float32)
        if h_init is not None
        else jnp.zeros((bsz, h, pdim, n), jnp.float32)
    )

    def body(hprev, inputs):
        hl, dtot, cck, cumk = inputs  # [B,H,P,N],[B,H],[B,L,N],[B,L,H]
        y_inter = jnp.einsum("bln,bhpn->blhp", cck, hprev) * jnp.exp(cumk)[..., None]
        hnext = hprev * dtot[:, :, None, None] + hl
        return hnext, y_inter

    xs = (
        jnp.moveaxis(h_loc, 1, 0),
        jnp.moveaxis(decay_tot, 1, 0),
        jnp.moveaxis(cc, 1, 0),
        jnp.moveaxis(cum, 1, 0),
    )
    h_final, y_inter = jax.lax.scan(body, h0, xs)
    y_inter = jnp.moveaxis(y_inter, 0, 1)  # [B,nc,L,H,P]
    y = (y_intra + y_inter).reshape(bsz, t, h, pdim)[:, :t_orig]
    return y.astype(x.dtype), h_final


def ssd_state_only(
    x: jnp.ndarray,  # [B, T, H, P]
    dt: jnp.ndarray,  # [B, T, H] f32
    a: jnp.ndarray,  # [H] f32 negative
    b: jnp.ndarray,  # [B, T, N]
    chunk: int,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Cheap segment-state fold for sequence parallelism: returns
    (h_seg [B,H,P,N] = final state from zero init, decay_seg [B,H] = total
    decay across the segment). Skips all output (y) math."""
    bsz, t_orig, h, pdim = x.shape
    n = b.shape[-1]
    pad = (-t_orig) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
    t = t_orig + pad
    nc = t // chunk
    xf, bf = x.astype(jnp.float32), b.astype(jnp.float32)
    da = dt * a[None, None, :]
    xc = xf.reshape(bsz, nc, chunk, h, pdim)
    bc = bf.reshape(bsz, nc, chunk, n)
    dac = da.reshape(bsz, nc, chunk, h)
    dtc = dt.reshape(bsz, nc, chunk, h)
    cum = jnp.cumsum(dac, axis=2)
    w = jnp.exp(cum[:, :, -1:, :] - cum) * dtc
    h_loc = jnp.einsum("bcjh,bcjn,bcjhp->bchpn", w, bc, xc)
    decay_tot = jnp.exp(cum[:, :, -1, :])  # [B,nc,H]

    def fold(hprev, inputs):
        hl, dtot = inputs
        return hprev * dtot[:, :, None, None] + hl, None

    h_seg, _ = jax.lax.scan(
        fold,
        jnp.zeros((bsz, h, pdim, n), jnp.float32),
        (jnp.moveaxis(h_loc, 1, 0), jnp.moveaxis(decay_tot, 1, 0)),
    )
    decay_seg = jnp.exp(jnp.sum(da, axis=1))  # [B,H]
    return h_seg, decay_seg


def mamba2_forward(
    p: dict,
    xin: jnp.ndarray,  # [B, T, d]
    cfg,
    state: Optional[SSMState] = None,
) -> Tuple[jnp.ndarray, SSMState]:
    """Full-sequence (prefill/train) mamba2 layer."""
    d_in = cfg.ssm_expand * cfg.d_model
    n_heads = d_in // cfg.ssm_head_dim
    zxbcdt = jnp.einsum("btd,de->bte", xin, p["w_in"])
    z, x, b, c, dt = _split_proj(p, zxbcdt, d_in, cfg.ssm_state, n_heads)
    xbc = jnp.concatenate([x, b, c], axis=-1)
    conv_init = state.conv if state is not None else None
    xbc, conv_tail = _causal_conv(xbc, p["conv_w"], p["conv_b"], conv_init)
    x = xbc[..., :d_in]
    b = xbc[..., d_in : d_in + cfg.ssm_state]
    c = xbc[..., d_in + cfg.ssm_state :]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"][None, None, :])
    a = -jnp.exp(p["A_log"])
    xh = x.reshape(*x.shape[:2], n_heads, cfg.ssm_head_dim)
    h_init = state.h if state is not None else None
    y, h_final = ssd_chunk_scan(xh, dt, a, b, c, cfg.ssm_chunk, h_init)
    y = y + xh.astype(jnp.float32) * p["D"][None, None, :, None]
    y = y.reshape(*x.shape[:2], d_in).astype(xin.dtype)
    y = _gated_norm(y, z, p["norm_scale"])
    out = jnp.einsum("bte,ed->btd", y, p["w_out"])
    return out, SSMState(h=h_final, conv=conv_tail)


def mamba2_decode_step(
    p: dict,
    xin: jnp.ndarray,  # [B, 1, d]
    cfg,
    state: SSMState,
) -> Tuple[jnp.ndarray, SSMState]:
    """One-token recurrent update: h = exp(dA) h + dt B (x) ; y = C.h + Dx."""
    d_in = cfg.ssm_expand * cfg.d_model
    n_heads = d_in // cfg.ssm_head_dim
    zxbcdt = jnp.einsum("btd,de->bte", xin, p["w_in"])
    z, x, b, c, dt = _split_proj(p, zxbcdt, d_in, cfg.ssm_state, n_heads)
    xbc = jnp.concatenate([x, b, c], axis=-1)
    xbc, conv_tail = _causal_conv(xbc, p["conv_w"], p["conv_b"], state.conv)
    x = xbc[..., :d_in]
    b = xbc[..., d_in : d_in + cfg.ssm_state]
    c = xbc[..., d_in + cfg.ssm_state :]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"][None, None, :])
    a = -jnp.exp(p["A_log"])
    xh = x.reshape(x.shape[0], n_heads, cfg.ssm_head_dim).astype(jnp.float32)
    dt1 = dt[:, 0]  # [B,H]
    decay = jnp.exp(dt1 * a[None, :])  # [B,H]
    b1 = b[:, 0].astype(jnp.float32)  # [B,N]
    c1 = c[:, 0].astype(jnp.float32)
    upd = jnp.einsum("bh,bhp,bn->bhpn", dt1, xh, b1)
    h = state.h * decay[:, :, None, None] + upd
    y = jnp.einsum("bn,bhpn->bhp", c1, h) + xh * p["D"][None, :, None]
    y = y.reshape(x.shape[0], 1, d_in).astype(xin.dtype)
    y = _gated_norm(y, z, p["norm_scale"])
    out = jnp.einsum("bte,ed->btd", y, p["w_out"])
    return out, SSMState(h=h, conv=conv_tail)


def init_ssm_state(cfg, batch: int) -> SSMState:
    d_in = cfg.ssm_expand * cfg.d_model
    n_heads = d_in // cfg.ssm_head_dim
    conv_dim = d_in + 2 * cfg.ssm_state
    return SSMState(
        h=jnp.zeros((batch, n_heads, cfg.ssm_head_dim, cfg.ssm_state), jnp.float32),
        conv=jnp.zeros((batch, cfg.ssm_conv_width - 1, conv_dim), jnp.float32),
    )

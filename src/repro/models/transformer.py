"""Composable model builder: one `Model` class covering dense / moe / vlm /
hybrid(zamba2) / ssm(xlstm) families (whisper enc-dec lives in encdec.py and
reuses the same block helpers).

Key properties:
  * `jax.lax.scan` over stacked layer params -> HLO size independent of depth.
  * Attention is pluggable (`attn_impl`): the default is dense local math; the
    ESP implementations (striped ring prefill, multi-master decode) from
    repro.core plug in here — the paper's technique is a first-class feature,
    not a fork of the model.
  * `positions` is an explicit input everywhere so the ESP *striped
    permutation* of the sequence is transparent to the model (RoPE and causal
    masks are position-based, DESIGN.md §2).
  * `constrain(tensor, tag)` hook threads pjit sharding hints without the
    model knowing about meshes.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import layers, moe, ssm, xlstm


def _id_constrain(x, _tag):
    return x


class DefaultAttnImpl:
    """Plain (single-group) attention implementation."""

    def prefill_attn(self, q, k, v, q_pos, k_pos, *, causal, window, softcap):
        return attn.full_attention(
            q, k, v, q_pos=q_pos, k_pos=k_pos, causal=causal, window=window,
            softcap=softcap,
        )

    def decode_attn(self, q, k_cache, v_cache, k_new, v_new, cache_len, *,
                    window, softcap):
        """q [B,1,H,D]; cache [B,S,KVH,D]; new token's kv [B,1,KVH,D] kept
        out of the cache (it lives at the master instance under ESP)."""
        b, s = k_cache.shape[0], k_cache.shape[1]
        pos = jnp.arange(s)
        cl = jnp.broadcast_to(jnp.asarray(cache_len), (b,))
        k_valid = pos[None, :] < cl[:, None]
        q_pos = cl[:, None]
        mask = attn.mask_from_positions(
            q_pos, jnp.broadcast_to(pos, (b, s)), causal=True, window=window,
            k_valid=k_valid,
        )
        p_hist = attn.partial_attention(q, k_cache, v_cache, mask, softcap=softcap)
        p_new = attn.partial_attention(q, k_new, v_new, None, softcap=softcap)
        out = attn.finalize_partial(attn.merge_partial(p_hist, p_new))
        return out.astype(q.dtype)

    def ssm_scan(self, kind, p, x, cfg, state):
        """Recurrent-layer hook so ESP can add cross-device state handoff.

        kind: "mamba" | "mlstm" | "slstm"; returns (y, new_state)."""
        if kind == "mamba":
            return ssm.mamba2_forward(p, x, cfg, state)
        if kind == "mlstm":
            return xlstm.mlstm_block_forward(p, x, cfg, state)
        if kind == "slstm":
            return xlstm.slstm_block_forward(p, x, cfg, state)
        raise ValueError(kind)  # pragma: no cover


class Cache(NamedTuple):
    """KV / recurrent state for decode. Fields unused by a family are None."""

    k: Optional[jnp.ndarray] = None  # [L,B,S,KVH,Dh]
    v: Optional[jnp.ndarray] = None
    length: Optional[jnp.ndarray] = None  # [] or [B] valid token count
    ssm: Optional[Any] = None  # stacked SSMState / (MLSTM, SLSTM) states
    cross_k: Optional[jnp.ndarray] = None  # whisper cross-attn
    cross_v: Optional[jnp.ndarray] = None


# ===================================================================== Model


class Model:
    def __init__(
        self,
        cfg: ModelConfig,
        attn_impl=None,
        constrain: Optional[Callable] = None,
        remat: bool = False,
    ):
        self.cfg = cfg
        self.attn_impl = attn_impl or DefaultAttnImpl()
        self.constrain = constrain or _id_constrain
        self.remat = remat
        self.dtype = jnp.dtype(cfg.dtype)

    # ----------------------------------------------------------- parameters
    def init(self, key) -> Dict[str, Any]:
        cfg = self.cfg
        dt = self.dtype
        keys = layers.split_keys(key, 8)
        params: Dict[str, Any] = {
            "embed": layers.init_embed(keys[0], cfg.vocab_size, cfg.d_model, dt),
            "final_norm": layers.init_norm(keys[1], cfg.d_model, cfg.norm_kind, dt),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = layers.normal_init(
                keys[2], (cfg.d_model, cfg.vocab_size), dt
            )
        if cfg.family in ("dense", "vlm", "moe"):
            n = cfg.n_layers
            params["layers"] = self._init_stacked(keys[3], n, self._init_dense_layer)
        elif cfg.family == "hybrid":
            n_super = cfg.n_layers // cfg.hybrid_mamba_per_block
            params["layers"] = self._init_stacked(
                keys[3], n_super, self._init_hybrid_superblock
            )
            params["shared_attn"] = self._init_attn(keys[4])
            params["shared_ffn"] = layers.init_ffn(
                keys[5], cfg.d_model, cfg.d_ff, cfg.ffn_kind, dt
            )
            params["shared_norms"] = {
                "n1": layers.init_norm(keys[6], cfg.d_model, cfg.norm_kind, dt),
                "n2": layers.init_norm(keys[7], cfg.d_model, cfg.norm_kind, dt),
            }
        elif cfg.family == "ssm":  # xlstm
            every = cfg.xlstm_slstm_every or (cfg.n_layers + 1)
            n_super = max(cfg.n_layers // every, 1)
            m_per = (cfg.n_layers // n_super) - 1  # mLSTM blocks per superblock
            self._xl_m_per = m_per
            params["layers"] = self._init_stacked(
                keys[3], n_super, functools.partial(self._init_xlstm_super, m_per)
            )
        else:  # pragma: no cover
            raise ValueError(cfg.family)
        return params

    def _init_stacked(self, key, n, init_one):
        ks = jax.random.split(key, n)
        return jax.vmap(init_one)(ks)

    def _init_attn(self, key) -> dict:
        cfg, dt = self.cfg, self.dtype
        hd = cfg.head_dim
        ks = layers.split_keys(key, 4)
        p = {
            "wq": layers.normal_init(ks[0], (cfg.d_model, cfg.n_heads, hd), dt),
            "wk": layers.normal_init(ks[1], (cfg.d_model, cfg.n_kv_heads, hd), dt),
            "wv": layers.normal_init(ks[2], (cfg.d_model, cfg.n_kv_heads, hd), dt),
            "wo": layers.normal_init(ks[3], (cfg.n_heads, hd, cfg.d_model), dt),
        }
        if cfg.qkv_bias:
            p["bq"] = jnp.zeros((cfg.n_heads, hd), dt)
            p["bk"] = jnp.zeros((cfg.n_kv_heads, hd), dt)
            p["bv"] = jnp.zeros((cfg.n_kv_heads, hd), dt)
        return p

    def _init_dense_layer(self, key) -> dict:
        cfg, dt = self.cfg, self.dtype
        ks = layers.split_keys(key, 5)
        p = {
            "attn": self._init_attn(ks[0]),
            "norm1": layers.init_norm(ks[1], cfg.d_model, cfg.norm_kind, dt),
            "norm2": layers.init_norm(ks[2], cfg.d_model, cfg.norm_kind, dt),
        }
        if cfg.family == "moe":
            p["moe"] = moe.init_moe(
                ks[3], cfg.d_model, cfg.d_ff, cfg.n_experts, cfg.ffn_kind, dt
            )
            if cfg.dense_ff:
                p["dense_ffn"] = layers.init_ffn(
                    ks[4], cfg.d_model, cfg.dense_ff, cfg.ffn_kind, dt
                )
        else:
            p["ffn"] = layers.init_ffn(ks[3], cfg.d_model, cfg.d_ff, cfg.ffn_kind, dt)
        return p

    def _init_hybrid_superblock(self, key) -> dict:
        cfg, dt = self.cfg, self.dtype
        ks = jax.random.split(key, cfg.hybrid_mamba_per_block)

        def one(k):
            k1, k2 = jax.random.split(k)
            return {
                "mamba": ssm.init_mamba2(
                    k1, cfg.d_model, expand=cfg.ssm_expand,
                    head_dim=cfg.ssm_head_dim, state=cfg.ssm_state,
                    conv_width=cfg.ssm_conv_width, dtype=dt,
                ),
                "norm": layers.init_norm(k2, cfg.d_model, cfg.norm_kind, dt),
            }

        return {"mamba_layers": jax.vmap(one)(ks)}

    def _init_xlstm_super(self, m_per, key) -> dict:
        cfg, dt = self.cfg, self.dtype
        k1, k2, k3, k4 = jax.random.split(key, 4)
        mk = jax.random.split(k1, m_per)

        def one_m(k):
            ka, kb = jax.random.split(k)
            return {
                "cell": xlstm.init_mlstm(ka, cfg, dt),
                "norm": layers.init_norm(kb, cfg.d_model, cfg.norm_kind, dt),
            }

        return {
            "mlstm_layers": jax.vmap(one_m)(mk),
            "slstm": {
                "cell": xlstm.init_slstm(k2, cfg, dt),
                "norm": layers.init_norm(k3, cfg.d_model, cfg.norm_kind, dt),
            },
        }

    # ------------------------------------------------------------ embedding
    def embed_inputs(self, params, batch: Dict[str, jnp.ndarray]) -> jnp.ndarray:
        """batch: {"tokens": [B,T]} (+ "patch_embeds": [B,Ti,d] for vlm)."""
        cfg = self.cfg
        x = layers.embed_lookup(params["embed"], batch["tokens"]).astype(self.dtype)
        if cfg.frontend == "patch_stub" and "patch_embeds" in batch:
            pe = batch["patch_embeds"].astype(self.dtype)
            x = jnp.concatenate([pe, x], axis=1)  # image tokens first
        return self.constrain(x, "act")

    def unembed(self, params, x: jnp.ndarray) -> jnp.ndarray:
        cfg = self.cfg
        x = layers.apply_norm(params["final_norm"], x, cfg.norm_kind, cfg.norm_eps)
        w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
        return self.constrain(layers.lm_head_logits(x, w), "logits")

    # -------------------------------------------------------------- qkv math
    def _qkv(self, p, x, positions):
        cfg = self.cfg
        q = jnp.einsum("btd,dhk->bthk", x, p["wq"])
        k = jnp.einsum("btd,dhk->bthk", x, p["wk"])
        v = jnp.einsum("btd,dhk->bthk", x, p["wv"])
        if cfg.qkv_bias:
            q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
        if cfg.rope_theta:
            d_rot = int(cfg.head_dim * cfg.rope_fraction) // 2 * 2
            cos, sin = layers.rope_cos_sin(positions, d_rot, cfg.rope_theta)
            q = layers.apply_rope(q, cos, sin, d_rot)
            k = layers.apply_rope(k, cos, sin, d_rot)
        return self.constrain(q, "q"), self.constrain(k, "kv"), self.constrain(v, "kv")

    def _attn_block_prefill(self, p, x, positions, return_kv: bool):
        cfg = self.cfg
        q, k, v = self._qkv(p, x, positions)
        out = self.attn_impl.prefill_attn(
            q, k, v, positions, positions, causal=True,
            window=cfg.sliding_window, softcap=cfg.attn_logit_softcap,
        )
        out = self.constrain(out, "attn_out")
        y = jnp.einsum("bthk,hkd->btd", out, p["wo"])
        return (y, (k, v)) if return_kv else (y, None)

    def _attn_block_decode(self, p, x, k_cache, v_cache, cache_len):
        cfg = self.cfg
        b = x.shape[0]
        cl = jnp.broadcast_to(jnp.asarray(cache_len), (b,))
        q, k_new, v_new = self._qkv(p, x, cl[:, None])
        out = self.attn_impl.decode_attn(
            q, k_cache, v_cache, k_new, v_new, cl,
            window=cfg.sliding_window, softcap=cfg.attn_logit_softcap,
        )
        y = jnp.einsum("bthk,hkd->btd", out, p["wo"])
        return y, (k_new, v_new)

    def _ffn_or_moe(self, p, x):
        cfg = self.cfg
        if cfg.family == "moe":
            b, s = x.shape[0], x.shape[1]
            # S-major flatten: the (sharded) sequence dim stays the leading
            # factor of the merged token dim, so SPMD propagates the sharding
            # through the reshape instead of all-gathering tokens
            flat = jnp.swapaxes(x, 0, 1).reshape(b * s, cfg.d_model)
            mo = moe.apply_moe(
                p["moe"], flat, top_k=cfg.moe_top_k,
                capacity_factor=cfg.moe_capacity_factor, ffn_kind=cfg.ffn_kind,
                constrain=self.constrain,
            )
            y = jnp.swapaxes(mo.out.reshape(s, b, cfg.d_model), 0, 1)
            if cfg.dense_ff:
                y = y + layers.apply_ffn(p["dense_ffn"], x, cfg.ffn_kind)
            return y, mo.aux_loss
        h = layers.apply_ffn(p["ffn"], x, cfg.ffn_kind)
        return h, jnp.float32(0.0)

    # ====================================================== dense-like stack
    def _dense_stack(self, params, x, positions, *, return_kv, k_caches=None,
                     v_caches=None, cache_len=None, decode=False,
                     unroll=False):
        cfg = self.cfg
        naux = jnp.float32(0.0)

        def body(carry, lp, kc=None, vc=None):
            x, aux = carry
            h = layers.apply_norm(lp["norm1"], x, cfg.norm_kind, cfg.norm_eps)
            if decode:
                y, kv = self._attn_block_decode(lp["attn"], h, kc, vc, cache_len)
            else:
                y, kv = self._attn_block_prefill(lp["attn"], h, positions, return_kv)
            x = self.constrain(x + y, "act")
            h = layers.apply_norm(lp["norm2"], x, cfg.norm_kind, cfg.norm_eps)
            y, aux_l = self._ffn_or_moe(lp, h)
            x = self.constrain(x + y, "act")
            return (x, aux + aux_l), kv

        if decode:
            # static python loop: per-layer cache slices keep per-layer
            # buffers per-layer-sized (a while-loop lets XLA hoist whole-cache
            # copies/conversions out of the loop — HBM blowup), and the tiny
            # decode body keeps the unrolled HLO small.
            # k_caches may be None: a paged attn_impl (core.paged_decode)
            # reads KV from the pool storage itself, layer by layer.
            if k_caches is None:
                n_layers = jax.tree_util.tree_leaves(params["layers"])[0].shape[0]
            else:
                n_layers = k_caches.shape[0]
            carry = (x, naux)
            kv_list = []
            for li in range(n_layers):
                lp = jax.tree.map(lambda a: a[li], params["layers"])
                kc = k_caches[li] if k_caches is not None else None
                vc = v_caches[li] if v_caches is not None else None
                carry, kv = body(carry, lp, kc, vc)
                kv_list.append(kv)
            x, aux = carry
            kvs = jax.tree.map(lambda *xs: jnp.stack(xs), *kv_list)
            return x, aux, kvs

        if unroll:
            # static python loop on the PREFILL branch: an attention impl
            # that dispatches per-layer paged operands (core.unified) needs a
            # python-level layer cursor, which lax.scan cannot provide.
            n_layers = jax.tree_util.tree_leaves(params["layers"])[0].shape[0]
            carry = (x, naux)
            kv_list = []
            for li in range(n_layers):
                lp = jax.tree.map(lambda a: a[li], params["layers"])
                carry, kv = body(carry, lp)
                kv_list.append(kv)
            x, aux = carry
            kvs = jax.tree.map(lambda *xs: jnp.stack(xs), *kv_list)
            return x, aux, kvs

        fn = jax.checkpoint(body) if self.remat else body
        (x, aux), kvs = jax.lax.scan(fn, (x, naux), params["layers"])
        return x, aux, kvs

    # ========================================================= hybrid stack
    def _hybrid_stack(self, params, x, positions, *, return_kv, ssm_states=None,
                      k_caches=None, v_caches=None, cache_len=None, decode=False):
        cfg = self.cfg
        shared_attn = params["shared_attn"]
        shared_ffn = params["shared_ffn"]
        sn = params["shared_norms"]

        def mamba_one(carry, xs):
            x = carry
            if decode:
                mp, st = xs
                h = layers.apply_norm(mp["norm"], x, cfg.norm_kind, cfg.norm_eps)
                y, st_new = ssm.mamba2_decode_step(mp["mamba"], h, cfg, st)
            else:
                mp, st = xs, None
                h = layers.apply_norm(mp["norm"], x, cfg.norm_kind, cfg.norm_eps)
                y, st_new = self.attn_impl.ssm_scan("mamba", mp["mamba"], h, cfg, st)
            return x + y, st_new

        def super_body(x, sp, sst=None, kc=None, vc=None):
            if decode:
                x, new_sst = jax.lax.scan(
                    mamba_one, x, (sp["mamba_layers"], sst)
                )
            else:
                x, new_sst = jax.lax.scan(mamba_one, x, sp["mamba_layers"])
            # shared attention + ffn application
            h = layers.apply_norm(sn["n1"], x, cfg.norm_kind, cfg.norm_eps)
            if decode:
                y, kv = self._attn_block_decode(shared_attn, h, kc, vc, cache_len)
            else:
                y, kv = self._attn_block_prefill(shared_attn, h, positions, return_kv)
            x = self.constrain(x + y, "act")
            h = layers.apply_norm(sn["n2"], x, cfg.norm_kind, cfg.norm_eps)
            x = self.constrain(x + layers.apply_ffn(shared_ffn, h, cfg.ffn_kind), "act")
            return x, kv, new_sst

        if decode:
            n_super = k_caches.shape[0]
            kv_list, st_list = [], []
            for si in range(n_super):
                sp = jax.tree.map(lambda a: a[si], params["layers"])
                sst = jax.tree.map(lambda a: a[si], ssm_states)
                x, kv, new_sst = super_body(x, sp, sst, k_caches[si], v_caches[si])
                kv_list.append(kv)
                st_list.append(new_sst)
            kvs = jax.tree.map(lambda *xs: jnp.stack(xs), *kv_list)
            new_states = jax.tree.map(lambda *xs: jnp.stack(xs), *st_list)
            return x, jnp.float32(0.0), kvs, new_states

        def scan_body(x, sp):
            x, kv, new_sst = super_body(x, sp)
            return x, (kv, new_sst)

        fn = jax.checkpoint(scan_body) if self.remat else scan_body
        x, (kvs, new_states) = jax.lax.scan(fn, x, params["layers"])
        return x, jnp.float32(0.0), kvs, new_states

    # ========================================================== xlstm stack
    def _xlstm_stack(self, params, x, *, states=None, decode=False):
        cfg = self.cfg

        def m_one(carry, xs):
            x = carry
            mp, st = xs if decode else (xs, None)
            h = layers.apply_norm(mp["norm"], x, cfg.norm_kind, cfg.norm_eps)
            if decode:
                y, st_new = xlstm.mlstm_block_step(mp["cell"], h, cfg, st)
            else:
                y, st_new = self.attn_impl.ssm_scan("mlstm", mp["cell"], h, cfg, st)
            return x + y, st_new

        def super_body(carry, xs):
            x = carry
            if decode:
                sp, (mst, sst) = xs
                x, new_mst = jax.lax.scan(m_one, x, (sp["mlstm_layers"], mst))
            else:
                sp = xs
                sst = None
                x, new_mst = jax.lax.scan(m_one, x, sp["mlstm_layers"])
            h = layers.apply_norm(
                sp["slstm"]["norm"], x, cfg.norm_kind, cfg.norm_eps
            )
            if decode:
                y, new_sst = xlstm.slstm_block_step(sp["slstm"]["cell"], h, cfg, sst)
            else:
                y, new_sst = self.attn_impl.ssm_scan(
                    "slstm", sp["slstm"]["cell"], h, cfg, None
                )
            x = self.constrain(x + y, "act")
            return x, (new_mst, new_sst)

        xs = (params["layers"], states) if decode else params["layers"]
        fn = jax.checkpoint(super_body) if (self.remat and not decode) else super_body
        x, new_states = jax.lax.scan(fn, x, xs)
        return x, new_states

    # ============================================================== public
    def hidden(self, params, batch, positions=None) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Pre-unembed hidden states (training losses chunk the unembed to
        avoid materializing [B,S,V]). Returns (x [B,T,d], aux_loss)."""
        x = self.embed_inputs(params, batch)
        t = x.shape[1]
        if positions is None:
            positions = jnp.arange(t)
        cfg = self.cfg
        if cfg.family in ("dense", "vlm", "moe"):
            x, aux, _ = self._dense_stack(params, x, positions, return_kv=False)
        elif cfg.family == "hybrid":
            x, aux, _, _ = self._hybrid_stack(params, x, positions, return_kv=False)
        elif cfg.family == "ssm":
            x, _ = self._xlstm_stack(params, x)
            aux = jnp.float32(0.0)
        else:  # pragma: no cover
            raise ValueError(cfg.family)
        return x, aux

    def forward(self, params, batch, positions=None) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Full forward (training). Returns (logits [B,T,V], aux_loss)."""
        x, aux = self.hidden(params, batch, positions)
        return self.unembed(params, x), aux

    def prefill(self, params, batch, positions=None, *,
                last_logit_only: bool = False) -> Tuple[jnp.ndarray, Cache]:
        """Prefill: logits (+ populated cache). With last_logit_only=True the
        hidden state is sliced to the final *global* position (argmax of the
        positions array — correct under striped layouts) before the unembed,
        so the [B,S,V] logits tensor is never materialized (serving path)."""
        x = self.embed_inputs(params, batch)
        b, t = x.shape[0], x.shape[1]
        if positions is None:
            positions = jnp.arange(t)
        cfg = self.cfg
        if cfg.family in ("dense", "vlm", "moe"):
            x, _, kvs = self._dense_stack(params, x, positions, return_kv=True)
            k, v = kvs
            cache = Cache(k=k, v=v, length=jnp.full((b,), t, jnp.int32))
        elif cfg.family == "hybrid":
            x, _, kvs, states = self._hybrid_stack(
                params, x, positions, return_kv=True
            )
            k, v = kvs
            cache = Cache(
                k=k, v=v, length=jnp.full((b,), t, jnp.int32), ssm=states
            )
        elif cfg.family == "ssm":
            x, states = self._xlstm_stack(params, x)
            cache = Cache(length=jnp.full((b,), t, jnp.int32), ssm=states)
        else:  # pragma: no cover
            raise ValueError(cfg.family)
        if last_logit_only:
            # masked reduction instead of dynamic-slice: stays sharded over
            # the sequence axis (a slice at a traced index would all-gather x)
            pos = jnp.broadcast_to(jnp.asarray(positions), (t,))
            sel = (pos == jnp.max(pos)).astype(x.dtype)
            x = jnp.einsum("bsd,s->bd", x, sel)[:, None, :]
        return self.unembed(params, x), cache

    def prefill_packed_hidden(
        self, params, batch, positions, *, unroll=False
    ) -> Tuple[jnp.ndarray, Tuple[jnp.ndarray, jnp.ndarray]]:
        """The stack half of `prefill_packed`: embed + dense stack over the
        packed token axis, returning the final hidden states instead of
        logits — (x [1, T, d], (k, v) packed per-layer KV [L, T, KVH, D]).
        The SPMD unified step calls this per rank (each rank holds a token
        stripe) and does its own gather + unembed; ``unroll=True`` runs the
        layer loop as a static python loop so a per-layer attention impl
        (core.unified) can keep a layer cursor."""
        cfg = self.cfg
        assert cfg.family in ("dense", "vlm"), cfg.family
        x = self.embed_inputs(params, batch)  # [1, T, d]
        x, _, kvs = self._dense_stack(
            params, x, positions, return_kv=True, unroll=unroll
        )
        k, v = kvs  # [L, 1, T, KVH, D]
        return x, (k[:, 0], v[:, 0])

    def prefill_packed(
        self, params, batch, positions, last_idx, *, unroll=False
    ) -> Tuple[jnp.ndarray, Tuple[jnp.ndarray, jnp.ndarray]]:
        """Packed ragged prefill: a whole batch of prompts concatenated on
        ONE token axis (batch dim 1).  `positions` are per-token LOCAL
        positions (so RoPE/window stay per-request correct) and the armed
        attention impl (core.paged_prefill.PackedPrefillAttnImpl) applies
        the segment mask that keeps requests from attending each other.
        `last_idx` [B] selects each request's final packed token; only those
        rows are unembedded, so the [T, V] logits tensor is never
        materialized.  Returns (logits [B, V], (k, v) packed per-layer KV
        [L, T, KVH, D]) — the KV that `kvcache.pool.fill_packed` scatters
        straight into paged device storage."""
        x, kv = self.prefill_packed_hidden(
            params, batch, positions, unroll=unroll
        )
        sel = jnp.take(x[0], jnp.asarray(last_idx, jnp.int32), axis=0)
        logits = self.unembed(params, sel[None])[0]  # [B, V]
        return logits, kv

    def decode(self, params, tokens, cache: Cache) -> Tuple[jnp.ndarray, Cache]:
        """One decode step. tokens [B] or [B,1]. Returns (logits [B,V],
        updated cache metadata + per-layer new KV stacked like the cache);
        cache.k/v are NOT updated in place here (the engine / KV pool owns
        placement — LoongServe semantics), instead the new kv is returned via
        the `ssm`-style aux field of the returned Cache (see `new_kv`)."""
        cfg = self.cfg
        if tokens.ndim == 1:
            tokens = tokens[:, None]
        x = layers.embed_lookup(params["embed"], tokens).astype(self.dtype)
        x = self.constrain(x, "act")
        cl = cache.length
        if cfg.family in ("dense", "vlm", "moe"):
            x, _, kvs = self._dense_stack(
                params, x, None, return_kv=False, k_caches=cache.k,
                v_caches=cache.v, cache_len=cl, decode=True,
            )
            new_cache = Cache(k=cache.k, v=cache.v, length=cl + 1)
        elif cfg.family == "hybrid":
            x, _, kvs, new_states = self._hybrid_stack(
                params, x, None, return_kv=False, ssm_states=cache.ssm,
                k_caches=cache.k, v_caches=cache.v, cache_len=cl, decode=True,
            )
            new_cache = Cache(k=cache.k, v=cache.v, length=cl + 1, ssm=new_states)
        elif cfg.family == "ssm":
            x, new_states = self._xlstm_stack(params, x, states=cache.ssm, decode=True)
            kvs = None
            new_cache = Cache(length=cl + 1, ssm=new_states)
        else:  # pragma: no cover
            raise ValueError(cfg.family)
        logits = self.unembed(params, x)[:, 0]
        return logits, new_cache, kvs

    def decode_sampled(self, params, tokens, cache: Cache):
        """Slice-aware decode entry: one decode step + greedy sampling for
        whatever batch slice the caller holds.  Nothing in `decode` couples
        rows, so inside the batch-sharded SPMD iteration each rank runs this
        on its own B/n slice — embed/FFN/unembed/argmax cost scales down with
        the slice while the armed attn impl pays the collective boundary.
        `jnp.argmax` matches the engine's host `_sample_token`
        (`np.argmax`) bit-exactly, first-max tie-break included, so the
        in-program token exchange is token-parity-exact with the host path.
        Returns (sampled ids [b] int32, updated cache, per-layer new KV)."""
        logits, new_cache, kvs = self.decode(params, tokens, cache)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), new_cache, kvs


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Cache:
    """Preallocated (padded) cache for the dense decode path."""
    dt = jnp.dtype(cfg.dtype)
    n_attn = cfg.n_attention_applications
    k = v = None
    if n_attn:
        k = jnp.zeros((n_attn, batch, max_len, cfg.n_kv_heads, cfg.head_dim), dt)
        v = jnp.zeros_like(k)
    def _stack(template, *dims):
        return jax.tree.map(
            lambda a: jnp.broadcast_to(a, dims + a.shape), template
        )

    ssm_states = None
    if cfg.family == "hybrid":
        n_super = cfg.n_layers // cfg.hybrid_mamba_per_block
        ssm_states = _stack(
            ssm.init_ssm_state(cfg, batch), n_super, cfg.hybrid_mamba_per_block
        )
    elif cfg.family == "ssm":
        every = cfg.xlstm_slstm_every or (cfg.n_layers + 1)
        n_super = max(cfg.n_layers // every, 1)
        m_per = (cfg.n_layers // n_super) - 1
        mst = _stack(xlstm.init_mlstm_state(cfg, batch), n_super, m_per)
        sst = _stack(xlstm.init_slstm_state(cfg, batch), n_super)
        ssm_states = (mst, sst)
    return Cache(
        k=k, v=v, length=jnp.zeros((batch,), jnp.int32), ssm=ssm_states
    )

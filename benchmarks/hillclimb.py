"""Dry-run hill-climb sweep over sharding/kernel variants (roofline census).

Folded into benchmarks/ from the root-level run_hillclimb*.py exploration
scripts (this is the latest sweep; the earlier two were supersets it
re-measures).  Usage:

  PYTHONPATH=src python -m benchmarks.hillclimb   # writes dryrun_hillclimb3.json
"""
import json

from repro.launch import sharding as shlib
from repro.launch.dryrun import run_cell

results = []
# Cell A: glm4 prefill (baseline chunkless; paper-faithful + variants)
results.append(run_cell("glm4-9b", "prefill_32k", options={"kernel_adjusted": True}))
results.append(run_cell("glm4-9b", "prefill_32k", options={"ring_slice_tp": True}))
# Cell B: xlstm prefill chunk sweep (baseline = 256 via config default)
for chunk in (64, 128, 512, 1024):
    results.append(run_cell("xlstm-350m", "prefill_32k", options={"ssm_chunk": chunk}))
results.append(run_cell("xlstm-350m", "prefill_32k",
                        options={"exclude_scope": "mlstm_chunk_body"}))
# Cell C: arctic refuted variant re-measured under the new census
shlib.MOE_GROUP_C_OVER_DATA = True
results.append(run_cell("arctic-480b", "prefill_32k", options={"moe_c_over_data": True}))
shlib.MOE_GROUP_C_OVER_DATA = False
json.dump(results, open("dryrun_hillclimb3.json", "w"), indent=1)
print("HILLCLIMB3 DONE")

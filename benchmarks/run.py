"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows; the `derived` column carries
the figure's headline quantity (speedups, error percentages, overheads).

  PYTHONPATH=src python -m benchmarks.run [--quick] [--only NAME]

``--collate`` instead merges every committed ``BENCH_*.json`` artifact into
one ``BENCH_trajectory.json`` — per-path tok/s, per-iteration collective
bytes and speedup/ratio headlines, keyed by bench and git commit — so the
perf history over PRs reads from one file instead of scattered per-PR
artifacts (run by the CI smoke step)."""
from __future__ import annotations

import argparse
import sys
import time


def _row(name: str, us: float, derived: str) -> None:
    print(f"{name},{us:.1f},{derived}")
    sys.stdout.flush()


# ---------------------------------------------------------------- Fig. 2


def bench_scalability(quick: bool = False):
    """Fig. 2: prefill scales with DoP; decode scales sub-linearly."""
    from repro.configs import get_config
    from repro.manager.sib import SIB

    sib = SIB(get_config("lwm-7b"))
    t0 = time.perf_counter()
    rows = []
    for length in [1_000, 100_000]:
        t1 = sib.prefill_time(1, [length])
        t8 = sib.prefill_time(8, [length])
        rows.append(f"prefill{length//1000}k:{t1/t8:.2f}x@dop8")
    d1 = sib.decode_time(1, 32, 64_000)
    d8 = sib.decode_time(8, 32, 64_000)
    rows.append(f"decode:{d1/d8:.2f}x@dop8")
    ratio = sib.prefill_time(1, [100_000]) / sib.prefill_time(1, [1_000])
    rows.append(f"100k/1k:{ratio:.0f}x")
    us = (time.perf_counter() - t0) * 1e6
    _row("fig2_scalability", us, ";".join(rows))


# ---------------------------------------------------------------- Fig. 10


def bench_end_to_end(quick: bool = False):
    """Fig. 10: latency under load, 4 workloads × 4 systems (SIB clock)."""
    import copy

    from repro.configs import get_config
    from repro.data import poisson_workload
    from repro.launch.serve import build_engine

    cfg = get_config("lwm-7b")
    n = 40 if quick else 80
    for ds, rate in [("sharegpt", 4.0), ("leval", 0.5), ("lveval", 0.15),
                     ("mixed", 0.5)]:
        reqs = poisson_workload(ds, n, rate, seed=7)
        res = {}
        t0 = time.perf_counter()
        for name in ["loongserve", "vllm-tp", "chunked", "pd-disagg"]:
            eng = build_engine(name, cfg, 8, 250_000)
            for r in copy.deepcopy(reqs):
                eng.submit(r)
            res[name] = eng.run().summary().get("norm_e2e_mean", float("nan"))
        us = (time.perf_counter() - t0) * 1e6
        ls = res["loongserve"]
        derived = ";".join(
            f"vs_{k}:{v/ls:.2f}x" for k, v in res.items() if k != "loongserve"
        )
        _row(f"fig10_e2e_{ds}", us, derived)


# ---------------------------------------------------------------- Fig. 11


def bench_multinode(quick: bool = False):
    """Fig. 11: 16-instance (2-node) scaling on the Mixed workload."""
    import copy

    from repro.configs import get_config
    from repro.data import poisson_workload
    from repro.launch.serve import build_engine

    cfg = get_config("lwm-7b")
    n = 40 if quick else 80
    reqs = poisson_workload("mixed", n, 0.8, seed=17)
    t0 = time.perf_counter()
    res = {}
    for name in ["loongserve", "vllm-tp", "chunked"]:
        eng = build_engine(name, cfg, 16, 250_000)
        for r in copy.deepcopy(reqs):
            eng.submit(r)
        res[name] = eng.run().summary().get("norm_e2e_mean", float("nan"))
    us = (time.perf_counter() - t0) * 1e6
    ls = res["loongserve"]
    _row(
        "fig11_multinode", us,
        ";".join(f"vs_{k}:{v/ls:.2f}x" for k, v in res.items() if k != "loongserve"),
    )


# ---------------------------------------------------------------- Fig. 12


def bench_goodput_zipf(quick: bool = False):
    """Fig. 12: P90 goodput under Zipf length distributions, ESP vs
    static-SP vs replication ablations."""
    import copy

    from repro.baselines import FixedGroupsEngine, StaticTPEngine
    from repro.configs import get_config
    from repro.data import zipf_workload
    from repro.engine.server import LoongServeEngine

    cfg = get_config("lwm-7b")
    n = 40 if quick else 100
    for a in ([1.2] if quick else [0.9, 1.2, 1.5]):
        # load high enough that static strategies saturate (paper Fig. 12)
        reqs = zipf_workload(n, zipf_a=a, rate=2.0, seed=13)
        t0 = time.perf_counter()
        res = {}
        for name, ctor in [
            ("esp", lambda: LoongServeEngine(cfg, 8, 120_000)),
            ("static_sp", lambda: StaticTPEngine(cfg, 8, 120_000)),
            ("replicated", lambda: FixedGroupsEngine(
                cfg, 8, 120_000, groups=[[i] for i in range(8)])),
        ]:
            eng = ctor()
            for r in copy.deepcopy(reqs):
                eng.submit(r)
            m = eng.run()
            fin = [r for r in m.finished if r.finish_time is not None]
            lat = sorted(
                r.norm_e2e_latency() for r in fin if r.norm_e2e_latency()
            )
            if not lat:
                res[name] = 0.0
                continue
            slo = (lat[len(lat) // 2] or 1e-6) * 25  # paper: 25x light-load
            good = [r for r in fin if (r.norm_e2e_latency() or 9e9) <= slo]
            span = max(r.finish_time for r in fin) - min(r.arrival for r in fin)
            res[name] = sum(r.seq_len for r in good) / max(span, 1e-9)
        us = (time.perf_counter() - t0) * 1e6
        esp = res["esp"]
        _row(
            f"fig12_goodput_zipf{a}", us,
            ";".join(
                f"vs_{k}:{esp/max(v,1e-9):.2f}x" for k, v in res.items() if k != "esp"
            ),
        )


# ---------------------------------------------------------------- Fig. 13


def bench_scaling_overhead(quick: bool = False):
    """Fig. 13: overhead of scale-down (proactive) and scale-up
    (multi-master) measured on REAL CPU compute with a reduced model."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import REGISTRY, reduced
    from repro.models import attention as A
    from repro.models import build_model

    cfg = reduced(REGISTRY["lwm-7b"])
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    b, t = (1, 128) if quick else (2, 256)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, t)), jnp.int32)

    # scale-DOWN: prefill with vs without proactive retention writes (the
    # retention reuses tensors the ring already produced — host pool writes)
    pre = jax.jit(lambda p, tk: model.prefill(p, {"tokens": tk}))
    pre(params, toks)[0].block_until_ready()
    # baseline: prefill + store full KV into ONE pool (every system stores KV)
    t0 = time.perf_counter()
    for _ in range(5):
        logits, cache = pre(params, toks)
        k = np.asarray(cache.k[:, 0])
        v = np.asarray(cache.v[:, 0])
    base = (time.perf_counter() - t0) / 5
    # proactive scale-down: same prefill, KV retained SPLIT across two target
    # pools per the placement plan (the ring already delivered every stripe)
    t0 = time.perf_counter()
    for _ in range(5):
        logits, cache = pre(params, toks)
        k = np.asarray(cache.k[:, 0])
        v = np.asarray(cache.v[:, 0])
        _ = (k[:, ::2], v[:, ::2], k[:, 1::2], v[:, 1::2])
    with_scale = (time.perf_counter() - t0) / 5
    down_ovh = (with_scale - base) / base * 100

    # scale-UP: decode partials across 1 -> 2 shards (multi-master combine)
    q = jnp.asarray(rng.normal(size=(b, 1, cfg.n_heads, cfg.head_dim)), jnp.float32)
    kc = jnp.asarray(rng.normal(size=(b, t, cfg.n_kv_heads, cfg.head_dim)), jnp.float32)
    vc = jnp.asarray(rng.normal(size=(b, t, cfg.n_kv_heads, cfg.head_dim)), jnp.float32)
    lens = jnp.full((b,), t, jnp.int32)
    one = jax.jit(
        lambda q, k, v: A.finalize_partial(A.partial_attention(q, k, v, None))
    )
    one(q, kc, vc).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(10):
        one(q, kc, vc).block_until_ready()
    t_one = (time.perf_counter() - t0) / 10

    def two(q, k, v):  # same math split over 2 shards + LSE combine
        h = t // 2
        p1 = A.partial_attention(q, k[:, :h], v[:, :h], None)
        p2 = A.partial_attention(q, k[:, h:], v[:, h:], None)
        return A.finalize_partial(A.merge_partial(p1, p2))

    two_j = jax.jit(two)
    two_j(q, kc, vc).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(10):
        two_j(q, kc, vc).block_until_ready()
    t_two = (time.perf_counter() - t0) / 10
    up_ovh = (t_two - t_one) / t_one * 100
    _row(
        "fig13_scaling_overhead", base * 1e6,
        f"scale_down_ovh:{down_ovh:.1f}%;scale_up_ovh:{up_ovh:.1f}%",
    )


# ---------------------------------------------------------------- Fig. 14


def bench_analytical_model(quick: bool = False):
    """Fig. 14: least-squares analytical model accuracy on REAL measured CPU
    prefill times of the reduced model."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import REGISTRY, reduced
    from repro.manager.sib import SIB
    from repro.models import build_model

    cfg = reduced(REGISTRY["lwm-7b"])
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    sib = SIB(cfg)
    rng = np.random.default_rng(0)
    fwd = jax.jit(lambda p, tk: model.forward(p, {"tokens": tk})[0])
    lengths = [32, 64, 96, 128] if quick else [32, 64, 96, 128, 160, 192]
    t0 = time.perf_counter()
    samples = []
    for ln in lengths:
        toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, ln)), jnp.int32)
        fwd(params, toks).block_until_ready()  # compile
        reps = 3
        t1 = time.perf_counter()
        for _ in range(reps):
            fwd(params, toks).block_until_ready()
        samples.append((ln, (time.perf_counter() - t1) / reps))
    for ln, dt in samples[:-1]:
        sib.record_prefill(1, [ln], dt)
    holdout = samples[-1]
    pred = sib.prefill_time(1, [holdout[0]])
    err = abs(pred - holdout[1]) / holdout[1] * 100
    us = (time.perf_counter() - t0) * 1e6
    _row("fig14_analytical_model", us, f"holdout_err:{err:.1f}%")


# ------------------------------------------------------------- kernels §6


def bench_kernels(quick: bool = False):
    """§6 kernels: interpret-mode correctness vs pure-jnp oracle."""
    import jax
    import jax.numpy as jnp

    from repro.core import striped as st
    from repro.kernels import ops

    b, s, h, kvh, d = 1, 256, 4, 2, 64
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, s, h, d))
    k = jax.random.normal(ks[1], (b, s, kvh, d))
    v = jax.random.normal(ks[2], (b, s, kvh, d))
    pos = st.striped_positions(s, 4)
    t0 = time.perf_counter()
    out_k = ops.attention(q, k, v, pos, pos, impl="interpret", block_q=64,
                          block_k=64)
    out_r = ops.attention(q, k, v, pos, pos, impl="xla")
    err = float(jnp.max(jnp.abs(out_k - out_r)))
    us = (time.perf_counter() - t0) * 1e6
    _row("kernel_striped_attention", us, f"allclose_err:{err:.1e}")

    lens = jnp.full((b,), s, jnp.int32)
    qd = jax.random.normal(ks[0], (b, 1, h, d))
    t0 = time.perf_counter()
    pk = ops.decode_partial(qd, k, v, lens, impl="interpret", block_k=64)
    pr = ops.decode_partial(qd, k, v, lens, impl="xla")
    err = float(jnp.max(jnp.abs(pk.o - pr.o)))
    us = (time.perf_counter() - t0) * 1e6
    _row("kernel_flash_decode", us, f"allclose_err:{err:.1e}")


# ------------------------------------------------------- paged decode step


def bench_decode_paged(quick: bool = False):
    """Decode-iteration benchmark on the REAL engine hot path: the legacy
    gather-dense dataflow (per-request host gather -> dense Cache -> one
    model.decode per request, i.e. O(batch) dispatches + O(tokens) host
    traffic per step) vs the batched paged path (block tables -> ONE batched
    model.decode with one paged launch per instance per layer).  Both arms
    run the same model, same pools, same DecodeBatch.  Writes
    BENCH_decode.json."""
    import json

    import jax
    import numpy as np

    from repro.configs import REGISTRY, reduced
    from repro.engine.request import Phase, Request
    from repro.engine.server import LoongServeEngine
    from repro.kernels import ops
    from repro.manager.scheduler import DecodeBatch
    from repro.models import build_model

    cfg = reduced(REGISTRY["lwm-7b"])
    page = 64
    b = 8 if quick else 16
    iters = 3 if quick else 10
    n_inst = 2
    rng = np.random.default_rng(0)
    lengths = np.sort(rng.integers(64, 1025, b))  # ragged cached KV

    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    capacity = (-(-int(lengths.sum()) // page) + 16) * page  # per instance
    eng = LoongServeEngine(cfg, n_inst, capacity, store_values=True,
                           model=model, params=params, page_size=page)
    # place ragged cached KV token-granularly across the instances and set up
    # one ready decode group, exactly as after prefill
    reqs = []
    for rid, ln in enumerate(lengths):
        n = int(ln)
        r = Request(input_len=n, max_new_tokens=64,
                    prompt=rng.integers(0, cfg.vocab_size, n).tolist())
        r.rid, r.generated, r.phase = rid, 1, Phase.DECODE
        r.output_tokens = [int(rng.integers(0, cfg.vocab_size))]
        plan = eng.pool.plan_placement(rid, list(range(n)), range(n_inst))
        k = rng.normal(size=(eng.pool.pools[0].n_attn, n, cfg.n_kv_heads,
                             cfg.head_dim))
        eng.pool.place(plan, k, k + 1)
        reqs.append(r)
    g = DecodeBatch(reqs, list(range(n_inst)),
                    {r.rid: r.rid % n_inst for r in reqs})
    impl = ops.get_default_impl()

    # steady state appends one token's KV per request per iteration; model it
    # in BOTH arms by re-filling each request's newest cached token so the
    # paged arm pays its incremental device-mirror sync and the dense arm its
    # re-gather (same host-side write cost on each side)
    fills = []
    for r in reqs:
        last = r.seq_len - 2
        inst = next(i for i in range(n_inst)
                    if last in eng.pool.pools[i].tokens_of(r.rid))
        kv1 = rng.normal(size=(eng.pool.pools[0].n_attn, 1, cfg.n_kv_heads,
                               cfg.head_dim))
        fills.append((eng.pool.pools[inst], r.rid, last, kv1))

    def run_arm(step):
        step(g)  # warmup / compile
        ops.reset_dispatch_counts()
        t0 = time.perf_counter()
        for _ in range(iters):
            for pool, rid, pos, kv1 in fills:
                pool.fill(rid, [pos], kv1, kv1)
            step(g)
        dt = (time.perf_counter() - t0) / iters
        return dt, {k: v // iters for k, v in ops.dispatch_counts.items()}

    t_dense, d_dense = run_arm(eng._real_decode_serial)
    t_paged, d_paged = run_arm(eng._real_decode_paged)
    results = {
        "gather_dense": {"s_per_decode_iter": t_dense, "dispatches": d_dense},
        "paged_batched": {"s_per_decode_iter": t_paged, "dispatches": d_paged},
    }
    speedup = t_dense / t_paged
    out = {
        "batch": b,
        "n_instances": n_inst,
        "page_size": page,
        "n_layers": int(eng.pool.pools[0].n_attn),
        "lengths": [int(x) for x in lengths],
        "kernel_impl": impl,
        # a decode iteration emits one token per request
        **{f"{k}_tok_s": float(b / v["s_per_decode_iter"])
           for k, v in results.items()},
        **{f"{k}_s_per_iter": v["s_per_decode_iter"]
           for k, v in results.items()},
        "dispatches_per_iter": {k: v["dispatches"] for k, v in results.items()},
        "speedup": speedup,
    }
    # quick mode gets its own artifact so it can't clobber the committed one
    path = "BENCH_decode_quick.json" if quick else "BENCH_decode.json"
    with open(path, "w") as f:
        json.dump(out, f, indent=2)
    _row(
        "decode_paged_vs_gather",
        t_paged * 1e6,
        f"speedup:{speedup:.2f}x;batch:{b};"
        f"paged_launches:{sum(d_paged.values())}",
    )


# ------------------------------------------------------ packed prefill step


def bench_prefill_packed(quick: bool = False):
    """Prefill benchmark on the REAL engine hot path: per-request serial
    prefill (one eager model.prefill per request — a fresh program per
    distinct prompt length, host-side pool.fill) vs packed ragged prefill
    (ONE jitted packed step per batch, segment-masked ragged attention,
    direct-to-pool paged KV write-through).  Same model, same pools, same
    PrefillBatch with reserved striped placement.  Writes
    BENCH_prefill.json."""
    import json

    import jax
    import numpy as np

    from repro.configs import REGISTRY, reduced
    from repro.engine.request import Phase, Request
    from repro.engine.server import LoongServeEngine
    from repro.kernels import ops
    from repro.manager.scheduler import PrefillBatch
    from repro.models import build_model

    cfg = reduced(REGISTRY["lwm-7b"])
    page = 64
    b = 8 if quick else 16
    iters = 2 if quick else 5
    n_inst = 2
    rng = np.random.default_rng(0)
    lo, hi = (32, 128) if quick else (64, 512)
    lengths = rng.integers(lo, hi + 1, b)
    lengths[0], lengths[-1] = lo, hi  # span >= 4x guaranteed

    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    capacity = (-(-int(lengths.sum()) // page) + 16) * page  # per instance
    eng = LoongServeEngine(cfg, n_inst, capacity, store_values=True,
                           model=model, params=params, page_size=page)
    # reserve striped token-granular placement across the instances, exactly
    # as the scheduler's proactive scale-down does before prefill executes
    reqs, placement = [], {}
    for rid, ln in enumerate(lengths):
        n = int(ln)
        r = Request(input_len=n, max_new_tokens=8,
                    prompt=rng.integers(0, cfg.vocab_size, n).tolist())
        r.rid, r.phase = rid, Phase.PREFILL
        plan = eng.pool.plan_placement(rid, list(range(n)), range(n_inst))
        eng.pool.place(plan)  # reserve slots; prefill fills the values
        placement[rid] = plan.assignment
        reqs.append(r)
    batch = PrefillBatch(reqs, list(range(n_inst)),
                         scale_down_to=list(range(n_inst)),
                         placement=placement)
    impl = ops.get_default_impl()

    def reset():
        for r in reqs:
            r.output_tokens = []

    def run_arm(step):
        reset()
        step(batch)  # warmup / compile
        t0 = time.perf_counter()
        for _ in range(iters):
            reset()
            step(batch)
        return (time.perf_counter() - t0) / iters

    t_serial = run_arm(eng._real_prefill_serial)
    t_packed = run_arm(eng._real_prefill_packed)

    # launch-count instrumentation: the jitted packed step fuses its
    # launches, so count the dataflow once in eager (disable_jit) mode —
    # exactly one prefill_packed dispatch per layer per batch
    ops.reset_dispatch_counts()
    with jax.disable_jit():
        reset()
        eng._real_prefill_packed(batch)
    packed_dispatches = dict(ops.dispatch_counts)

    # write-through invariant: after a packed prefill no slot is dirty, so
    # the first decode's mirror sync would upload zero prefill slots
    post_dirty = sum(p.dirty_slot_count() for p in eng.pool.pools)

    # bucketing: sweep random batch shapes up to max_tokens and count the
    # distinct compiled packed-prefill programs — O(log max_tokens), not one
    # per prompt length
    max_tokens = int(lengths.sum())
    n_sweep = 3 if quick else 12
    for s in range(n_sweep):
        ls = rng.integers(lo, hi + 1, int(rng.integers(2, b + 1)))
        sreqs = []
        for j, ln in enumerate(ls):
            r = Request(input_len=int(ln), max_new_tokens=8,
                        prompt=rng.integers(0, cfg.vocab_size, int(ln)).tolist())
            r.rid = 10_000 + s * 100 + j
            sreqs.append(r)
        # no placement -> the KV scatter is skipped; only the model step runs
        eng._real_prefill_packed(
            PrefillBatch(sreqs, list(range(n_inst)), scale_down_to=[])
        )
    n_programs = len(eng._prefill_programs)

    total = int(lengths.sum())
    speedup = t_serial / t_packed
    out = {
        "batch": b,
        "n_instances": n_inst,
        "page_size": page,
        "n_layers": int(eng.pool.pools[0].n_attn),
        "lengths": [int(x) for x in lengths],
        "total_prompt_tokens": total,
        "kernel_impl": impl,
        "serial_tok_s": float(total / t_serial),
        "packed_tok_s": float(total / t_packed),
        "serial_s_per_batch": t_serial,
        "packed_s_per_batch": t_packed,
        "speedup": speedup,
        # eager-instrumented dataflow: one prefill_packed launch per layer
        "packed_dispatches_per_batch": packed_dispatches,
        "prefill_packed_per_layer": (
            packed_dispatches.get("prefill_packed", 0)
            == int(eng.pool.pools[0].n_attn)
        ),
        "post_prefill_dirty_slots": int(post_dirty),
        "distinct_compiled_prefill_programs": n_programs,
        "sweep_batches": n_sweep + 1,
        "log2_max_tokens": int(np.ceil(np.log2(max(max_tokens, 2)))),
    }
    path = "BENCH_prefill_quick.json" if quick else "BENCH_prefill.json"
    with open(path, "w") as f:
        json.dump(out, f, indent=2)
    _row(
        "prefill_packed_vs_serial",
        t_packed * 1e6,
        f"speedup:{speedup:.2f}x;batch:{b};programs:{n_programs};"
        f"dirty_after:{post_dirty}",
    )


# ------------------------------------------------- ring-fused DoP>1 prefill


def bench_prefill_ring(quick: bool = False):
    """Ring-fused packed prefill for multi-instance (DoP>1) ESP groups on the
    REAL engine hot path: per-request serial prefill (the pre-fusion fallback
    for scaled-up groups — one eager model.prefill per request) vs the packed
    ring (ONE jitted packed step per batch; attention runs one packed ragged
    chunk launch per instance per ring step with carried flash state), at
    DoP in {1, 2, 4}.  Same model, same pools, same PrefillBatch with
    reserved striped placement.  Writes BENCH_prefill_ring.json."""
    import json

    import jax
    import numpy as np

    from repro.configs import REGISTRY, reduced
    from repro.engine.request import Phase, Request
    from repro.engine.server import LoongServeEngine
    from repro.kernels import ops
    from repro.manager.scheduler import PrefillBatch
    from repro.models import build_model

    cfg = reduced(REGISTRY["lwm-7b"])
    page = 64
    b = 4 if quick else 8
    iters = 2 if quick else 3
    lo, hi = (64, 256) if quick else (256, 1024)
    rng = np.random.default_rng(0)
    lengths = rng.integers(lo, hi + 1, b)
    lengths[0], lengths[-1] = lo, hi  # span guaranteed
    total = int(lengths.sum())

    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    impl = ops.get_default_impl()
    results = {}
    for dop in (1, 2, 4):
        capacity = (-(-total // page) + 16) * page  # per instance
        eng = LoongServeEngine(cfg, dop, capacity, store_values=True,
                               model=model, params=params, page_size=page)
        reqs, placement = [], {}
        for rid, ln in enumerate(lengths):
            n = int(ln)
            r = Request(input_len=n, max_new_tokens=8,
                        prompt=rng.integers(0, cfg.vocab_size, n).tolist())
            r.rid, r.phase = rid, Phase.PREFILL
            plan = eng.pool.plan_placement(rid, list(range(n)), range(dop))
            eng.pool.place(plan)  # reserve slots; the ring fills the values
            placement[rid] = plan.assignment
            reqs.append(r)
        batch = PrefillBatch(reqs, list(range(dop)),
                             scale_down_to=list(range(dop)),
                             placement=placement)

        def reset():
            for r in reqs:
                r.output_tokens = []

        def run_arm(step):
            reset()
            step(batch)  # warmup / compile
            best = float("inf")
            for _ in range(iters):
                reset()
                t0 = time.perf_counter()
                step(batch)
                best = min(best, time.perf_counter() - t0)
            return best  # min-of-iters: robust to background load spikes

        t_serial = run_arm(eng._real_prefill_serial)
        t_packed = run_arm(eng._real_prefill_packed)
        # eager-instrumented dataflow: zero per-request serial model.prefill
        # calls, dop^2 ring-chunk launches per layer (1 per instance per
        # ring step) — the jitted step fuses them, so count with disable_jit
        ops.reset_dispatch_counts()
        with jax.disable_jit():
            reset()
            eng._real_prefill_packed(batch)
        d = dict(ops.dispatch_counts)
        results[f"dop{dop}"] = {
            "serial_tok_s": float(total / t_serial),
            "packed_tok_s": float(total / t_packed),
            "serial_s_per_batch": t_serial,
            "packed_s_per_batch": t_packed,
            "speedup": t_serial / t_packed,
            "packed_dispatches_per_batch": d,
            "serial_model_prefill_calls": d.get("prefill_serial_model", 0),
            "post_prefill_dirty_slots": int(
                sum(p.dirty_slot_count() for p in eng.pool.pools)
            ),
            "host_syncs": int(sum(p.host_syncs for p in eng.pool.pools)),
        }
    out = {
        "batch": b,
        "page_size": page,
        "n_layers": int(cfg.n_attention_applications),
        "lengths": [int(x) for x in lengths],
        "total_prompt_tokens": total,
        "kernel_impl": impl,
        **results,
        "dop2_speedup": results["dop2"]["speedup"],
    }
    path = "BENCH_prefill_ring_quick.json" if quick else "BENCH_prefill_ring.json"
    with open(path, "w") as f:
        json.dump(out, f, indent=2)
    _row(
        "prefill_ring_vs_serial",
        results["dop2"]["packed_s_per_batch"] * 1e6,
        ";".join(
            f"{k}_speedup:{v['speedup']:.2f}x" for k, v in results.items()
        ) + f";batch:{b};serial_calls_in_packed:"
        f"{results['dop2']['serial_model_prefill_calls']}",
    )


# ------------------------------------------- unified mixed prefill+decode


def bench_mixed(quick: bool = False):
    """Mixed continuous-batching workload on the REAL engine: B=8 short
    requests are mid-decode when ONE long prompt arrives whose placement
    must span every instance.  Sequential baseline (``prefill_chunk_tokens``
    unset): the monolithic prefill annexes the decode instances and token
    emission stalls for the whole prompt.  Unified arm: the prefill runs as
    a chain of bounded chunks and the decode rows RIDE each fused iteration,
    so the worst-case time-between-tokens collapses from one-full-prefill to
    one-chunk.  Reports decode TBT p50/p99 (engine-clock emission
    timestamps), the p99 ratio, riding evidence from the fused-step token
    counters, and wall-clock tok/s.  Writes BENCH_mixed.json."""
    import copy
    import json

    import jax
    import numpy as np

    from repro.configs import REGISTRY, reduced
    from repro.engine.request import Request
    from repro.engine.server import LoongServeEngine
    from repro.kernels import ops
    from repro.kernels import ref as kref
    from repro.manager.scheduler import ManagerConfig
    from repro.models import build_model

    cfg = reduced(REGISTRY["lwm-7b"])
    n_inst = 2
    b = 8
    # short_new sized so the 8 stall-affected TBT samples (one per short,
    # the diff spanning the baseline's monolithic long prefill) sit fully
    # above the p99 index of the 8*(short_new-1) samples — p99 must measure
    # the stall, not interpolate across its boundary
    short_len, short_new = (16, 48) if quick else (32, 64)
    long_len, chunk = (1280, 64) if quick else (2048, 256)
    long_new = 4
    # capacity: sized so the long prompt IS admitted while the shorts are
    # still mid-decode (fleet-wide free >= its footprint + growth reserve)
    # but does NOT fit on one instance, so its placement (and the
    # baseline's monolithic prefill) spans both — stripping the shorts'
    # decode group — the contended scenario the unified step targets
    capacity = 912 if quick else 1600
    rng = np.random.default_rng(0)
    reqs = []
    for _ in range(b):
        reqs.append(Request(
            input_len=short_len, max_new_tokens=short_new, arrival=0.0,
            prompt=rng.integers(0, cfg.vocab_size, short_len).tolist(),
        ))
    long_req = Request(
        input_len=long_len, max_new_tokens=long_new, arrival=0.05,
        prompt=rng.integers(0, cfg.vocab_size, long_len).tolist(),
    )
    reqs.append(long_req)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    oracle = {
        i: kref.serial_decode_oracle(model, params, r.prompt,
                                     r.max_new_tokens - 1)
        for i, r in enumerate(reqs)
    }

    def seed_profile(sib):
        # serving-scale iteration-time profile (the paper's SQLite profile
        # store, condensed to a fitted plane): per-token prefill cost
        # dominates the launch overhead, so a monolithic long prefill
        # occupies its instances for time proportional to prompt length.
        # DoP=2 gets a mild efficiency edge so DP batching keeps the
        # same-instant burst in one spanning batch (one decode group).
        # Identical profile for both arms; decode keeps the napkin model.
        for dop in (1, 2):
            beta = 25e-6 / dop * (0.96 if dop == 2 else 1.0)
            for lens in ([64], [256], [1024], [2048], [512, 512]):
                s1 = sum(lens)
                s2 = sum(l * l for l in lens)
                sib.record_prefill(dop, lens, 0.003 + beta * s1 + 1e-11 * s2)
        # the memory-bound tipping point is profilable too (§5.1); the
        # napkin default reflects the reduced toy model, not this profile —
        # pin it so a burst of B shorts still forms one prefill batch
        sib.prefill_tipping_point = lambda dop: 0.012

    def run_arm(chunk_tokens):
        eng = LoongServeEngine(
            cfg, n_inst, capacity, store_values=True, model=model,
            params=params, page_size=16,
            mcfg=ManagerConfig(prefill_chunk_tokens=chunk_tokens),
        )
        seed_profile(eng.sib)
        rs = copy.deepcopy(reqs)
        shorts = rs[:b]
        # engine-clock emission timestamps of every short-request token
        emitted = {id(r): [0] * 0 for r in shorts}
        seen = {id(r): 0 for r in shorts}

        def watch(e, kind, payload):
            for r in shorts:
                if r.generated > seen[id(r)]:
                    emitted[id(r)].extend(
                        [e.clock] * (r.generated - seen[id(r)])
                    )
                    seen[id(r)] = r.generated

        ops.reset_dispatch_counts()
        for r in rs:
            eng.submit(r)
        eng.event_hooks.append(watch)
        t0 = time.perf_counter()
        m = eng.run()
        wall = time.perf_counter() - t0
        assert len(m.finished) == len(rs), (chunk_tokens, len(m.finished))
        for i, r in enumerate(rs):
            assert r.output_tokens == oracle[i], (chunk_tokens, i)
        tbt = np.concatenate([
            np.diff(np.asarray(ts)) for ts in emitted.values() if len(ts) > 1
        ])
        total_tok = sum(r.generated for r in rs)
        return {
            "decode_tbt_p50": float(np.percentile(tbt, 50)),
            "decode_tbt_p99": float(np.percentile(tbt, 99)),
            "decode_tbt_max": float(tbt.max()),
            "wall_tok_s": float(total_tok / wall),
            "unified_steps": int(ops.dispatch_counts["unified_step"]),
            "unified_decode_tokens": int(
                ops.dispatch_counts["unified_decode_tokens"]
            ),
        }

    seq = run_arm(None)
    uni = run_arm(chunk)
    ratio = seq["decode_tbt_p99"] / max(uni["decode_tbt_p99"], 1e-12)
    out = {
        "batch": b,
        "n_instances": n_inst,
        "short_len": short_len,
        "short_new_tokens": short_new,
        "long_len": long_len,
        "prefill_chunk_tokens": chunk,
        "kernel_impl": ops.get_default_impl(),
        "sequential": seq,
        "unified": uni,
        "tbt_p99_ratio": ratio,
    }
    path = "BENCH_mixed_quick.json" if quick else "BENCH_mixed.json"
    with open(path, "w") as f:
        json.dump(out, f, indent=2)
    _row(
        "mixed_unified_vs_sequential",
        uni["decode_tbt_p99"] * 1e6,
        f"tbt_p99_ratio:{ratio:.2f}x;"
        f"riders:{uni['unified_decode_tokens']};"
        f"steps:{uni['unified_steps']}",
    )


# ------------------------------------------------- SPMD mesh-executor ring


def bench_prefill_spmd(quick: bool = False):
    """Mesh-executor ring prefill on an 8-virtual-device host mesh: the
    DoP>1 packed prefill as ONE shard_map program with the KV stripes
    ppermuted between devices — double-buffered vs sequential ring vs the
    in-process LocalExecutor replay, plus exact per-ring-step ppermute
    bytes.  Runs in a subprocess because the device-count XLA flag must be
    set before jax initializes.  Writes BENCH_prefill_spmd.json."""
    import os
    import pathlib
    import subprocess
    import sys

    root = pathlib.Path(__file__).parent.parent
    # the child module self-appends the 8-device XLA flag before jax
    # initializes; only PYTHONPATH needs to be threaded through here
    env = dict(os.environ)
    env["PYTHONPATH"] = str(root / "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else ""
    )
    cmd = [sys.executable, "-m", "benchmarks.prefill_spmd"]
    if quick:
        cmd.append("--quick")
    out = subprocess.run(cmd, env=env, cwd=root, capture_output=True,
                         text=True, timeout=3600)
    if out.returncode != 0:
        raise RuntimeError(out.stdout + "\n" + out.stderr)
    row = next(
        ln for ln in out.stdout.splitlines() if ln.startswith("prefill_spmd,")
    )
    _, us, derived = row.split(",", 2)
    _row("prefill_spmd", float(us), derived)


# ------------------------------------------------ SPMD mesh-executor decode


def bench_decode_spmd(quick: bool = False):
    """Mesh-executor decode on an 8-virtual-device host mesh: the whole
    batched decode iteration as ONE shard_map program — the batch-sharded
    multi-master arm (stack on B/n rows per rank, all_gather/psum_scatter
    boundary, in-program sampling) vs the replicated overlapped/barriered
    programs vs the per-shard Python loop with explicit device hops — plus
    per-iteration collective payload bytes, structural StableHLO overlap
    evidence and the ~1/n dot-FLOP census ratio.  Runs in a subprocess
    because the device-count XLA flag must be set before jax initializes.
    Writes BENCH_decode_spmd.json."""
    import os
    import pathlib
    import subprocess
    import sys

    root = pathlib.Path(__file__).parent.parent
    # the child module self-appends the 8-device XLA flag before jax
    # initializes; only PYTHONPATH needs to be threaded through here
    env = dict(os.environ)
    env["PYTHONPATH"] = str(root / "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else ""
    )
    cmd = [sys.executable, "-m", "benchmarks.decode_spmd"]
    if quick:
        cmd.append("--quick")
    out = subprocess.run(cmd, env=env, cwd=root, capture_output=True,
                         text=True, timeout=3600)
    if out.returncode != 0:
        raise RuntimeError(out.stdout + "\n" + out.stderr)
    row = next(
        ln for ln in out.stdout.splitlines() if ln.startswith("decode_spmd,")
    )
    _, us, derived = row.split(",", 2)
    _row("decode_spmd", float(us), derived)


# -------------------------------------------------------------- roofline


def bench_roofline_summary(quick: bool = False):
    """Surfaces the dry-run roofline table if dryrun_singlepod.json exists."""
    import json
    import os

    path = "dryrun_singlepod.json"
    if not os.path.exists(path):
        _row("roofline_summary", 0.0, "run launch.dryrun --all first")
        return
    with open(path) as f:
        rows = json.load(f)
    t0 = time.perf_counter()
    ok = [r for r in rows if r.get("status") == "ok"]
    if not ok:
        _row("roofline_summary", 0.0, "no ok cells")
        return
    worst = min(
        ok,
        key=lambda r: r["roofline"]["compute_s"]
        / max(sum(r["roofline"][k] for k in ("compute_s", "memory_s", "collective_s")), 1e-12),
    )
    n_dom = {}
    for r in ok:
        dom = r["roofline"]["dominant"]
        n_dom[dom] = n_dom.get(dom, 0) + 1
    us = (time.perf_counter() - t0) * 1e6
    _row(
        "roofline_summary", us,
        f"cells:{len(ok)};dominants:{n_dom};worst:{worst['arch']}x{worst['shape']}",
    )


BENCHES = {
    "fig2": bench_scalability,
    "fig10": bench_end_to_end,
    "fig11": bench_multinode,
    "fig12": bench_goodput_zipf,
    "fig13": bench_scaling_overhead,
    "fig14": bench_analytical_model,
    "kernels": bench_kernels,
    "decode": bench_decode_paged,
    "prefill": bench_prefill_packed,
    "prefill_ring": bench_prefill_ring,
    "mixed": bench_mixed,
    "prefill_spmd": bench_prefill_spmd,
    "decode_spmd": bench_decode_spmd,
    "roofline": bench_roofline_summary,
}

# CI smoke: the engine hot paths (quick mode, *_quick.json artifacts);
# failures are fatal so the benchmark paths can't silently rot.
SMOKE = ("decode", "prefill", "prefill_ring", "mixed", "prefill_spmd",
         "decode_spmd")


def _bench_headline(data: dict) -> dict:
    """Extract one bench artifact's headline numbers: every ``*tok_s``
    leaf, every ``collective_bytes_per_iter`` table and every
    speedup/ratio leaf, each keyed by its dotted path in the artifact."""
    tok_s: dict = {}
    bytes_iter: dict = {}
    derived: dict = {}

    def walk(node, prefix):
        if not isinstance(node, dict):
            return
        for k, v in node.items():
            p = f"{prefix}.{k}" if prefix else k
            if k == "collective_bytes_per_iter" and isinstance(v, dict):
                for ck, cv in v.items():
                    bytes_iter[f"{p}.{ck}"] = cv
            elif isinstance(v, dict):
                walk(v, p)
            elif isinstance(v, (int, float)) and not isinstance(v, bool):
                if k.endswith("tok_s"):
                    tok_s[p] = v
                elif "speedup" in k or "ratio" in k:
                    derived[p] = v

    walk(data, "")
    out = {}
    if tok_s:
        out["tok_s"] = tok_s
    if bytes_iter:
        out["bytes_per_iter"] = bytes_iter
    if derived:
        out["derived"] = derived
    return out


def collate() -> None:
    """Merge the committed per-PR ``BENCH_*.json`` artifacts (the _quick CI
    variants excluded) into ``BENCH_trajectory.json``: a ``latest`` headline
    snapshot per bench plus an append-only per-commit ``history`` (one entry
    per commit, overwritten on re-run at the same commit)."""
    import glob
    import json
    import subprocess

    benches = {}
    for path in sorted(glob.glob("BENCH_*.json")):
        name = path[len("BENCH_"):-len(".json")]
        if name.endswith("_quick") or name == "trajectory":
            continue
        with open(path) as f:
            headline = _bench_headline(json.load(f))
        if headline:
            benches[name] = headline
    try:
        commit = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"], capture_output=True,
            text=True, check=True,
        ).stdout.strip()
    except Exception:  # noqa: BLE001 — not a repo / no git: still collate
        commit = "unknown"
    out_path = "BENCH_trajectory.json"
    try:
        with open(out_path) as f:
            traj = json.load(f)
    except (FileNotFoundError, json.JSONDecodeError):
        traj = {"history": []}
    traj["latest"] = {"commit": commit, "benches": benches}
    history = [e for e in traj.get("history", []) if e.get("commit") != commit]
    history.append({"commit": commit, "benches": benches})
    traj["history"] = history
    with open(out_path, "w") as f:
        json.dump(traj, f, indent=2)
    _row("collate", 0.0,
         f"benches:{len(benches)};commits:{len(history)};out:{out_path}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None)
    ap.add_argument("--smoke", action="store_true",
                    help="CI: quick decode+prefill benches only; raise on error")
    ap.add_argument("--collate", action="store_true",
                    help="merge BENCH_*.json into BENCH_trajectory.json")
    args = ap.parse_args()
    if args.collate:
        print("name,us_per_call,derived")
        collate()
        return
    if args.smoke:
        args.quick = True
    print("name,us_per_call,derived")
    for name, fn in BENCHES.items():
        if args.smoke and name not in SMOKE:
            continue
        if args.only and args.only not in name:
            continue
        try:
            fn(quick=args.quick)
        except Exception as e:  # noqa: BLE001
            if args.smoke:
                raise
            _row(name, 0.0, f"ERROR:{type(e).__name__}:{e}")


if __name__ == "__main__":
    main()

"""SPMD mesh-executor prefill benchmark body (multi-device subprocess).

Launched by `benchmarks/run.py --only prefill_spmd` as
``XLA_FLAGS=--xla_force_host_platform_device_count=8 python -m
benchmarks.prefill_spmd [--quick]`` because the device-count flag must be
set before jax initializes (the parent benchmark process may already hold a
single-device runtime).

Measures the REAL engine hot path at DoP {2, 4}, B=8, lengths 256-1024:

  * ``mesh_db``  — MeshExecutor, double-buffered ring (the ppermute for
    step s+1 issued before folding step s);
  * ``mesh_seq`` — MeshExecutor, sequential ring (transfer pinned behind
    the fold with an optimization barrier);
  * ``local``    — LocalExecutor in-process replay, same batch, for scale.

plus the exact per-ring-step ppermute payload bytes (trace-time counters in
`kernels.ops` — static shapes make them exact).  Writes
``BENCH_prefill_spmd.json`` (``_quick`` suffix under --quick).
"""
from __future__ import annotations

import argparse
import json
import os
import time

_DEV_FLAG = "--xla_force_host_platform_device_count=8"
if "--xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    # append, preserving any user-supplied XLA flags (must happen before
    # jax initializes)
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "") + " " + _DEV_FLAG
    ).strip()


def run(quick: bool = False) -> dict:
    import jax
    import numpy as np

    from repro.configs import REGISTRY, reduced
    from repro.engine.executor import MeshExecutor
    from repro.engine.request import Phase, Request
    from repro.engine.server import LoongServeEngine
    from repro.kernels import ops
    from repro.launch.mesh import make_test_mesh
    from repro.manager.scheduler import PrefillBatch
    from repro.models import build_model

    cfg = reduced(REGISTRY["lwm-7b"])
    page = 64
    b = 4 if quick else 8
    iters = 2 if quick else 3
    lo, hi = (64, 256) if quick else (256, 1024)
    rng = np.random.default_rng(0)
    lengths = rng.integers(lo, hi + 1, b)
    lengths[0], lengths[-1] = lo, hi  # span guaranteed
    total = int(lengths.sum())

    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    n_dev = len(jax.devices())
    results: dict = {}
    for dop in (2, 4):
        mesh = make_test_mesh(data=dop, model=max(n_dev // dop, 1))

        def build(arm: str):
            capacity = (-(-total // page) + 16) * page  # per instance
            if arm == "local":
                eng = LoongServeEngine(cfg, dop, capacity, store_values=True,
                                       model=model, params=params,
                                       page_size=page)
            else:
                eng = LoongServeEngine(cfg, dop, capacity, store_values=True,
                                       model=model, params=params,
                                       page_size=page, mesh=mesh)
                if arm == "mesh_seq":
                    eng.executor = MeshExecutor(eng, mesh,
                                                double_buffer=False)
            reqs, placement = [], {}
            for rid, ln in enumerate(lengths):
                n = int(ln)
                r = Request(input_len=n, max_new_tokens=8,
                            prompt=rng.integers(0, cfg.vocab_size, n).tolist())
                r.rid, r.phase = rid, Phase.PREFILL
                plan = eng.pool.plan_placement(rid, list(range(n)), range(dop))
                eng.pool.place(plan)  # reserve; the ring fills the values
                placement[rid] = plan.assignment
                reqs.append(r)
            batch = PrefillBatch(reqs, list(range(dop)),
                                 scale_down_to=list(range(dop)),
                                 placement=placement)
            return eng, batch

        # structural overlap check at the ring-driver level (StableHLO —
        # the CPU backend elides the barrier after scheduling): the
        # double-buffered program carries NO optimization barrier between
        # the permute and the fold (the transfer is free to overlap), the
        # sequential program does (transfer pinned behind the fold); both
        # move the same n-1 collective-permute legs.  Wall-clock on the CPU
        # host platform cannot show the overlap win — XLA:CPU executes
        # collective-permute synchronously inside each device's thunk
        # sequence — so this is the platform-independent evidence the
        # orderings differ as designed; the latency hiding itself is a
        # TPU/ICI property.
        from repro.core import esp

        hlo = {}
        for db in (True, False):
            tb = int(-(-total // dop) * dop)  # token axis, dop-aligned
            spec = jax.ShapeDtypeStruct
            lowered = jax.jit(
                lambda q, k, v, o, _db=db: esp.ring_packed_prefill_spmd(
                    mesh, q, k, v, o, max_seq_len=hi, double_buffer=_db,
                )
            ).lower(
                spec((tb, cfg.n_heads, cfg.head_dim), "float32"),
                spec((tb, cfg.n_kv_heads, cfg.head_dim), "float32"),
                spec((tb, cfg.n_kv_heads, cfg.head_dim), "float32"),
                spec((b + 1,), "int32"),
            )
            txt = lowered.as_text()
            hlo["db" if db else "seq"] = {
                "collective_permutes": txt.count("stablehlo.collective_permute"),
                "opt_barriers": txt.count("stablehlo.optimization_barrier"),
            }
        assert hlo["db"]["opt_barriers"] == 0, hlo
        assert hlo["seq"]["opt_barriers"] == dop - 1, hlo
        # one stablehlo op per ppermuted operand (K and V) per ring leg
        assert hlo["db"]["collective_permutes"] == 2 * (dop - 1), hlo

        arm_res: dict = {}
        for arm in ("mesh_db", "mesh_seq", "local"):
            eng, batch = build(arm)

            def reset():
                for r in batch.requests:
                    r.output_tokens = []

            ops.reset_dispatch_counts()
            reset()
            eng._real_prefill_packed(batch)  # warmup: compile (counts trace)
            d = dict(ops.dispatch_counts)
            comm = dict(ops.comm_bytes)
            best = float("inf")
            for _ in range(iters):
                reset()
                t0 = time.perf_counter()
                eng._real_prefill_packed(batch)
                best = min(best, time.perf_counter() - t0)
            legs = d.get("ring_ppermute", 0)
            arm_res[arm] = {
                "tok_s": float(total / best),
                "s_per_batch": best,
                "dispatches_per_trace": d,
                "serial_model_prefill_calls": d.get("prefill_serial_model", 0),
                # >0 only for the local arm (its ring IS the replay)
                "inprocess_ring_replays": d.get("prefill_ring_replay", 0),
                # static-shape exact: one ring leg moves this instance's
                # current (K, V) stripe to its neighbour
                "ppermute_legs_per_trace": legs,
                "ppermute_bytes_per_step": (
                    comm.get("ring_ppermute", 0) // legs if legs else 0
                ),
                "ppermute_bytes_per_trace": comm.get("ring_ppermute", 0),
            }
            if arm.startswith("mesh"):
                assert arm_res[arm]["serial_model_prefill_calls"] == 0
                assert d.get("prefill_ring_replay", 0) == 0, d
                assert d.get("prefill_ring_spmd", 0) >= 1, d
        results[f"dop{dop}"] = {
            **arm_res,
            "db_vs_seq_speedup": (
                arm_res["mesh_seq"]["s_per_batch"]
                / arm_res["mesh_db"]["s_per_batch"]
            ),
            "ring_hlo": hlo,
        }
    out = {
        "batch": b,
        "page_size": page,
        "n_layers": int(cfg.n_attention_applications),
        "lengths": [int(x) for x in lengths],
        "total_prompt_tokens": total,
        "n_devices": n_dev,
        # XLA:CPU runs collective-permute synchronously inside each
        # device's thunk sequence, so the double-buffered ordering cannot
        # beat the sequential one in wall-clock HERE; `ring_hlo` proves the
        # overlap is structurally enabled (no barrier between transfer and
        # fold) — the hiding itself needs async ICI (TPU).
        "collectives_synchronous_on_cpu": True,
        **results,
    }
    path = ("BENCH_prefill_spmd_quick.json" if quick
            else "BENCH_prefill_spmd.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=2)
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    out = run(quick=args.quick)
    rows = []
    for dop in (2, 4):
        r = out[f"dop{dop}"]
        rows.append(
            f"dop{dop}_db:{r['mesh_db']['tok_s']:.0f}tok/s;"
            f"dop{dop}_db_vs_seq:{r['db_vs_seq_speedup']:.2f}x;"
            f"dop{dop}_step_bytes:{r['mesh_db']['ppermute_bytes_per_step']};"
            f"dop{dop}_overlap_hlo:"
            f"{r['ring_hlo']['db']['opt_barriers'] == 0}"
        )
    print(f"prefill_spmd,{out['dop2']['mesh_db']['s_per_batch'] * 1e6:.1f},"
          + ";".join(rows))


if __name__ == "__main__":
    main()

"""SPMD mesh-executor decode benchmark body (multi-device subprocess).

Launched by `benchmarks/run.py --only decode_spmd` as
``XLA_FLAGS=--xla_force_host_platform_device_count=8 python -m
benchmarks.decode_spmd [--quick]`` because the device-count flag must be set
before jax initializes (the parent benchmark process may already hold a
single-device runtime).

Measures one REAL engine decode iteration at DoP {2, 4} over ragged cached
KV striped across the instances' per-device pool mirrors:

  * ``spmd_batch_sharded`` — MeshExecutor default: the whole iteration as
    ONE shard_map program with the non-attention stack BATCH-SHARDED
    (LoongServe §4.2 multi-master — each rank embeds/FFNs/samples B/n
    rows, per-layer boundary all_gather(q-slice) in / psum_scatter of the
    LSE-merged output back to batch shards, sampled ids exchanged and KV
    appends master-routed in-program);
  * ``spmd_overlap`` — the replicated-stack PR 5 program
    (``batch_shard=False``): every layer's LSE-merge is a pmax+psum
    collective with NO barriers (XLA free to schedule it against
    independent compute), but embed/FFN/unembed replicate across ranks;
  * ``spmd_barrier`` — the replicated program with each merge collective
    pinned behind an optimization barrier (the sequential baseline);
  * ``loop``         — the pre-SPMD per-shard Python loop on the same
    per-device mirrors: one eager paged launch per instance per layer with
    explicit q-broadcast / partial-home `device_put` hops.

plus the per-iteration collective payload bytes (trace-time counters in
`kernels.ops`), the structural StableHLO overlap evidence (mirroring the
prefill_spmd methodology — the batch-sharded and overlapped programs carry
ZERO optimization barriers, the barriered one exactly one per layer), and
the compiled dot-FLOP census ratio of the batch-sharded program vs the
replicated one (~1/n).  Writes ``BENCH_decode_spmd.json`` (``_quick``
suffix under --quick).
"""
from __future__ import annotations

import argparse
import json
import os
import time

_DEV_FLAG = "--xla_force_host_platform_device_count=8"
if "--xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    # append, preserving any user-supplied XLA flags (must happen before
    # jax initializes)
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "") + " " + _DEV_FLAG
    ).strip()


def run(quick: bool = False) -> dict:
    import jax
    import numpy as np

    from repro.configs import REGISTRY, reduced
    from repro.engine.executor import MeshExecutor
    from repro.engine.request import Phase, Request
    from repro.engine.server import LoongServeEngine
    from repro.kernels import ops
    from repro.launch.mesh import make_test_mesh
    from repro.manager.scheduler import DecodeBatch
    from repro.models import build_model

    cfg = reduced(REGISTRY["lwm-7b"])
    page = 64
    b = 4 if quick else 16
    iters = 3 if quick else 10
    lo, hi = (64, 256) if quick else (256, 1024)
    rng = np.random.default_rng(0)
    lengths = np.sort(rng.integers(lo, hi + 1, b))
    lengths[0], lengths[-1] = lo, hi  # span guaranteed
    total = int(lengths.sum())
    n_layers = int(cfg.n_attention_applications)

    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    n_dev = len(jax.devices())
    results: dict = {}
    for dop in (2, 4):
        mesh = make_test_mesh(data=dop, model=max(n_dev // dop, 1))

        def build(arm: str):
            capacity = (-(-total // page) + 16) * page  # per instance
            eng = LoongServeEngine(cfg, dop, capacity, store_values=True,
                                   model=model, params=params,
                                   page_size=page, mesh=mesh)
            if arm == "spmd_overlap":
                eng.executor = MeshExecutor(eng, mesh, batch_shard=False)
            elif arm == "spmd_barrier":
                eng.executor = MeshExecutor(eng, mesh, decode_overlap=False,
                                            batch_shard=False)
            elif arm == "loop":
                eng.executor = MeshExecutor(eng, mesh, spmd_decode=False)
            # spmd_batch_sharded: the engine's default MeshExecutor
            # ragged cached KV striped token-granularly across the
            # instances' per-device mirrors, exactly as after prefill
            reqs = []
            for rid, ln in enumerate(lengths):
                n = int(ln)
                r = Request(input_len=n, max_new_tokens=64,
                            prompt=rng.integers(0, cfg.vocab_size, n).tolist())
                r.rid, r.generated, r.phase = rid, 1, Phase.DECODE
                r.output_tokens = [int(rng.integers(0, cfg.vocab_size))]
                plan = eng.pool.plan_placement(rid, list(range(n)), range(dop))
                kv = rng.normal(size=(eng.pool.pools[0].n_attn, n,
                                      cfg.n_kv_heads, cfg.head_dim))
                eng.pool.place(plan, kv, kv + 1)
                reqs.append(r)
            g = DecodeBatch(reqs, list(range(dop)),
                            {r.rid: r.rid % dop for r in reqs})
            # steady state appends one token's KV per request per iteration;
            # model it by re-filling each request's newest cached token so
            # every arm pays its incremental mirror sync
            fills = []
            for r in reqs:
                last = r.seq_len - 2
                inst = next(i for i in range(dop)
                            if last in eng.pool.pools[i].tokens_of(r.rid))
                kv1 = rng.normal(size=(eng.pool.pools[0].n_attn, 1,
                                       cfg.n_kv_heads, cfg.head_dim))
                fills.append((eng.pool.pools[inst], r.rid, last, kv1))
            return eng, g, fills

        def program_text(eng, g, compiled=False):
            """StableHLO (or compiled HLO) of the engine's decode program;
            the paged impl must be the model's attn impl during (re)trace."""
            fn, args, _ = eng.executor._decode_spmd_setup(g)
            prev = eng.model.attn_impl
            eng.model.attn_impl = eng.executor._paged_impl
            try:
                low = fn.lower(*args)
                return low.compile().as_text() if compiled else low.as_text()
            finally:
                eng.model.attn_impl = prev

        arm_res: dict = {}
        hlo: dict = {}
        flops: dict = {}
        for arm in ("spmd_batch_sharded", "spmd_overlap", "spmd_barrier",
                    "loop"):
            eng, g, fills = build(arm)
            ops.reset_dispatch_counts()
            eng._real_decode_paged(g)  # warmup: compile (counts trace)
            d = dict(ops.dispatch_counts)
            comm = dict(ops.comm_bytes)
            if arm == "spmd_batch_sharded":
                assert d.get("decode_merge_loop", 0) == 0, d
                assert d.get("decode_iteration_spmd", 0) == 1, d
                assert d.get("paged_decode_sharded", 0) == n_layers, d
                assert d.get("psum_scatter", 0) == n_layers, d
                txt = program_text(eng, g)
                hlo[arm] = {
                    "all_reduces": txt.count("stablehlo.all_reduce"),
                    "reduce_scatters": txt.count("stablehlo.reduce_scatter"),
                    "all_gathers": txt.count("stablehlo.all_gather"),
                    "opt_barriers": txt.count("stablehlo.optimization_barrier"),
                    "dots": txt.count("stablehlo.dot"),
                }
                from repro.launch.hlo import hlo_census

                flops[arm] = hlo_census(program_text(eng, g, compiled=True))[
                    "flops"
                ]
            elif arm.startswith("spmd"):
                assert d.get("decode_merge_loop", 0) == 0, d
                assert d.get("paged_decode_spmd", 0) == n_layers, d
                # structural overlap evidence (StableHLO — the CPU backend
                # runs collectives synchronously after scheduling, so
                # wall-clock cannot show the hiding HERE): the overlapped
                # program has NO optimization barrier anywhere — every
                # per-layer merge all-reduce is schedulable against the
                # stack's independent compute (next layer's weight loads /
                # dots, the new-token partial) — while the barriered
                # program pins each of the n_layers merges.
                txt = program_text(eng, g)
                hlo[arm] = {
                    "all_reduces": txt.count("stablehlo.all_reduce"),
                    "opt_barriers": txt.count("stablehlo.optimization_barrier"),
                    "dots": txt.count("stablehlo.dot"),
                }
                if arm == "spmd_overlap":
                    from repro.launch.hlo import hlo_census

                    flops[arm] = hlo_census(
                        program_text(eng, g, compiled=True)
                    )["flops"]
            else:
                assert d.get("decode_merge_loop", 0) == dop * n_layers, d
                assert comm.get("decode_q_broadcast", 0) > 0, comm
                assert comm.get("decode_partial_home", 0) > 0, comm
            best = float("inf")
            for _ in range(iters):
                for pool, rid, pos, kv1 in fills:
                    pool.fill(rid, [pos], kv1, kv1)
                t0 = time.perf_counter()
                eng._real_decode_paged(g)
                best = min(best, time.perf_counter() - t0)
            arm_res[arm] = {
                # a decode iteration emits one token per request
                "tok_s": float(b / best),
                "s_per_iter": best,
                "dispatches_per_trace": d,
                "collective_bytes_per_iter": {
                    k: comm.get(k, 0)
                    for k in ("psum", "pmax", "psum_scatter", "all_gather",
                              "decode_q_broadcast", "decode_partial_home")
                    if comm.get(k, 0)
                },
            }
        assert hlo["spmd_batch_sharded"]["opt_barriers"] == 0, hlo
        assert hlo["spmd_overlap"]["opt_barriers"] == 0, hlo
        assert hlo["spmd_barrier"]["opt_barriers"] == n_layers, hlo
        # replicated arms: every layer's merge is collective — >= 2
        # all-reduces (pmax + the weighted-accumulator psum) per layer,
        # identical across the overlap/barrier pair.  The batch-sharded
        # program swaps the psum for a reduce_scatter and adds the q-slice
        # gather per layer (plus the token/KV exchanges at the end).
        assert hlo["spmd_overlap"]["all_reduces"] >= 2 * n_layers, hlo
        assert (hlo["spmd_overlap"]["all_reduces"]
                == hlo["spmd_barrier"]["all_reduces"]), hlo
        assert hlo["spmd_batch_sharded"]["reduce_scatters"] >= n_layers, hlo
        assert hlo["spmd_batch_sharded"]["all_gathers"] >= n_layers, hlo
        results[f"dop{dop}"] = {
            **arm_res,
            "overlap_vs_barrier_speedup": (
                arm_res["spmd_barrier"]["s_per_iter"]
                / arm_res["spmd_overlap"]["s_per_iter"]
            ),
            "loop_vs_spmd_speedup": (
                arm_res["loop"]["s_per_iter"]
                / arm_res["spmd_overlap"]["s_per_iter"]
            ),
            "batch_vs_replicated_speedup": (
                arm_res["spmd_overlap"]["s_per_iter"]
                / arm_res["spmd_batch_sharded"]["s_per_iter"]
            ),
            # per-rank dot FLOPs, batch-sharded / replicated (~1/dop)
            "flop_census_ratio": (
                flops["spmd_batch_sharded"] / flops["spmd_overlap"]
            ),
            "decode_hlo": hlo,
        }
    out = {
        "batch": b,
        "page_size": page,
        "n_layers": n_layers,
        "lengths": [int(x) for x in lengths],
        "total_cached_tokens": total,
        "n_devices": n_dev,
        # XLA:CPU executes all-reduce synchronously inside each device's
        # thunk sequence, so the overlapped ordering cannot beat the
        # barriered one in wall-clock HERE; `decode_hlo` proves the overlap
        # is structurally enabled (no barrier between the merge collective
        # and the rest of the stack) — the hiding itself needs async ICI
        # (TPU).
        "collectives_synchronous_on_cpu": True,
        **results,
    }
    path = ("BENCH_decode_spmd_quick.json" if quick
            else "BENCH_decode_spmd.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=2)
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    out = run(quick=args.quick)
    rows = []
    for dop in (2, 4):
        r = out[f"dop{dop}"]
        rows.append(
            f"dop{dop}_batch:{r['spmd_batch_sharded']['tok_s']:.1f}tok/s;"
            f"dop{dop}_spmd:{r['spmd_overlap']['tok_s']:.1f}tok/s;"
            f"dop{dop}_batch_vs_rep:{r['batch_vs_replicated_speedup']:.2f}x;"
            f"dop{dop}_flop_ratio:{r['flop_census_ratio']:.3f};"
            f"dop{dop}_vs_loop:{r['loop_vs_spmd_speedup']:.2f}x;"
            f"dop{dop}_ov_vs_bar:{r['overlap_vs_barrier_speedup']:.2f}x;"
            f"dop{dop}_scatter_bytes:"
            f"{r['spmd_batch_sharded']['collective_bytes_per_iter'].get('psum_scatter', 0)};"
            f"dop{dop}_overlap_hlo:"
            f"{r['decode_hlo']['spmd_batch_sharded']['opt_barriers'] == 0}"
        )
    print(
        f"decode_spmd,"
        f"{out['dop2']['spmd_batch_sharded']['s_per_iter'] * 1e6:.1f},"
        + ";".join(rows))


if __name__ == "__main__":
    main()

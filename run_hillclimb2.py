import json
from repro.launch.dryrun import run_cell
results = []
for chunk in (128, 256, 512):
    results.append(run_cell("xlstm-350m", "prefill_32k", options={"ssm_chunk": chunk}))
results.append(run_cell("xlstm-350m", "prefill_32k",
                        options={"ssm_chunk": 256, "exclude_scope": "mlstm_chunk_body"}))
json.dump(results, open("dryrun_hillclimb2.json", "w"), indent=1)
print("HILLCLIMB2 DONE")

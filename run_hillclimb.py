import json, sys
from repro.launch.dryrun import run_cell
from repro.launch import sharding as shlib

results = []
# ---- Cell A: glm4-9b x prefill_32k (paper-representative) ----
results.append(run_cell("glm4-9b", "prefill_32k", options={"kernel_adjusted": True}))
results.append(run_cell("glm4-9b", "prefill_32k", options={"ring_slice_tp": True}))
results.append(run_cell("glm4-9b", "prefill_32k",
                        options={"ring_slice_tp": True, "kernel_adjusted": True}))
# ---- Cell B: xlstm-350m x prefill_32k (worst roofline fraction) ----
for chunk in (128, 256, 512):
    results.append(run_cell("xlstm-350m", "prefill_32k", options={"ssm_chunk": chunk}))
results.append(run_cell("xlstm-350m", "prefill_32k",
                        options={"ssm_chunk": 256, "exclude_scope": "mlstm_chunk_body"}))
# ---- Cell C: arctic-480b x prefill_32k (most collective-bound) ----
shlib.MOE_GROUP_C_OVER_DATA = True
results.append(run_cell("arctic-480b", "prefill_32k",
                        options={"moe_c_over_data": True}))
shlib.MOE_GROUP_C_OVER_DATA = False
json.dump(results, open("dryrun_hillclimb.json", "w"), indent=1)
print("HILLCLIMB DONE")

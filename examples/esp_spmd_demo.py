"""ESP SPMD demo: the serving engine running through the MESH EXECUTOR on 8
host devices — the DoP>1 packed ring prefill as a real shard_map program
(each elastic instance physically owns its KV stripe on its own device,
stripes rotating via ppermute, double-buffered against chunk compute),
followed by batch-sharded SPMD multi-master paged decode: one shard_map
program per iteration over the per-device pool mirrors, each rank running
the non-attention stack for only its B/n batch slice, each layer's
LSE-merge an all_gather(q) + pmax + psum_scatter schedule, sampled tokens
exchanged and KV appends routed in-program — validated token-for-token
against the serial dense oracle.

  PYTHONPATH=src python examples/esp_spmd_demo.py
(sets XLA_FLAGS itself — run as a fresh process)
"""
import os
import pathlib
import sys

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
sys.path.insert(0, str(pathlib.Path(__file__).parent.parent / "src"))

import jax
import numpy as np

from repro.configs import REGISTRY, reduced
from repro.engine.request import Phase, Request
from repro.engine.server import LoongServeEngine
from repro.kernels import ops
from repro.launch.mesh import make_test_mesh
from repro.manager.scheduler import PrefillBatch
from repro.models import build_model

DOP = 4
N_DECODE = 3


def main():
    cfg = reduced(REGISTRY["lwm-7b"])
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    mesh = make_test_mesh(data=DOP, model=8 // DOP)
    eng = LoongServeEngine(cfg, DOP, 4000, store_values=True, model=model,
                           params=params, page_size=16, mesh=mesh)
    print(f"executor: {type(eng.executor).__name__} on mesh "
          f"{dict(mesh.shape)}; per-instance mirror devices: "
          f"{[str(p.device) for p in eng.pool.pools]}")

    # one DoP=4 ESP prefill batch with scheduler-reserved striped placement
    rng = np.random.default_rng(23)
    reqs, placement = [], {}
    for j, ln in enumerate([65, 17, 120, 48, 33, 80]):
        r = Request(input_len=ln, max_new_tokens=N_DECODE + 1,
                    prompt=rng.integers(0, cfg.vocab_size, ln).tolist())
        r.rid, r.phase = j, Phase.PREFILL
        plan = eng.pool.plan_placement(r.rid, list(range(ln)), range(DOP))
        eng.pool.place(plan)  # reserve slots; the ring pass fills the values
        placement[r.rid] = plan.assignment
        reqs.append(r)
    batch = PrefillBatch(reqs, list(range(DOP)),
                         scale_down_to=list(range(DOP)), placement=placement)
    for pool in eng.pool.pools:  # pre-create mirrors to expose the invariant
        pool.device_kv()
        pool.mirror_uploaded_slots = 0

    ops.reset_dispatch_counts()
    eng._on_prefill_done(batch)  # shard_map ring prefill + decode transition
    d = dict(ops.dispatch_counts)
    assert d.get("prefill_serial_model", 0) == 0, d
    assert d.get("prefill_ring_replay", 0) == 0, d
    assert d.get("prefill_ring_spmd", 0) >= 1, d
    legs = d.get("ring_ppermute", 0)
    print(f"ring prefill: {d.get('prefill_ring_chunk', 0)} chunk folds, "
          f"{legs} ppermute legs/trace, "
          f"{ops.comm_bytes.get('ring_ppermute', 0) // max(legs, 1)} "
          f"bytes/leg; zero serial + zero in-process replay")
    uploads = sum(p.mirror_uploaded_slots for p in eng.pool.pools)
    assert uploads == 0, uploads
    print("write-through: 0 mirror slots re-uploaded (KV landed on each "
          "instance's own device during the ring pass)")

    ops.reset_dispatch_counts()
    eng._push(eng.clock, "join", 0)  # kick the scheduler; decode to finish
    m = eng.run()
    assert len(m.finished) == len(reqs)
    d = dict(ops.dispatch_counts)
    assert d.get("decode_merge_loop", 0) == 0, d  # no per-shard Python loop
    assert d.get("decode_iteration_spmd", 0) >= 1, d
    assert d.get("paged_decode_sharded", 0) >= 1, d
    assert d.get("psum_scatter", 0) >= 1, d
    print(f"spmd decode: {d.get('paged_decode_sharded', 0)} batch-sharded "
          f"LSE-merges/trace "
          f"({ops.comm_bytes.get('psum_scatter', 0)} psum_scatter + "
          f"{ops.comm_bytes.get('all_gather', 0)} all_gather bytes), "
          "zero per-shard loop merges")

    # token-exact vs the serial dense oracle (prefill + N_DECODE decodes)
    from repro.kernels.ref import serial_decode_oracle

    for r in reqs:
        want = serial_decode_oracle(model, params, r.prompt, N_DECODE)
        assert want == r.output_tokens, (r.rid, want, r.output_tokens)
    print(f"token parity: {len(reqs)} requests x {N_DECODE + 1} tokens "
          "== serial dense oracle")
    print("OK")


if __name__ == "__main__":
    main()

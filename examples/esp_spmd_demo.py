"""ESP SPMD demo: the striped ring prefill + multi-master decode running as
real shard_map programs on 8 host devices, validated against the dense oracle.

  PYTHONPATH=src python examples/esp_spmd_demo.py
(sets XLA_FLAGS itself — run as a fresh process)
"""
import os
import pathlib
import sys

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
sys.path.insert(0, str(pathlib.Path(__file__).parent.parent / "src"))

import jax
import jax.numpy as jnp

from repro.configs import REGISTRY, reduced
from repro.core import striped
from repro.core.esp import ESPAttnImpl
from repro.models import attention as A
from repro.models.transformer import DefaultAttnImpl


def main():
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    cfg = reduced(REGISTRY["glm4-9b"], n_kv_heads=2, n_heads=4, d_head=16)
    impl = ESPAttnImpl(mesh, cfg)
    B, S, H, KVH, D = 2, 64, 4, 2, 16
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (B, S, H, D))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, KVH, D))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, KVH, D))

    # --- striped ring prefill ---
    ref = A.full_attention(q, k, v, causal=True)
    n = 4
    pos = striped.striped_positions(S, n)
    with mesh:
        out = jax.jit(
            lambda q, k, v: impl.prefill_attn(
                q, k, v, pos, pos, causal=True, window=None, softcap=None
            )
        )(striped.stripe(q, n), striped.stripe(k, n), striped.stripe(v, n))
    err = float(jnp.max(jnp.abs(striped.unstripe(out, n) - ref)))
    print(f"striped ring prefill vs dense oracle: max err {err:.2e}")

    # --- multi-master decode ---
    Bd, Sc = 8, 128
    qd = jax.random.normal(key, (Bd, 1, H, D))
    kc = jax.random.normal(jax.random.PRNGKey(3), (Bd, Sc, KVH, D))
    vc = jax.random.normal(jax.random.PRNGKey(4), (Bd, Sc, KVH, D))
    kn = jax.random.normal(jax.random.PRNGKey(5), (Bd, 1, KVH, D))
    vn = jax.random.normal(jax.random.PRNGKey(6), (Bd, 1, KVH, D))
    lens = jnp.arange(1, Bd + 1, dtype=jnp.int32) * 13 % Sc
    refd = DefaultAttnImpl().decode_attn(qd, kc, vc, kn, vn, lens,
                                         window=None, softcap=None)
    with mesh:
        outd = jax.jit(
            lambda *a: impl.decode_attn(*a, window=None, softcap=None)
        )(qd, kc, vc, kn, vn, lens)
    errd = float(jnp.max(jnp.abs(outd - refd)))
    print(f"multi-master decode vs oracle:        max err {errd:.2e}")
    assert err < 1e-5 and errd < 1e-5
    print("OK")


if __name__ == "__main__":
    main()

"""Quickstart: serve a reduced LWM model end-to-end with LoongServe.

Real compute on CPU: requests flow pending -> ESP prefill (proactive
scale-down places KV tokens across instance pools with ZERO migration) ->
multi-master decode -> finished, generating real tokens.

  PYTHONPATH=src python examples/quickstart.py
"""
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).parent.parent / "src"))

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.data import poisson_workload, with_prompts
from repro.engine.server import LoongServeEngine
from repro.models import build_model


def main():
    cfg = reduced(get_config("lwm-7b"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    eng = LoongServeEngine(
        cfg, n_instances=4, capacity_per_instance=2048,
        store_values=True, model=model, params=params,
    )
    reqs = poisson_workload("sharegpt", 8, rate=2.0, seed=1, max_len=120)
    for r in reqs:
        r.max_new_tokens = min(r.max_new_tokens, 12)
    with_prompts(reqs, cfg.vocab_size, seed=2)
    for r in reqs:
        eng.submit(r)

    metrics = eng.run()
    print("== LoongServe quickstart ==")
    for k, v in metrics.summary().items():
        print(f"  {k:28s} {v}")
    print("\nScaling-migration bytes (ESP zero-overhead invariant):",
          metrics.scaling_migration_bytes)
    for r in metrics.finished[:3]:
        print(f"  r{r.rid}: in={r.input_len} -> out {r.output_tokens}")
    assert metrics.scaling_migration_bytes == 0
    assert len(metrics.finished) == len(reqs)
    print("OK")


if __name__ == "__main__":
    main()

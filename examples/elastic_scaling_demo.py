"""Elastic scaling + fault tolerance demo.

1. Proactive scale-down: a long prefill's KV lands directly in the shrunken
   target group's pools (zero migration bytes).
2. Multi-master scale-up: decode group grows with no KV movement.
3. Failure: an instance dies mid-decode; affected requests recompute and
   still finish (elasticity as the recovery mechanism).
4. Checkpoint/restore of the full serving state.

  PYTHONPATH=src python examples/elastic_scaling_demo.py
"""
import pathlib
import sys
import tempfile

sys.path.insert(0, str(pathlib.Path(__file__).parent.parent / "src"))

from repro.configs import get_config
from repro.engine.request import Request
from repro.engine.server import LoongServeEngine


def main():
    cfg = get_config("lwm-7b")
    eng = LoongServeEngine(cfg, 8, 300_000)

    # 1+2: long request -> prefill at high DoP, decode scaled down
    long_req = Request(input_len=200_000, max_new_tokens=64, arrival=0.0)
    short = [Request(input_len=2_000, max_new_tokens=64, arrival=0.01 * i)
             for i in range(6)]
    for r in [long_req] + short:
        eng.submit(r)

    # 3: kill an instance mid-flight, bring it back later
    eng.fail_instance(2, at=5.0)
    eng.join_instance(2, at=30.0)

    # 4: checkpoint after some progress, restore into a fresh engine
    eng.run(max_time=10.0)
    with tempfile.NamedTemporaryFile(suffix=".ckpt", delete=False) as f:
        path = f.name
    eng.checkpoint(path)
    eng2 = LoongServeEngine(cfg, 8, 300_000)
    eng2.restore(path)
    m = eng2.run()

    print("== elastic scaling + fault tolerance demo ==")
    for k, v in m.summary().items():
        print(f"  {k:28s} {v}")
    evicted = sum(r.n_evictions for r in m.finished)
    print(f"  recomputed-after-failure requests: {evicted}")
    assert m.scaling_migration_bytes == 0, "ESP transitions must be zero-copy"
    assert len(m.finished) == 7, [r.phase for r in m.finished]
    print("OK — all requests finished despite the instance failure")


if __name__ == "__main__":
    main()

"""Fig.10-style comparison: LoongServe vs vLLM-TP vs chunked prefill vs
PD-disaggregation on the four paper workloads (SIB-clock simulation).

  PYTHONPATH=src python examples/compare_systems.py [--n 80]
"""
import argparse
import copy
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).parent.parent / "src"))

from repro.configs import get_config
from repro.data import poisson_workload
from repro.launch.serve import build_engine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=80)
    args = ap.parse_args()
    cfg = get_config("lwm-7b")
    CAP = 250_000
    systems = ["loongserve", "vllm-tp", "chunked", "pd-disagg"]
    for ds, rate in [("sharegpt", 4.0), ("leval", 0.5), ("lveval", 0.15),
                     ("mixed", 0.5)]:
        reqs = poisson_workload(ds, args.n, rate, seed=7)
        print(f"=== {ds} (rate {rate}) ===")
        base_e2e = None
        for name in systems:
            eng = build_engine(name, cfg, 8, CAP)
            for r in copy.deepcopy(reqs):
                eng.submit(r)
            m = eng.run().summary()
            e2e = m.get("norm_e2e_mean", float("nan"))
            if name == "loongserve":
                base_e2e = e2e
            speedup = (e2e / base_e2e) if base_e2e else float("nan")
            print(
                f"  {name:12s} e2e={e2e:.5f} in={m.get('norm_input_mean', 0):.5f} "
                f"out={m.get('norm_output_mean', 0):.5f} fin={m.get('n_finished')} "
                f"mig={m.get('reactive_migration_bytes', 0)/1e9:.1f}GB "
                f"(loongserve is {speedup:.2f}x better)"
            )


if __name__ == "__main__":
    main()

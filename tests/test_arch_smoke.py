"""Per-assigned-architecture smoke tests: reduced same-family config, one
forward + one train step + prefill/decode consistency on CPU; asserts output
shapes and no NaNs (the FULL configs are exercised only via the dry-run)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, REGISTRY, reduced
from repro.launch import steps as steps_lib
from repro.models import build_model


def _batch_for(cfg, b, t, key):
    rng = np.random.default_rng(int(key))
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, t)), jnp.int32)}
    extra = 0
    if cfg.frontend == "patch_stub":
        batch["patch_embeds"] = jnp.asarray(
            rng.normal(size=(b, cfg.n_frontend_tokens, cfg.d_model)) * 0.05,
            jnp.dtype(cfg.dtype))
        extra = cfg.n_frontend_tokens
    if cfg.frontend == "audio_stub":
        batch["frames"] = jnp.asarray(
            rng.normal(size=(b, cfg.encoder_seq, cfg.d_model)) * 0.05,
            jnp.dtype(cfg.dtype))
    return batch, extra


@pytest.mark.parametrize("arch", ASSIGNED)
def test_forward_shapes_no_nan(arch):
    cfg = reduced(REGISTRY[arch])
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    b, t = 2, 33
    batch, extra = _batch_for(cfg, b, t, 1)
    logits, aux = model.forward(params, batch)
    assert logits.shape == (b, t + extra, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any())
    assert not bool(jnp.isnan(aux).any())


@pytest.mark.parametrize("arch", ASSIGNED)
def test_prefill_decode_matches_forward(arch):
    cfg = reduced(REGISTRY[arch])
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    b, t = 2, 17
    rng = np.random.default_rng(2)
    toks = rng.integers(0, cfg.vocab_size, (b, t + 1))
    batch, extra = _batch_for(cfg, b, t, 3)
    batch["tokens"] = jnp.asarray(toks[:, :t], jnp.int32)
    full = dict(batch)
    full["tokens"] = jnp.asarray(toks, jnp.int32)
    logits_full, _ = model.forward(params, full)

    logits_pre, cache = model.prefill(params, batch)
    if cache.k is not None:
        pad_to = t + extra + 4
        k_pad = jnp.zeros(
            (cache.k.shape[0], b, pad_to) + cache.k.shape[3:], cache.k.dtype
        ).at[:, :, : t + extra].set(cache.k)
        v_pad = jnp.zeros_like(k_pad).at[:, :, : t + extra].set(cache.v)
        cache = cache._replace(k=k_pad, v=v_pad)
    logits_dec, _, _ = model.decode(params, jnp.asarray(toks[:, t], jnp.int32), cache)
    scale = float(jnp.max(jnp.abs(logits_full[:, -1]))) + 1.0
    err = float(jnp.max(jnp.abs(logits_dec - logits_full[:, -1])))
    assert err < 3e-3 * scale, (arch, err, scale)


@pytest.mark.parametrize("arch", ASSIGNED)
def test_one_train_step(arch):
    cfg = reduced(REGISTRY[arch])
    model, train_step = steps_lib.make_train_step(
        cfg, None, remat=False, loss_chunk=32
    )
    params = model.init(jax.random.PRNGKey(0))
    opt = steps_lib.init_opt_state(params)
    b, t = 2, 32
    rng = np.random.default_rng(4)
    batch, extra = _batch_for(cfg, b, t, 5)
    labels = rng.integers(0, cfg.vocab_size, (b, t + extra))
    if extra:
        labels[:, :extra] = -1
    batch["labels"] = jnp.asarray(labels, jnp.int32)
    new_params, new_opt, m = jax.jit(train_step)(params, opt, batch)
    assert np.isfinite(float(m["loss"]))
    assert np.isfinite(float(m["grad_norm"])) and float(m["grad_norm"]) > 0
    # params actually changed
    delta = sum(
        float(jnp.sum(jnp.abs(a.astype(jnp.float32) - b_.astype(jnp.float32))))
        for a, b_ in zip(jax.tree.leaves(params), jax.tree.leaves(new_params))
    )
    assert delta > 0

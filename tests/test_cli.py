"""CLI drivers: serve.py / train.py / dryrun.py entry points."""
import json
import os
import pathlib
import subprocess
import sys

ROOT = pathlib.Path(__file__).parent.parent


def _run(args, timeout=900, extra_env=None):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    if extra_env:
        env.update(extra_env)
    return subprocess.run(
        [sys.executable, "-m", *args], env=env, capture_output=True,
        text=True, timeout=timeout, cwd=ROOT,
    )


def test_serve_cli_sim():
    out = _run(["repro.launch.serve", "--dataset", "sharegpt", "--rate", "2",
                "--n", "12", "--json"])
    assert out.returncode == 0, out.stderr
    data = json.loads(out.stdout[out.stdout.index("{"):])
    assert data["n_finished"] == 12
    assert data["scaling_migration_bytes"] == 0


def test_serve_cli_baseline():
    out = _run(["repro.launch.serve", "--system", "pd-disagg",
                "--dataset", "sharegpt", "--rate", "2", "--n", "8", "--json"])
    assert out.returncode == 0, out.stderr


def test_train_cli_loss_decreases():
    out = _run(["repro.launch.train", "--arch", "lwm-7b", "--steps", "6",
                "--batch", "2", "--seq", "64"])
    assert out.returncode == 0, out.stdout + out.stderr  # rc!=0 => loss rose


def test_train_cli_grad_compression():
    out = _run(["repro.launch.train", "--arch", "lwm-7b", "--steps", "4",
                "--batch", "2", "--seq", "48", "--grad-compression", "int8"])
    assert out.returncode == 0, out.stdout + out.stderr

"""Elastic fault recovery: KV salvage + scale-down resume (ISSUE 10).

Covers the salvage primitives (sparse coverage maps, mid-stripe position
insertion with KV permutation, salvage planning), the engine recovery
path (mid-chain instance failure at DoP 2 and 4 with bit-for-bit oracle
parity and per-request recompute bounded by the lost stripe), decode-phase
salvage accounting in sim mode, the `salvage_ratio` metric, deterministic
backoff jitter, the invariant-checker sampling knob, and checkpoint /
restore while a unified chain is in flight (resume, not restart)."""
import copy

import jax
import numpy as np
import pytest

from repro.configs import REGISTRY, reduced
from repro.engine.invariants import InvariantChecker
from repro.engine.request import Phase, Request
from repro.engine.server import EngineMetrics, LoongServeEngine
from repro.kernels import ops
from repro.kernels import ref as kref
from repro.kvcache.distributed import DistributedKVPool
from repro.kvcache.pool import KVPool
from repro.manager.scheduler import ManagerConfig
from repro.models import build_model

CFG = reduced(REGISTRY["lwm-7b"])


@pytest.fixture(scope="module")
def model_params():
    model = build_model(CFG)
    params = model.init(jax.random.PRNGKey(0))
    return model, params


# ----------------------------------------------------------- pool primitives
def _pos_coded(pool, positions):
    """KV whose every column encodes its global position (k[., j] == pos)."""
    shape = (pool.n_attn, len(positions)) + pool.k.shape[2:]
    k = np.broadcast_to(
        np.asarray(positions, np.float32)[None, :, None, None], shape
    ).copy()
    return k, -k


def test_insert_positions_restores_local_order_and_kv():
    pool = KVPool(CFG, 64, 0, True, 1)
    lo, hole, hi = [0, 1, 2], [3, 4, 5, 6], [7, 8, 9]
    for part in (lo, hi):
        pool.write(5, part, *_pos_coded(pool, part))
    # the hole PRECEDES already-held positions: plain alloc would append it
    # after `hi` and break the position-ascending local order
    slots = pool.insert_positions(5, hole)
    assert len(slots) == len(hole)
    assert np.array_equal(pool.positions_of(5), np.arange(10))
    # local order really is position-ascending again (prefix_block_table
    # asserts it internally for every prefix limit)
    for lim in (3, 5, 10):
        pool.prefix_block_table([5], np.array([lim]))
    # surviving KV moved WITH its positions during the permutation; the
    # inserted slots are reserved-but-empty, filled like any placement
    pool.fill(5, hole, *_pos_coded(pool, hole))
    positions, k, _ = pool.gather(5)
    assert np.array_equal(positions, np.arange(10))
    assert np.array_equal(k[0, :, 0, 0], np.arange(10, dtype=np.float32))


def test_insert_positions_append_fast_path():
    pool = KVPool(CFG, 64, 0, False, 1)
    pool.alloc(7, [0, 1, 2])
    pool.insert_positions(7, [3, 4])  # strictly above max_pos: plain append
    assert np.array_equal(pool.positions_of(7), np.arange(5))
    assert pool.insert_positions(7, []) == []


def test_salvage_placement_inventory_and_replacement():
    pool = DistributedKVPool(CFG, 3, 64, store_values=False)
    for i in range(3):  # contiguous stripes: inst i holds [10i, 10i+10)
        pool.pools[i].alloc(5, range(10 * i, 10 * i + 10))
    plan = pool.salvage_placement(5, 30, failed={1})
    assert plan.lost_spans == [(10, 20)]
    assert plan.n_salvaged == 20 and plan.n_lost == 10
    assert set(plan.coverage) == {0, 2}
    assert np.array_equal(plan.coverage[0], np.arange(10))
    # re-reserve the dead stripe on the survivors -> full coverage again
    repl = pool.plan_placement(5, list(range(10, 20)), [0, 2])
    pool.place_salvage(repl)
    cov = pool.coverage_map(5, failed={1})
    assert np.array_equal(
        np.sort(np.concatenate(list(cov.values()))), np.arange(30)
    )
    for inst, pos in cov.items():  # every leg stays locally sorted
        assert np.array_equal(pos, np.sort(pos))


def test_salvage_placement_interleaved_stripes():
    pool = DistributedKVPool(CFG, 2, 64, store_values=False)
    pool.pools[0].alloc(9, range(0, 12, 2))   # even positions
    pool.pools[1].alloc(9, range(1, 12, 2))   # odd positions
    plan = pool.salvage_placement(9, 12, failed={0})
    assert plan.lost_spans == [(p, p + 1) for p in range(0, 12, 2)]
    assert plan.n_salvaged == 6 and plan.n_lost == 6
    # no failure -> nothing lost
    assert pool.salvage_placement(9, 12, failed=set()).lost_spans == []


# ------------------------------------------------- engine recovery, real mode
def _salvage_workload(rng, n_short=3, long_len=240):
    reqs = []
    for _ in range(n_short):
        ln = int(rng.integers(20, 30))
        reqs.append(Request(
            input_len=ln, max_new_tokens=8, arrival=0.0,
            prompt=rng.integers(0, CFG.vocab_size, ln).tolist(),
        ))
    reqs.append(Request(
        input_len=long_len, max_new_tokens=4, arrival=0.03,
        prompt=rng.integers(0, CFG.vocab_size, long_len).tolist(),
    ))
    return reqs


# (group DoP, engine instances, per-instance capacity, long prompt).  The
# long prompt exceeds (dop-1) instances' capacity, so the proactive
# scale-down placement MUST stripe it over `dop` instances; the engine is
# larger than the group so the survivors + bystanders can absorb a lost
# stripe's re-reservation.
_TOPOLOGIES = [(2, 3, 220, 300), (4, 6, 170, 560)]


@pytest.mark.parametrize("dop,n,cap,long_len", _TOPOLOGIES)
def test_mid_chain_failure_salvage_parity(model_params, dop, n, cap, long_len):
    """Single-instance failure mid-unified-chain at DoP 2 / 4: survivors'
    KV is salvaged, each salvaged request recomputes at most its lost
    stripe (strictly less than seq_len), final tokens are bit-for-bit the
    no-failure serial oracle, and the sanitizer stays green throughout."""
    model, params = model_params
    rng = np.random.default_rng(29)
    reqs = _salvage_workload(rng, long_len=long_len)
    eng = LoongServeEngine(
        CFG, n, cap, store_values=True, model=model, params=params,
        mcfg=ManagerConfig(prefill_chunk_tokens=48),
    )
    chk = InvariantChecker(eng)
    chk.arm()
    rs = copy.deepcopy(reqs)
    for r in rs:
        eng.submit(r)
    long_r = rs[-1]
    # run until the long prompt is striped over `dop` instances and deep
    # enough into its chain that EVERY stripe holds computed tokens (so a
    # failure of any holder leaves salvageable survivor KV), with the next
    # link in flight (failure lands mid-chain)
    guard = 0
    while not (
        long_r.phase is Phase.PREFILL
        and long_r.prefill_pos >= int(0.8 * long_len)
        and len(eng.pool.request_instances(long_r.rid)) >= dop
        and any(e[2] == "unified_done" for e in eng.events)
    ):
        assert eng.events and guard < 2000, "never reached a striped mid-chain"
        eng.run(max_events=1)
        guard += 1
    victim = eng.pool.request_instances(long_r.rid)[0]
    held = {
        rid: len(eng.pool.pools[victim].tokens_of(rid))
        for rid in eng.pool.pools[victim].requests()
    }
    eng.fail_instance(victim)
    eng.run(max_events=1)  # the fail event is next (pushed at eng.clock)
    rec = dict(eng._recovering)
    assert long_r.rid in rec, "mid-chain failure did not salvage the chain"
    for rid, st in rec.items():
        lost = sum(e - s for s, e in st.spans)
        assert lost <= held.get(rid, 0), (rid, st.spans, held)
        assert st.salvaged > 0
    m = eng.run()
    assert len(m.finished) == len(rs)
    assert eng.metrics.salvaged_tokens > 0
    assert eng.metrics.recomputed_tokens < sum(r.seq_len for r in rs)
    assert not eng._recovering  # exact coverage again at completion
    assert chk.leaked_slots() == 0
    assert eng.pool.total_used == 0
    for orig, r in zip(reqs, rs):  # originals: folding mutates rs prompts
        want = kref.serial_decode_oracle(
            model, params, orig.prompt, orig.max_new_tokens - 1
        )
        assert want == r.output_tokens, (dop, r.rid, want, r.output_tokens)


def test_decode_phase_salvage_sim_accounting():
    """Failure during decode: the whole prefix {0..seq_len-2} minus the
    dead stripe is salvaged, the request resumes decode after the hole
    re-prefills, and the accounting splits salvaged vs recomputed."""
    # per-instance capacity (100) < input_len (150): the token-granularity
    # placement MUST stripe each request across instances, so a failure
    # always leaves salvageable survivor shards
    eng = LoongServeEngine(CFG, 3, 100)
    reqs = [
        Request(input_len=150, max_new_tokens=10, arrival=0.0)
        for _ in range(2)
    ]
    for r in reqs:
        eng.submit(r)
    guard = 0
    while not any(
        r.phase is Phase.DECODE and r.generated >= 2 for r in reqs
    ):
        assert eng.events and guard < 800, "no request reached decode"
        eng.run(max_events=1)
        guard += 1
    victim_req = next(
        r for r in reqs if r.phase is Phase.DECODE and r.generated >= 2
    )
    insts = eng.pool.request_instances(victim_req.rid)
    assert len(insts) >= 2, insts  # striped: survivors will hold shards
    victim = next(i for i in insts if i not in eng.failed)
    survivors_hold = sum(
        len(p)
        for i, p in eng.pool.coverage_map(victim_req.rid, {victim}).items()
    )
    victim_holds = len(eng.pool.pools[victim].tokens_of(victim_req.rid))
    eng.fail_instance(victim)
    eng.run(max_events=1)
    rec = eng._recovering.get(victim_req.rid)
    assert rec is not None and rec.resume_decode
    assert rec.expected == rec.salvaged + sum(e - s for s, e in rec.spans)
    assert eng.metrics.salvaged_tokens >= survivors_hold
    assert eng.metrics.recomputed_tokens <= victim_holds
    m = eng.run()
    assert len(m.finished) == len(reqs)
    assert all(r.generated == r.max_new_tokens for r in reqs)
    assert eng.pool.total_used == 0
    snap = eng.metrics.snapshot()
    assert snap["salvage_ratio"] > 0


# --------------------------------------------------------- metrics & knobs
def test_metrics_snapshot_salvage_ratio():
    m = EngineMetrics()
    assert m.snapshot()["salvage_ratio"] == 0.0  # no faults: defined as 0
    m.salvaged_tokens, m.recomputed_tokens = 30, 10
    assert m.snapshot()["salvage_ratio"] == pytest.approx(0.75)
    assert m.summary()["salvaged_tokens"] == 30
    assert "salvage_ratio" not in m.summary()  # ratio is snapshot-only


def test_backoff_jitter_deterministic_per_seed():
    a, b = (LoongServeEngine(CFG, 2, 500, seed=5) for _ in range(2))
    sa = [a._backoff_rng.random() for _ in range(16)]
    assert sa == [b._backoff_rng.random() for _ in range(16)]
    assert all(0.0 <= x < 1.0 for x in sa)  # jitter factor is 0.5 + this
    c = LoongServeEngine(CFG, 2, 500, seed=6)
    assert [c._backoff_rng.random() for _ in range(16)] != sa
    # the jitter stream is SEPARATE from the sim token stream: draining it
    # must not shift the tokens a same-seed engine generates
    assert a.rng.random() == b.rng.random()


def test_invariant_checker_sampling_knob():
    with pytest.raises(AssertionError):
        InvariantChecker(LoongServeEngine(CFG, 2, 1000), check_every_n=0)
    eng = LoongServeEngine(CFG, 2, 2000)
    full = InvariantChecker(eng)
    sampled = InvariantChecker(eng, check_every_n=7)
    full.arm()
    sampled.arm()
    for _ in range(3):
        eng.submit(Request(input_len=40, max_new_tokens=6, arrival=0.0))
    eng.run()
    assert full.checks > 7  # default: after every handled event
    assert sampled.checks == full.checks // 7  # same event stream, sampled
    # manual checks are never sampled
    before = sampled.checks
    sampled.check()
    assert sampled.checks == before + 1


# ------------------------------------------------ checkpoint mid-chain resume
def test_checkpoint_restore_mid_unified_chain_resumes(model_params, tmp_path):
    """Checkpoint while a unified chain is in flight: the chunk cursors and
    the `_active_unified` registry round-trip, and the restored engine
    RESUMES the chain at its cursor (dispatching only the remaining spans)
    with oracle token parity."""
    model, params = model_params
    rng = np.random.default_rng(31)
    reqs = _salvage_workload(rng, n_short=2, long_len=200)
    mk = lambda: LoongServeEngine(
        CFG, 2, 600, store_values=True, model=model, params=params,
        mcfg=ManagerConfig(prefill_chunk_tokens=32),
    )
    eng = mk()
    rs = copy.deepcopy(reqs)
    for r in rs:
        eng.submit(r)
    long_r = rs[-1]
    guard = 0
    while not (
        long_r.phase is Phase.PREFILL
        and 0 < long_r.prefill_pos < long_r.input_len
        and any(e[2] == "unified_done" for e in eng.events)
    ):
        assert eng.events and guard < 1000, "never caught the chain mid-link"
        eng.run(max_events=1)
        guard += 1
    cursor = long_r.prefill_pos
    path = str(tmp_path / "mid_chain.ckpt")
    eng.checkpoint(path)

    eng2 = mk()
    eng2.restore(path)
    assert eng2._active_unified, "in-flight chain registry did not round-trip"
    r2 = eng2._req_index[long_r.rid]
    assert r2.prefill_pos == cursor  # chunk cursor survived the round-trip
    assert any(e[2] == "unified_done" for e in eng2.events)
    ops.reset_dispatch_counts()
    m = eng2.run()
    assert len(m.finished) == len(rs)
    # resume, not restart: everything already prefilled before the
    # checkpoint is NOT re-dispatched (the in-flight link and all later
    # ones are; `cursor` tokens of the long prompt are not)
    total_input = sum(r.input_len for r in rs)
    assert ops.dispatch_counts["unified_prefill_tokens"] <= total_input - cursor
    for orig, r in zip(reqs, (eng2._req_index[x.rid] for x in rs)):
        want = kref.serial_decode_oracle(
            model, params, orig.prompt, orig.max_new_tokens - 1
        )
        assert want == r.output_tokens, (r.rid, want, r.output_tokens)

"""Packed ragged prefill: numerical parity with the per-request reference
(mixed lengths, GQA, sliding window, softcap), model-level packed vs serial
prefill equivalence, pool `fill_packed` write-through (zero mirror re-upload
before the first decode), bucketed compile counts, and the failure-path
satellites (graceful in-flight decode on instance failure, duplicate-free
KV placement order)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import REGISTRY, reduced
from repro.engine.request import Phase, Request
from repro.engine.server import LoongServeEngine
from repro.kernels import ops
from repro.manager.scheduler import DecodeBatch, PrefillBatch
from repro.models import attention as A
from repro.models import build_model

CFG = reduced(REGISTRY["lwm-7b"])


def _packed_case(seed, lens, h, kvh, d, bucket):
    rng = np.random.default_rng(seed)
    total = sum(lens)
    assert total <= bucket
    off = np.full(len(lens) + 1, total, np.int32)
    off[0] = 0
    c = 0
    for i, n in enumerate(lens):
        c += n
        off[i + 1] = c
    q = rng.normal(size=(bucket, h, d)).astype(np.float32)
    k = rng.normal(size=(bucket, kvh, d)).astype(np.float32)
    v = rng.normal(size=(bucket, kvh, d)).astype(np.float32)
    return q, k, v, off


@pytest.mark.parametrize("impl", ["xla", "interpret"])
@pytest.mark.parametrize("window,softcap", [(None, None), (7, None), (None, 5.0)])
def test_packed_prefill_matches_per_request_reference(impl, window, softcap):
    """One packed ragged launch == per-request full_attention on every
    segment, for mixed lengths (incl. length-1) under GQA, sliding window
    and logit softcap; bucket padding rows never leak into real rows."""
    lens = [5, 1, 17, 9, 12]
    h, kvh, d = 4, 2, 32
    q, k, v, off = _packed_case(0, lens, h, kvh, d, bucket=64)
    kw = dict(block_q=16, block_k=16)
    if impl == "xla":
        kw["max_seq_len"] = 32  # force a banded (not full-reach) fallback
    out = np.asarray(ops.prefill_packed(
        jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), jnp.asarray(off),
        window=window, softcap=softcap, impl=impl, **kw,
    ))
    c = 0
    for n in lens:
        ref = np.asarray(A.full_attention(
            jnp.asarray(q[None, c : c + n]), jnp.asarray(k[None, c : c + n]),
            jnp.asarray(v[None, c : c + n]), causal=True, window=window,
            softcap=softcap,
        ))[0]
        np.testing.assert_allclose(out[c : c + n], ref, atol=2e-5)
        c += n


def test_banded_fallback_matches_dense_oracle():
    """The production banded XLA fallback equals the O(T^2) dense oracle for
    every band width, including bands narrower than the packed axis."""
    from repro.kernels import ref as kref

    lens = [3, 11, 8, 2]
    q, k, v, off = _packed_case(1, lens, 4, 2, 16, bucket=32)
    dense = np.asarray(kref.packed_prefill_ref(
        jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), jnp.asarray(off),
    ))
    for max_len in (11, 16, 32, None):
        banded = np.asarray(kref.packed_prefill_banded(
            jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), jnp.asarray(off),
            block_q=8, max_seq_len=max_len,
        ))
        np.testing.assert_allclose(banded, dense, atol=2e-5)


def test_model_prefill_packed_matches_serial_prefill():
    """Model-level: one packed step reproduces per-request model.prefill —
    last-token logits AND the packed per-layer KV output."""
    from repro.core.paged_prefill import PackedPrefillAttnImpl

    model = build_model(CFG)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(3)
    lens = [19, 7, 33]
    prompts = [rng.integers(0, CFG.vocab_size, n).tolist() for n in lens]
    total = sum(lens)
    bucket = 64
    tokens = np.zeros(bucket, np.int32)
    positions = np.zeros(bucket, np.int32)
    off = np.full(len(lens) + 1, total, np.int32)
    off[0] = 0
    last = np.zeros(len(lens), np.int32)
    c = 0
    for i, p in enumerate(prompts):
        tokens[c : c + lens[i]] = p
        positions[c : c + lens[i]] = np.arange(lens[i])
        c += lens[i]
        off[i + 1] = c
        last[i] = c - 1
    impl = PackedPrefillAttnImpl()
    prev = model.attn_impl
    model.attn_impl = impl
    impl.begin_step(jnp.asarray(off), max_seq_len=64)
    try:
        logits, (kp, vp) = model.prefill_packed(
            params, {"tokens": jnp.asarray(tokens)[None]},
            jnp.asarray(positions), jnp.asarray(last),
        )
    finally:
        impl.end_step()
        model.attn_impl = prev
    logits = np.asarray(logits)
    kp, vp = np.asarray(kp), np.asarray(vp)
    c = 0
    for i, p in enumerate(prompts):
        ref_logits, cache = model.prefill(
            params, {"tokens": jnp.asarray(np.asarray(p, np.int32)[None])}
        )
        np.testing.assert_allclose(
            logits[i], np.asarray(ref_logits[0, -1]), atol=1e-4
        )
        np.testing.assert_allclose(
            kp[:, c : c + lens[i]], np.asarray(cache.k[:, 0]), atol=1e-4
        )
        np.testing.assert_allclose(
            vp[:, c : c + lens[i]], np.asarray(cache.v[:, 0]), atol=1e-4
        )
        c += lens[i]


def _prefill_batch(eng, rng, lengths, rid0=0):
    """Reserve striped placement + build a PrefillBatch, as the scheduler's
    proactive scale-down does before prefill executes."""
    n_inst = len(eng.pool.pools)
    reqs, placement = [], {}
    for j, ln in enumerate(lengths):
        n = int(ln)
        r = Request(input_len=n, max_new_tokens=8,
                    prompt=rng.integers(0, eng.cfg.vocab_size, n).tolist())
        r.rid, r.phase = rid0 + j, Phase.PREFILL
        plan = eng.pool.plan_placement(r.rid, list(range(n)), range(n_inst))
        eng.pool.place(plan)
        placement[r.rid] = plan.assignment
        reqs.append(r)
    return PrefillBatch(reqs, list(range(n_inst)),
                        scale_down_to=list(range(n_inst)),
                        placement=placement)


def test_fill_packed_write_through_zero_reupload():
    """After a packed prefill NO slot is dirty and the first decode-style
    mirror sync uploads ZERO slots — the write-through already updated the
    device mirror in place.  The host management copy is LAZY: the prefill
    critical path downloads nothing (slots stale, host_syncs == 0); the
    first management-plane read (gather) pulls them from the mirror once."""
    model = build_model(CFG)
    params = model.init(jax.random.PRNGKey(0))
    eng = LoongServeEngine(CFG, 2, 1024, store_values=True, model=model,
                           params=params, page_size=16)
    rng = np.random.default_rng(5)
    batch = _prefill_batch(eng, rng, [24, 61, 9, 40])
    eng._real_prefill(batch)
    for pool in eng.pool.pools:
        # dirty-tracking counters: nothing pending for the next sync
        assert pool.dirty_slot_count() == 0
        # lazy host copy: the critical path downloaded nothing
        assert pool.stale_host_slot_count() > 0
        assert pool.host_syncs == 0
        uploads_before = pool.mirror_uploaded_slots
        fulls_before = pool.mirror_full_syncs
        kd, vd, pd = pool.device_kv()  # first decode iteration's sync
        assert pool.mirror_uploaded_slots == uploads_before
        assert pool.mirror_full_syncs == fulls_before
        np.testing.assert_array_equal(np.asarray(pd), pool.slot_pos)
    # host copy materializes each request's prefill KV on demand (gather)
    for r in batch.requests:
        pos, k, _ = eng.pool.gather_request(r.rid)
        assert len(pos) == r.input_len
        assert float(np.abs(k).sum()) > 0.0
    for pool in eng.pool.pools:
        assert pool.host_syncs == 1  # one forced sync, then clean
        assert pool.stale_host_slot_count() == 0
        kd, vd, pd = pool.device_kv()
        # the mirror and the (now synced) host management copy agree
        np.testing.assert_allclose(np.asarray(kd), pool.k, atol=1e-6)
        np.testing.assert_allclose(np.asarray(vd), pool.v, atol=1e-6)


def test_engine_end_to_end_packed_prefill_matches_oracle():
    """Real-mode engine with simultaneous arrivals (a true multi-request
    packed batch): exactly one packed program compiles per bucket shape, the
    packed kernel is dispatched, and generated tokens match the per-request
    dense oracle."""
    model = build_model(CFG)
    params = model.init(jax.random.PRNGKey(0))
    eng = LoongServeEngine(CFG, 2, 4000, store_values=True, model=model,
                           params=params, page_size=16)
    rng = np.random.default_rng(7)
    reqs = []
    for _ in range(4):
        ln = int(rng.integers(16, 80))
        r = Request(input_len=ln, max_new_tokens=4, arrival=0.0,
                    prompt=rng.integers(0, CFG.vocab_size, ln).tolist())
        reqs.append(r)
        eng.submit(r)
    ops.reset_dispatch_counts()
    m = eng.run()
    assert len(m.finished) == len(reqs)
    assert m.scaling_migration_bytes == 0
    assert ops.dispatch_counts["prefill_packed"] > 0  # traced packed kernel
    assert len(eng._prefill_programs) >= 1
    for r in reqs:
        toks = jnp.asarray(np.asarray(r.prompt)[None], jnp.int32)
        logits, cache = model.prefill(params, {"tokens": toks})
        nxt = int(np.argmax(np.asarray(logits[0, -1])))
        out = [nxt]
        S = r.input_len + 8
        k_pad = jnp.zeros((cache.k.shape[0], 1, S) + cache.k.shape[3:],
                          cache.k.dtype).at[:, :, : r.input_len].set(cache.k)
        v_pad = jnp.zeros_like(k_pad).at[:, :, : r.input_len].set(cache.v)
        cache = cache._replace(k=k_pad, v=v_pad)
        for _ in range(3):
            logits, cache, kvs = model.decode(
                params, jnp.asarray([nxt], jnp.int32), cache
            )
            pos = int(cache.length[0]) - 1
            cache = cache._replace(
                k=cache.k.at[:, :, pos : pos + 1].set(kvs[0]),
                v=cache.v.at[:, :, pos : pos + 1].set(kvs[1]),
            )
            nxt = int(np.argmax(np.asarray(logits[0])))
            out.append(nxt)
        assert out == r.output_tokens, (r.rid, out, r.output_tokens)


def test_inflight_instance_failure_graceful_real_decode():
    """A fail_instance landing between a decode launch and its decode_done
    must not trip the KV-coverage assert: affected requests are re-queued
    for recompute (emitted tokens folded into the prompt) and every request
    still finishes."""
    model = build_model(CFG)
    params = model.init(jax.random.PRNGKey(0))
    eng = LoongServeEngine(CFG, 3, 4000, store_values=True, model=model,
                           params=params, page_size=8)
    rng = np.random.default_rng(11)
    reqs = []
    for _ in range(5):
        ln = int(rng.integers(16, 64))
        r = Request(input_len=ln, max_new_tokens=5, arrival=0.0,
                    prompt=rng.integers(0, CFG.vocab_size, ln).tolist())
        reqs.append(r)
        eng.submit(r)
    # step events until a decode iteration is in flight, then fail one of
    # its instances NOW (clock < the pending decode_done's timestamp)
    guard = 0
    while not any(e[2] == "decode_done" for e in eng.events):
        assert eng.events and guard < 500, "no decode launched"
        eng.run(max_events=1)
        guard += 1
    g = next(e[3] for e in eng.events if e[2] == "decode_done")
    victim = next(
        i for i in g.instances
        if any(eng.pool.pools[i].tokens_of(r.rid) for r in g.requests)
    )
    eng.fail_instance(victim)
    m = eng.run()
    assert len(m.finished) == len(reqs)
    assert all(r.generated >= r.max_new_tokens for r in reqs)
    assert any(r.n_evictions > 0 for r in reqs)  # somebody was requeued


def test_inflight_instance_failure_graceful_real_prefill():
    """A fail_instance landing between a prefill launch and its prefill_done
    must not crash the packed KV scatter (the requeued requests' reserved
    slots are gone): stale requests are dropped from the batch and every
    request still finishes via recompute."""
    model = build_model(CFG)
    params = model.init(jax.random.PRNGKey(0))
    eng = LoongServeEngine(CFG, 3, 4000, store_values=True, model=model,
                           params=params, page_size=8)
    rng = np.random.default_rng(13)
    reqs = []
    for _ in range(4):
        ln = int(rng.integers(24, 64))
        r = Request(input_len=ln, max_new_tokens=3, arrival=0.0,
                    prompt=rng.integers(0, CFG.vocab_size, ln).tolist())
        reqs.append(r)
        eng.submit(r)
    guard = 0
    while not any(e[2] == "prefill_done" for e in eng.events):
        assert eng.events and guard < 500, "no prefill launched"
        eng.run(max_events=1)
        guard += 1
    b = next(e[3] for e in eng.events if e[2] == "prefill_done")
    victim = next(
        i for i in range(3)
        if any(eng.pool.pools[i].tokens_of(r.rid) for r in b.requests)
    )
    eng.fail_instance(victim)
    m = eng.run()
    assert len(m.finished) == len(reqs)
    assert any(r.n_evictions > 0 for r in reqs)


def test_stale_decode_done_after_recompute_is_skipped():
    """A decode_done whose request was requeued by a failure AND already
    recomputed into a fresh group (phase back to DECODE, seq_len moved past
    the launch-time stamp) must be ignored — processing it would emit a
    duplicate token and double-allocate the same KV position."""
    eng = LoongServeEngine(CFG, 2, 1000)
    r = Request(input_len=8, max_new_tokens=4)
    r.phase = Phase.DECODE
    r.generated = 1
    g = DecodeBatch([r], [0], {r.rid: 0})
    eng._decode_launch_seq[id(g)] = {r.rid: r.seq_len}  # as _execute_plan does
    # in-flight failure: requeue folds the emitted token into the prompt...
    eng._requeue_for_recompute(r)
    assert r.seq_len == 9 and r.generated == 0
    # ...and the recompute prefill completes before the stale decode_done
    r.phase = Phase.DECODE
    r.generated = 1  # prefill_done's first-token emission -> seq moved to 10
    eng._on_decode_done(g)
    assert r.generated == 1  # NOT bumped by the stale completion
    assert eng.pool.request_tokens(r.rid) == 0  # no KV allocated by it
    # control: a matching stamp processes normally
    g2 = DecodeBatch([r], [0], {r.rid: 0})
    eng._decode_launch_seq[id(g2)] = {r.rid: r.seq_len}
    eng._on_decode_done(g2)
    assert r.generated == 2
    assert eng.pool.request_tokens(r.rid) == 1


def test_placement_order_master_first_no_duplicates():
    """KV-append probe order: master first, then the group, then other live
    instances — each exactly once, even when the rid is missing from
    `g.masters` (regression: g.instances[0] used to appear twice) and with
    failed instances excluded."""
    eng = LoongServeEngine(CFG, 5, 1000)
    r = Request(input_len=4, max_new_tokens=2)
    g = DecodeBatch([r], instances=[2, 0, 3], masters={})  # rid missing
    order = eng._placement_order(r, g)
    assert order[0] == 2  # default master = g.instances[0]
    assert sorted(order) == [0, 1, 2, 3, 4]  # every instance exactly once
    assert order[:3] == [2, 0, 3]  # group preference preserved
    g2 = DecodeBatch([r], instances=[2, 0, 3], masters={r.rid: 3})
    order2 = eng._placement_order(r, g2)
    assert order2[0] == 3 and sorted(order2) == [0, 1, 2, 3, 4]
    eng.failed.add(0)
    assert 0 not in eng._placement_order(r, g2)

"""SPMD equivalence tests: run in subprocesses with a multi-device host
platform (the main pytest process keeps the default single device)."""
import os
import pathlib
import subprocess
import sys

import pytest

ROOT = pathlib.Path(__file__).parent.parent


def _run(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = str(ROOT / "src")
    out = subprocess.run(
        [sys.executable, "-c", code], env=env, capture_output=True, text=True,
        timeout=1200,
    )
    assert out.returncode == 0, out.stdout + "\n" + out.stderr
    return out.stdout


def test_esp_spmd_demo_runs():
    """Ring prefill + multi-master decode == dense oracle on an 8-dev mesh."""
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    out = subprocess.run(
        [sys.executable, str(ROOT / "examples" / "esp_spmd_demo.py")],
        env=env, capture_output=True, text=True, timeout=1200,
    )
    assert out.returncode == 0, out.stdout + "\n" + out.stderr
    assert "OK" in out.stdout


def test_sp_recurrent_protocols():
    code = """
import jax, jax.numpy as jnp
from repro.core import ssm_sp
from repro.models import ssm, xlstm
from repro.configs import REGISTRY, reduced
mesh = jax.make_mesh((4, 2), ("data", "model"))
key = jax.random.PRNGKey(0)
cfg = reduced(REGISTRY["zamba2-2.7b"])
p = ssm.init_mamba2(key, cfg.d_model, expand=cfg.ssm_expand, head_dim=cfg.ssm_head_dim,
                    state=cfg.ssm_state, conv_width=cfg.ssm_conv_width, dtype=jnp.float32)
B, S = 2, 128
x = jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model)) * 0.1
y_ref, st_ref = ssm.mamba2_forward(p, x, cfg, None)
with mesh:
    y_sp, st_sp = jax.jit(lambda x, p: ssm_sp.mamba2_forward_sp(mesh, "data", p, x, cfg, None, tp="model"))(x, p)
assert float(jnp.max(jnp.abs(y_sp - y_ref))) < 1e-4
assert float(jnp.max(jnp.abs(st_sp.h - st_ref.h))) < 1e-4
cfgx = reduced(REGISTRY["xlstm-350m"])
px = xlstm.init_mlstm(key, cfgx, jnp.float32)
x2 = jax.random.normal(jax.random.PRNGKey(2), (B, S, cfgx.d_model)) * 0.1
y_ref2, _ = xlstm.mlstm_block_forward(px, x2, cfgx, None, chunk=16)
with mesh:
    y_sp2, _ = jax.jit(lambda x, p: ssm_sp.mlstm_forward_sp(mesh, "data", p, x, cfgx, None, tp="model"))(x2, px)
assert float(jnp.max(jnp.abs(y_sp2 - y_ref2))) < 1e-4
ps = xlstm.init_slstm(key, cfgx, jnp.float32)
y_ref3, _ = xlstm.slstm_block_forward(ps, x2, cfgx, None)
with mesh:
    y_sp3, _ = jax.jit(lambda x, p: ssm_sp.slstm_forward_sp(mesh, "data", p, x, cfgx, None, tp="model"))(x2, ps)
assert float(jnp.max(jnp.abs(y_sp3 - y_ref3))) < 1e-4
print("SP-RECURRENT-OK")
"""
    assert "SP-RECURRENT-OK" in _run(code)


def test_esp_dop_subgroups():
    """Elastic DoP: rings confined to subgroups of the sp axis (two ESP
    groups sharing one mesh) still match the dense oracle per group."""
    code = """
import jax, jax.numpy as jnp
from repro.core.esp import ESPAttnImpl
from repro.core import striped
from repro.models import attention as A
from repro.configs import REGISTRY, reduced
mesh = jax.make_mesh((4, 2), ("data", "model"))
cfg = reduced(REGISTRY["lwm-7b"], n_heads=4, n_kv_heads=4, d_head=16)
impl = ESPAttnImpl(mesh, cfg, dop=2)  # two DoP-2 groups on the 4-wide axis
B, S, H, D = 2, 64, 4, 16
key = jax.random.PRNGKey(0)
q = jax.random.normal(key, (B, S, H, D))
k = jax.random.normal(jax.random.PRNGKey(1), (B, S, H, D))
v = jax.random.normal(jax.random.PRNGKey(2), (B, S, H, D))
# each group handles half the sequence as an independent request segment
n, g = 4, 2
half = S // 2
pos_parts = []
qs, ks_, vs = [], [], []
for gi in range(2):
    sl = slice(gi * half, (gi + 1) * half)
    pos_parts.append(striped.striped_positions(half, g))
    qs.append(striped.stripe(q[:, sl], g)); ks_.append(striped.stripe(k[:, sl], g)); vs.append(striped.stripe(v[:, sl], g))
pos = jnp.concatenate(pos_parts)
qq, kk, vv = (jnp.concatenate(t, axis=1) for t in (qs, ks_, vs))
with mesh:
    out = jax.jit(lambda q, k, v: impl.prefill_attn(q, k, v, pos, pos, causal=True, window=None, softcap=None))(qq, kk, vv)
for gi in range(2):
    sl = slice(gi * half, (gi + 1) * half)
    ref = A.full_attention(q[:, sl], k[:, sl], v[:, sl], causal=True)
    got = striped.unstripe(out[:, sl], g)
    err = float(jnp.max(jnp.abs(got - ref)))
    assert err < 1e-5, (gi, err)
print("DOP-GROUPS-OK")
"""
    assert "DOP-GROUPS-OK" in _run(code)


def test_hlo_census_flops_exact():
    code = """
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.launch.hlo import hlo_census
mesh = jax.make_mesh((4, 2), ("data", "model"))
def f(x, w):
    def body(c, wl):
        h = c @ wl
        h = jax.lax.with_sharding_constraint(h, NamedSharding(mesh, P("data", "model")))
        return h @ wl.T, None
    y, _ = jax.lax.scan(body, x, w)
    return y.sum()
x = jax.ShapeDtypeStruct((8, 64), jnp.float32)
w = jax.ShapeDtypeStruct((3, 64, 64), jnp.float32)
with mesh:
    compiled = jax.jit(f, in_shardings=(NamedSharding(mesh, P("data", None)), NamedSharding(mesh, P()))).lower(x, w).compile()
c = hlo_census(compiled.as_text())
assert c["flops"] == 49152.0, c  # 3 layers x 2 dots x 2*2*64*32, trip-expanded
assert c["collective_bytes"] > 0
print("CENSUS-OK")
"""
    assert "CENSUS-OK" in _run(code)

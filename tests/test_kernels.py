"""Per-kernel validation: shape/dtype sweeps, interpret mode vs ref oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import striped as st
from repro.kernels import ops, ref


def _rand(key, shape, dtype):
    x = jax.random.normal(key, shape, jnp.float32)
    return x.astype(dtype)


TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,s,h,kvh,d,bq,bk",
    [
        (1, 128, 4, 4, 64, 64, 64),  # MHA
        (2, 256, 8, 2, 64, 128, 128),  # GQA
        (2, 192, 6, 2, 32, 64, 64),  # non-pow2 heads, odd blocks
        (1, 128, 4, 1, 128, 128, 32),  # MQA
    ],
)
def test_striped_attention_kernel_sweep(dtype, b, s, h, kvh, d, bq, bk):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = _rand(ks[0], (b, s, h, d), dtype)
    k = _rand(ks[1], (b, s, kvh, d), dtype)
    v = _rand(ks[2], (b, s, kvh, d), dtype)
    pos = st.striped_positions(s, 4)
    out_k = ops.attention(q, k, v, pos, pos, causal=True,
                          impl="interpret", block_q=bq, block_k=bk)
    out_r = ops.attention(q, k, v, pos, pos, causal=True, impl="xla")
    np.testing.assert_allclose(
        np.asarray(out_k, np.float32), np.asarray(out_r, np.float32),
        atol=TOL[dtype], rtol=TOL[dtype],
    )


@pytest.mark.parametrize("window", [None, 64])
@pytest.mark.parametrize("causal", [True, False])
def test_striped_attention_masks(window, causal):
    b, s, h, kvh, d = 2, 128, 4, 2, 32
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q, k, v = (_rand(ks[i], (b, s, h if i == 0 else kvh, d), jnp.float32)
               for i in range(3))
    pos = st.striped_positions(s, 8)
    out_k = ops.attention(q, k, v, pos, pos, causal=causal, window=window,
                          impl="interpret", block_q=32, block_k=32)
    out_r = ops.attention(q, k, v, pos, pos, causal=causal, window=window,
                          impl="xla")
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r), atol=2e-5)


def test_striped_attention_softcap():
    b, s, h, d = 1, 64, 2, 32
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q, k, v = (_rand(ks[i], (b, s, h, d), jnp.float32) for i in range(3))
    pos = jnp.arange(s)
    out_k = ops.attention(q, k, v, pos, pos, softcap=20.0, impl="interpret",
                          block_q=32, block_k=32)
    out_r = ops.attention(q, k, v, pos, pos, softcap=20.0, impl="xla")
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r), atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,s,h,kvh,d,bk,off,win",
    [
        (2, 128, 4, 4, 64, 64, 0, None),
        (4, 256, 8, 2, 64, 128, 0, None),
        (2, 128, 4, 2, 32, 32, 128, None),  # offset shard
        (2, 256, 8, 2, 64, 64, 0, 64),  # SWA
        (1, 64, 4, 1, 128, 64, 64, 32),  # MQA + offset + window
    ],
)
def test_flash_decode_kernel_sweep(dtype, b, s, h, kvh, d, bk, off, win):
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = _rand(ks[0], (b, 1, h, d), dtype)
    k = _rand(ks[1], (b, s, kvh, d), dtype)
    v = _rand(ks[2], (b, s, kvh, d), dtype)
    lens = jnp.asarray(
        np.random.default_rng(0).integers(0, off + s + 1, b), jnp.int32
    )
    pk = ops.decode_partial(q, k, v, lens, k_pos_offset=off, window=win,
                            impl="interpret", block_k=bk)
    pr = ops.decode_partial(q, k, v, lens, k_pos_offset=off, window=win,
                            impl="xla")
    np.testing.assert_allclose(
        np.asarray(pk.o), np.asarray(pr.o), atol=5e-2 if dtype == jnp.bfloat16 else 1e-4
    )
    np.testing.assert_allclose(
        np.nan_to_num(np.asarray(pk.m), neginf=-1e9),
        np.nan_to_num(np.asarray(pr.m), neginf=-1e9), atol=1e-2,
    )
    np.testing.assert_allclose(np.asarray(pk.l), np.asarray(pr.l),
                               rtol=2e-2, atol=1e-4)


@pytest.mark.parametrize("window", [1, 2, 32, 64])
def test_window_convention_parity(window):
    """Cross-kernel sliding-window convention at the boundary: a query at
    global position qp attends keys with 0 <= qp - kp < window (self
    inclusive).  The prefill kernel applies it literally; the decode kernel
    sees the cache WITHOUT the query's own KV (query position == lengths) and
    merges the own-token partial — both must select the identical window."""
    from repro.models import attention as A

    b, s, h, kvh, d = 2, 64, 4, 2, 32
    ks = jax.random.split(jax.random.PRNGKey(11), 3)
    q = _rand(ks[0], (b, s, h, d), jnp.float32)
    k = _rand(ks[1], (b, s, kvh, d), jnp.float32)
    v = _rand(ks[2], (b, s, kvh, d), jnp.float32)
    pos = jnp.arange(s)
    # prefill convention: last row of the striped kernel output
    full = ops.attention(q, k, v, pos, pos, causal=True, window=window,
                         impl="interpret", block_q=32, block_k=32)
    last_prefill = np.asarray(full)[:, -1]
    # decode convention: cache = tokens 0..s-2, query's own KV merged apart
    qd = q[:, s - 1 : s]
    lens = jnp.full((b,), s - 1, jnp.int32)
    p_hist = ops.decode_partial(qd, k[:, : s - 1], v[:, : s - 1], lens,
                                window=window, impl="interpret", block_k=21)
    p_own = A.partial_attention(qd, k[:, s - 1 :], v[:, s - 1 :], None)
    last_decode = np.asarray(
        A.finalize_partial(A.merge_partial(p_hist, p_own))
    )[:, 0]
    np.testing.assert_allclose(last_decode, last_prefill, atol=2e-5)


def test_decode_partials_compose_to_full():
    """Sharded decode partials (kernel) merged across shards == full attn."""
    from repro.models import attention as A

    b, s, h, kvh, d = 2, 256, 4, 2, 32
    ks = jax.random.split(jax.random.PRNGKey(4), 3)
    q = _rand(ks[0], (b, 1, h, d), jnp.float32)
    k = _rand(ks[1], (b, s, kvh, d), jnp.float32)
    v = _rand(ks[2], (b, s, kvh, d), jnp.float32)
    lens = jnp.asarray([100, 256], jnp.int32)
    parts = []
    n_shards = 4
    per = s // n_shards
    for i in range(n_shards):
        sl = slice(i * per, (i + 1) * per)
        parts.append(
            ops.decode_partial(q, k[:, sl], v[:, sl], lens,
                               k_pos_offset=i * per, impl="interpret",
                               block_k=32)
        )
    combined = A.combine_partials(parts)
    ref_out = A.decode_attention(q, k, v, lens)
    np.testing.assert_allclose(
        np.asarray(combined, np.float32), np.asarray(ref_out, np.float32),
        atol=2e-5,
    )

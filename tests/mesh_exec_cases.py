"""Mesh-executor test bodies, run in a multi-device subprocess.

`tests/test_mesh_executor.py` launches each case as
``python tests/mesh_exec_cases.py <case>`` with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the main pytest
process keeps the default single device); a case prints ``<CASE>-OK`` on
success.  Kept as plain functions (not pytest tests) so failures surface
full tracebacks through the subprocess assert.
"""
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).parent.parent / "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import REGISTRY, reduced
from repro.core import esp, striped
from repro.engine.request import Phase, Request
from repro.engine.server import LoongServeEngine
from repro.kernels import ops
from repro.kernels import ref as kref
from repro.launch.mesh import make_test_mesh
from repro.manager.scheduler import PrefillBatch
from repro.models import build_model

CFG = reduced(REGISTRY["lwm-7b"])


def _packed_case(seed, lens, h, kvh, d, bucket):
    rng = np.random.default_rng(seed)
    total = sum(lens)
    assert total <= bucket
    off = np.full(len(lens) + 1, total, np.int32)
    off[0] = 0
    c = 0
    for i, n in enumerate(lens):
        c += n
        off[i + 1] = c
    q = rng.normal(size=(bucket, h, d)).astype(np.float32)
    k = rng.normal(size=(bucket, kvh, d)).astype(np.float32)
    v = rng.normal(size=(bucket, kvh, d)).astype(np.float32)
    return q, k, v, off


def case_ring_parity():
    """shard_map ring prefill == dense packed oracle, bit-for-bit at the
    test_ring_prefill tolerance, for DoP {2, 4} x {GQA, sliding window,
    logit softcap} x {double-buffered, sequential}, with a model axis on the
    mesh (attention replicated over it) and without."""
    lens = [5, 1, 17, 9, 12]
    h, kvh, d = 4, 2, 32
    q, k, v, off = _packed_case(0, lens, h, kvh, d, bucket=64)
    total = sum(lens)
    dense = {}
    for window, softcap in [(None, None), (7, None), (None, 5.0)]:
        dense[(window, softcap)] = np.asarray(kref.packed_prefill_ref(
            jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), jnp.asarray(off),
            window=window, softcap=softcap,
        ))
    for dop in (2, 4):
        for model_ax in (1, 2):
            mesh = make_test_mesh(data=dop, model=model_ax)
            for (window, softcap), want in dense.items():
                for db in (True, False):
                    out = np.asarray(jax.jit(
                        lambda q, k, v, o: esp.ring_packed_prefill_spmd(
                            mesh, q, k, v, o, window=window, softcap=softcap,
                            max_seq_len=32, block_q=8, block_k=8,
                            double_buffer=db,
                        )
                    )(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                      jnp.asarray(off)))
                    np.testing.assert_allclose(
                        out[:total], want[:total], atol=2e-5,
                        err_msg=str((dop, model_ax, window, softcap, db)),
                    )
    # provenance closed form == the simulated ppermute schedule
    for n, g in [(2, None), (4, None), (8, 4)]:
        sched = striped.ring_chunk_schedule(n, g)
        for s in range(g or n):
            assert striped.chunk_provenance(n, s, g) == sched[s], (n, g, s)
    print("RING-PARITY-OK")


def _prefill_batch(eng, rng, lengths, rid0=0, max_new=8):
    n_inst = len(eng.pool.pools)
    reqs, placement = [], {}
    for j, ln in enumerate(lengths):
        n = int(ln)
        r = Request(input_len=n, max_new_tokens=max_new,
                    prompt=rng.integers(0, eng.cfg.vocab_size, n).tolist())
        r.rid, r.phase = rid0 + j, Phase.PREFILL
        eng._req_index[r.rid] = r  # what submit() does: the engine must
        # know every rid in the pool (failure requeue + invariants need it)
        plan = eng.pool.plan_placement(r.rid, list(range(n)), range(n_inst))
        eng.pool.place(plan)
        placement[r.rid] = plan.assignment
        reqs.append(r)
    return PrefillBatch(reqs, list(range(n_inst)),
                        scale_down_to=list(range(n_inst)),
                        placement=placement)


def _oracle_tokens(model, params, r, n_decode):
    return kref.serial_decode_oracle(model, params, r.prompt, n_decode)


def case_engine_e2e():
    """Engine through the MeshExecutor at DoP {2, 4}: shard_map ring prefill
    with ZERO serial dispatches and ZERO in-process ring-replay calls, KV
    write-through onto per-instance devices with zero mirror re-uploads,
    paged decode across the per-device mirrors, token sequences == serial
    dense oracle."""
    model = build_model(CFG)
    params = model.init(jax.random.PRNGKey(0))
    for dop in (2, 4):
        mesh = make_test_mesh(data=dop, model=8 // dop)
        eng = LoongServeEngine(CFG, dop, 4000, store_values=True, model=model,
                               params=params, page_size=16, mesh=mesh)
        assert type(eng.executor).__name__ == "MeshExecutor"
        devs = {str(p.device) for p in eng.pool.pools}
        assert len(devs) == dop, devs  # one mirror device per instance
        rng = np.random.default_rng(23 + dop)
        batch = _prefill_batch(eng, rng, [33, 17, 50, 8], max_new=4)
        reqs = list(batch.requests)
        for pool in eng.pool.pools:
            pool.device_kv()
            pool.mirror_uploaded_slots = 0
            pool.mirror_full_syncs = 0
        ops.reset_dispatch_counts()
        eng._on_prefill_done(batch)
        d = dict(ops.dispatch_counts)
        assert d.get("prefill_serial_model", 0) == 0, d
        assert d.get("prefill_ring_replay", 0) == 0, d
        assert d.get("prefill_ring_spmd", 0) >= 1, d
        assert d.get("ring_ppermute", 0) == dop - 1, d  # legs per trace
        assert any(key[3] == dop for key in eng._prefill_programs)
        for pool in eng.pool.pools:
            assert pool.mirror_uploaded_slots == 0  # write-through, in place
            assert pool.mirror_full_syncs == 0
            assert pool.dirty_slot_count() == 0
            assert pool.host_syncs == 0  # critical path stayed device-only
        eng._push(eng.clock, "join", 0)
        m = eng.run()
        assert len(m.finished) == len(reqs)
        assert ops.dispatch_counts.get("prefill_serial_model", 0) == 0
        assert ops.dispatch_counts.get("prefill_ring_replay", 0) == 0
        for r in reqs:
            want = _oracle_tokens(model, params, r, 3)
            assert want == r.output_tokens, (dop, r.rid, want, r.output_tokens)
    print("ENGINE-E2E-OK")


def case_checkpoint_restore():
    """Checkpoint/restore under the sharded mirror: the checkpoint resyncs
    the stale (fill_packed) host slots exactly ONCE per pool (`host_syncs`),
    restore drops every per-shard device mirror, and the restored engine
    finishes decode reproducing the serial-oracle token sequence (mirrors
    rebuilt from the host copy on their own devices)."""
    import tempfile

    model = build_model(CFG)
    params = model.init(jax.random.PRNGKey(0))
    dop = 2
    mesh = make_test_mesh(data=dop, model=8 // dop)

    def fresh():
        return LoongServeEngine(CFG, dop, 4000, store_values=True,
                                model=model, params=params, page_size=16,
                                mesh=mesh)

    eng = fresh()
    rng = np.random.default_rng(29)
    batch = _prefill_batch(eng, rng, [21, 42, 13], max_new=4)
    reqs = list(batch.requests)
    eng._on_prefill_done(batch)  # ring prefill: host copies now stale
    for pool in eng.pool.pools:
        assert pool.stale_host_slot_count() > 0 and pool.host_syncs == 0
    with tempfile.NamedTemporaryFile(suffix=".ckpt") as f:
        eng.checkpoint(f.name)
        for pool in eng.pool.pools:
            # the snapshot pulled each pool's stale slots down exactly once
            assert pool.host_syncs == 1, pool.host_syncs
            assert pool.stale_host_slot_count() == 0
        eng.checkpoint(f.name)  # nothing stale -> no second sync
        for pool in eng.pool.pools:
            assert pool.host_syncs == 1, pool.host_syncs

        eng2 = fresh()
        eng2.restore(f.name)
        for pool in eng2.pool.pools:
            assert pool._mirror is None  # per-shard device_kv dropped
            assert pool.stale_host_slot_count() == 0
            assert pool.device is not None  # binding survives the restore
        # the restored engine owns the request objects from the snapshot
        restored = {r.rid: r for g in eng2.ready_decode for r in g.requests}
        assert set(restored) == {r.rid for r in reqs}
        eng2._push(eng2.clock, "join", 0)
        m = eng2.run()
        assert len(m.finished) == len(reqs)
        for r in reqs:
            want = _oracle_tokens(model, params, r, 3)
            got = restored[r.rid].output_tokens
            assert want == got, (r.rid, want, got)
    print("CHECKPOINT-RESTORE-OK")


def _build_paged_shards(rng, n, lens, kvh, d, page):
    """Distribute each request's cached tokens round-robin over ``n`` shards
    and pack them pool-style (dense local order, exclusive pages).  Returns
    (k_dense, v_dense [B, max(lens), kvh, d]) and the per-shard
    (k_pages, v_pages, table, lengths, pos) tuples, all with COMMON shapes
    across shards (the SPMD operand stacks them on a leading rank axis)."""
    B = len(lens)
    s_max = max(lens)
    k_dense = rng.normal(size=(B, s_max, kvh, d)).astype(np.float32)
    v_dense = rng.normal(size=(B, s_max, kvh, d)).astype(np.float32)
    locs = [
        [np.arange(s, lens[b], n) for b in range(B)] for s in range(n)
    ]
    pages_req = [
        [max(-(-len(p) // page), 0) for p in locs[s]] for s in range(n)
    ]
    n_pages = max(sum(pr) for pr in pages_req) + 1
    max_tbl = max(max(pr) for pr in pages_req) or 1
    shards = []
    for s in range(n):
        kp = np.zeros((n_pages, page, kvh, d), np.float32)
        vp = np.zeros((n_pages, page, kvh, d), np.float32)
        pos = np.full((n_pages, page), -1, np.int32)
        tbl = np.zeros((B, max_tbl), np.int32)
        counts = np.array([len(p) for p in locs[s]], np.int32)
        pg = 0
        for b in range(B):
            npg = pages_req[s][b]
            if npg == 0:
                continue
            tbl[b, :npg] = np.arange(pg, pg + npg)
            c = counts[b]
            flat = np.zeros((npg * page, kvh, d), np.float32)
            flat[:c] = k_dense[b, locs[s][b]]
            kp[pg : pg + npg] = flat.reshape(npg, page, kvh, d)
            flat = np.zeros((npg * page, kvh, d), np.float32)
            flat[:c] = v_dense[b, locs[s][b]]
            vp[pg : pg + npg] = flat.reshape(npg, page, kvh, d)
            fpos = np.full(npg * page, -1, np.int32)
            fpos[:c] = locs[s][b]
            pos[pg : pg + npg] = fpos.reshape(npg, page)
            pg += npg
        shards.append((kp, vp, tbl, counts, pos))
    return k_dense, v_dense, shards


def case_decode_parity():
    """SPMD paged decode (one shard_map region per layer, pmax+psum
    LSE-merge) == dense decode oracle for DoP {2, 4} x {GQA, sliding
    window, logit softcap} x {overlapped, barriered}, on paged shards laid
    out exactly like the pool's (round-robin token split, exclusive pages);
    the new `kernels/ref.py` multi-shard merge oracle agrees too."""
    from jax.sharding import Mesh

    from repro.models.transformer import DefaultAttnImpl

    h, kvh, d, page = 4, 2, 32, 4
    lens = [13, 1, 29, 8, 22]
    B = len(lens)
    rng = np.random.default_rng(3)
    q = rng.normal(size=(B, 1, h, d)).astype(np.float32)
    k_new = rng.normal(size=(B, 1, kvh, d)).astype(np.float32)
    v_new = rng.normal(size=(B, 1, kvh, d)).astype(np.float32)
    cl = jnp.asarray(lens, jnp.int32)
    for dop in (2, 4):
        mesh = Mesh(np.asarray(jax.devices()[:dop]), ("data",))
        k_dense, v_dense, shards = _build_paged_shards(
            rng, dop, lens, kvh, d, page
        )
        k_g = jnp.asarray(np.stack([s[0] for s in shards]))
        v_g = jnp.asarray(np.stack([s[1] for s in shards]))
        tbl_g = jnp.asarray(np.stack([s[2] for s in shards]))
        len_g = jnp.asarray(np.stack([s[3] for s in shards]))
        pos_g = jnp.asarray(np.stack([s[4] for s in shards]))
        for window, softcap in [(None, None), (9, None), (None, 5.0)]:
            want = np.asarray(DefaultAttnImpl().decode_attn(
                jnp.asarray(q), jnp.asarray(k_dense), jnp.asarray(v_dense),
                jnp.asarray(k_new), jnp.asarray(v_new), cl,
                window=window, softcap=softcap,
            ))
            ref_merge = np.asarray(kref.paged_decode_merge_ref(
                jnp.asarray(q), jnp.asarray(k_new), jnp.asarray(v_new),
                [(s[0], s[1], s[2], s[3], s[4]) for s in shards],
                query_pos=cl, window=window, softcap=softcap,
            ))
            np.testing.assert_allclose(
                ref_merge, want, atol=2e-5,
                err_msg=f"merge-ref {(dop, window, softcap)}",
            )
            for overlap in (True, False):
                out = np.asarray(jax.jit(
                    lambda q_, kn, vn, kg, vg, tg, lg, pg, _ov=overlap,
                    _w=window, _sc=softcap: esp.paged_decode_spmd(
                        mesh, q_, kn, vn, cl, kg, vg, tg, lg,
                        pg if _w is not None else None,
                        window=_w, softcap=_sc, overlap=_ov,
                    )
                )(jnp.asarray(q), jnp.asarray(k_new), jnp.asarray(v_new),
                  k_g, v_g, tbl_g, len_g, pos_g))
                np.testing.assert_allclose(
                    out, want, atol=2e-5,
                    err_msg=str((dop, window, softcap, overlap)),
                )
        # static-rank kernel specialization: the interpret-mode Pallas paged
        # kernel dispatched through the per-rank lax.switch INSIDE the
        # shard_map region (no XLA-fallback forcing) stays parity-exact
        want = np.asarray(DefaultAttnImpl().decode_attn(
            jnp.asarray(q), jnp.asarray(k_dense), jnp.asarray(v_dense),
            jnp.asarray(k_new), jnp.asarray(v_new), cl,
            window=None, softcap=None,
        ))
        out = np.asarray(jax.jit(
            lambda q_, kn, vn, kg, vg, tg, lg: esp.paged_decode_spmd(
                mesh, q_, kn, vn, cl, kg, vg, tg, lg, None,
                impl="interpret",
            )
        )(jnp.asarray(q), jnp.asarray(k_new), jnp.asarray(v_new),
          k_g, v_g, tbl_g, len_g))
        np.testing.assert_allclose(
            out, want, atol=2e-5, err_msg=f"interpret-switch dop={dop}"
        )
    print("DECODE-PARITY-OK")


def case_decode_shard_parity():
    """BATCH-SHARDED multi-master decode boundary
    (`esp.paged_decode_attn_sharded`: all_gather(q-slice) in, psum_scatter
    of the LSE-merged output back to batch shards) == dense decode oracle
    for DoP {2, 4} x {GQA, sliding window, logit softcap} x {overlapped,
    barriered}, with q/k_new/v_new physically sharded over the batch axis;
    the plain-jnp batch-sharded ref (`kernels/ref.py`) agrees too, and the
    interpret-mode Pallas kernel through the per-rank switch stays exact."""
    from jax.sharding import Mesh, PartitionSpec as P

    from repro.core.shmap import shmap
    from repro.models.transformer import DefaultAttnImpl

    h, kvh, d, page = 4, 2, 32, 4
    lens = [13, 1, 29, 8, 22, 40, 5, 17]  # B=8: divisible by both DoPs
    B = len(lens)
    rng = np.random.default_rng(5)
    q = jnp.asarray(rng.normal(size=(B, 1, h, d)).astype(np.float32))
    k_new = jnp.asarray(rng.normal(size=(B, 1, kvh, d)).astype(np.float32))
    v_new = jnp.asarray(rng.normal(size=(B, 1, kvh, d)).astype(np.float32))
    cl = jnp.asarray(lens, jnp.int32)
    for dop in (2, 4):
        mesh = Mesh(np.asarray(jax.devices()[:dop]), ("data",))
        k_dense, v_dense, shards = _build_paged_shards(
            rng, dop, lens, kvh, d, page
        )
        k_g = jnp.asarray(np.stack([s[0] for s in shards]))
        v_g = jnp.asarray(np.stack([s[1] for s in shards]))
        tbl_g = jnp.asarray(np.stack([s[2] for s in shards]))
        len_g = jnp.asarray(np.stack([s[3] for s in shards]))
        pos_g = jnp.asarray(np.stack([s[4] for s in shards]))

        def sharded(window, softcap, overlap, impl=None, _dop=dop,
                    _mesh=mesh):
            def body(qb, knb, vnb, kg, vg, tg, lg, pg):
                out = esp.paged_decode_attn_sharded(
                    "data", _dop, qb, knb, vnb, cl,
                    kg[0], vg[0], tg[0], lg[0],
                    pg[0] if window is not None else None,
                    window=window, softcap=softcap, overlap=overlap,
                    impl=impl,
                )
                return out.astype(qb.dtype)

            fn = shmap(
                body, _mesh,
                in_specs=(P("data"),) * 8, out_specs=P("data"),
            )
            return np.asarray(jax.jit(fn)(
                q, k_new, v_new, k_g, v_g, tbl_g, len_g, pos_g
            ))

        for window, softcap in [(None, None), (9, None), (None, 5.0)]:
            want = np.asarray(DefaultAttnImpl().decode_attn(
                q, jnp.asarray(k_dense), jnp.asarray(v_dense),
                k_new, v_new, cl, window=window, softcap=softcap,
            ))
            ref_bs = np.asarray(kref.paged_decode_batch_sharded_ref(
                q, k_new, v_new,
                [(s[0], s[1], s[2], s[3], s[4]) for s in shards],
                query_pos=cl, window=window, softcap=softcap,
            ))
            np.testing.assert_allclose(
                ref_bs, want, atol=2e-5,
                err_msg=f"batch-sharded-ref {(dop, window, softcap)}",
            )
            for overlap in (True, False):
                out = sharded(window, softcap, overlap)
                np.testing.assert_allclose(
                    out, want, atol=2e-5,
                    err_msg=str((dop, window, softcap, overlap)),
                )
        want = np.asarray(DefaultAttnImpl().decode_attn(
            q, jnp.asarray(k_dense), jnp.asarray(v_dense),
            k_new, v_new, cl, window=None, softcap=None,
        ))
        out = sharded(None, None, True, impl="interpret")
        np.testing.assert_allclose(
            out, want, atol=2e-5, err_msg=f"interpret-sharded dop={dop}"
        )
    print("DECODE-SHARD-PARITY-OK")


def case_decode_e2e():
    """Engine decode through the MeshExecutor's SPMD program at DoP {2, 4}:
    ZERO per-shard Python-loop merges (`decode_merge_loop`), distinct
    per-instance mirror devices, token sequences == serial dense oracle —
    for the default BATCH-SHARDED arms (whole iteration in-program: sampled
    ids exchanged by all_gather, LSE-merge psum_scattered back to batch
    shards, both byte-counted), the replicated PR 5 program
    (``batch_shard=False``: pmax+psum merge), and (at DoP 2) the legacy
    per-shard loop with its q-broadcast / partial-home transfers counted."""
    from repro.engine.executor import MeshExecutor

    model = build_model(CFG)
    params = model.init(jax.random.PRNGKey(0))
    lengths = [33, 17, 50, 8]

    def run_engine(dop, arm):
        mesh = make_test_mesh(data=dop, model=8 // dop)
        eng = LoongServeEngine(CFG, dop, 4000, store_values=True,
                               model=model, params=params, page_size=16,
                               mesh=mesh)
        if arm == "barrier":
            eng.executor = MeshExecutor(eng, mesh, decode_overlap=False)
        elif arm == "replicated":
            eng.executor = MeshExecutor(eng, mesh, batch_shard=False)
        elif arm == "loop":
            eng.executor = MeshExecutor(eng, mesh, spmd_decode=False)
        rng = np.random.default_rng(31 + dop)
        batch = _prefill_batch(eng, rng, lengths, max_new=4)
        reqs = list(batch.requests)
        eng._on_prefill_done(batch)
        ops.reset_dispatch_counts()
        eng._push(eng.clock, "join", 0)
        m = eng.run()
        assert len(m.finished) == len(reqs)
        devs = {str(p.device) for p in eng.pool.pools}
        assert len(devs) == dop, devs
        for r in reqs:
            want = _oracle_tokens(model, params, r, 3)
            assert want == r.output_tokens, (
                dop, arm, r.rid, want, r.output_tokens
            )
        return dict(ops.dispatch_counts), dict(ops.comm_bytes)

    for dop in (2, 4):
        # default arms are BATCH-SHARDED: the non-attention stack runs on
        # B/n rows per rank, tokens are sampled in-program, and the decode
        # collectives are the sharded boundary's all_gather/psum_scatter
        for arm in ("overlap", "barrier"):
            d, c = run_engine(dop, arm)
            assert d.get("decode_merge_loop", 0) == 0, (dop, arm, d)
            assert d.get("paged_decode_spmd", 0) == 0, (dop, arm, d)
            assert d.get("decode_iteration_spmd", 0) >= 1, (dop, arm, d)
            assert d.get("paged_decode_sharded", 0) >= 1, (dop, arm, d)
            assert d.get("psum_scatter", 0) >= 1, d
            assert d.get("all_gather", 0) >= 1 and d.get("pmax", 0) >= 1, d
            assert c.get("psum_scatter", 0) > 0, c
            assert c.get("all_gather", 0) > 0, c
        # PR 5 replicated-stack program still exact behind batch_shard=False
        d, c = run_engine(dop, "replicated")
        assert d.get("decode_merge_loop", 0) == 0, (dop, d)
        assert d.get("decode_iteration_spmd", 0) == 0, (dop, d)
        assert d.get("paged_decode_spmd", 0) >= 1, (dop, d)
        assert d.get("psum", 0) >= 1 and d.get("pmax", 0) >= 1, d
        assert c.get("psum", 0) > 0, c
    # pre-SPMD per-shard loop still exact, its decode comm now visible
    d, c = run_engine(2, "loop")
    assert d.get("paged_decode_spmd", 0) == 0, d
    assert d.get("decode_iteration_spmd", 0) == 0, d
    assert d.get("decode_merge_loop", 0) >= 1, d
    assert c.get("decode_q_broadcast", 0) > 0, c
    assert c.get("decode_partial_home", 0) > 0, c
    print("DECODE-E2E-OK")


def case_decode_flops():
    """FLOP-census guard for the whole point of the batch sharding: the
    compiled batch-sharded program's per-rank dot FLOPs
    (`launch/hlo.py` census) are <= 1/n + eps of the replicated PR 5
    program at DoP {2, 4} — the embed/FFN/unembed stack really runs on B/n
    rows per rank, not just logically."""
    from repro.engine.executor import MeshExecutor
    from repro.engine.request import Phase, Request
    from repro.launch.hlo import hlo_census
    from repro.manager.scheduler import DecodeBatch

    model = build_model(CFG)
    params = model.init(jax.random.PRNGKey(0))
    lengths = [33, 17, 50, 8, 21, 44, 12, 60]
    page = 16
    capacity = (-(-sum(lengths) // page) + 16) * page

    def census(dop, batch_shard):
        mesh = make_test_mesh(data=dop, model=8 // dop)
        eng = LoongServeEngine(CFG, dop, capacity, store_values=True,
                               model=model, params=params, page_size=page,
                               mesh=mesh)
        eng.executor = MeshExecutor(eng, mesh, batch_shard=batch_shard)
        rng = np.random.default_rng(41)
        reqs = []
        for rid, ln in enumerate(lengths):
            n = int(ln)
            r = Request(input_len=n, max_new_tokens=8,
                        prompt=rng.integers(0, CFG.vocab_size, n).tolist())
            r.rid, r.generated, r.phase = rid, 1, Phase.DECODE
            r.output_tokens = [int(rng.integers(0, CFG.vocab_size))]
            plan = eng.pool.plan_placement(rid, list(range(n)), range(dop))
            kv = rng.normal(size=(eng.pool.pools[0].n_attn, n,
                                  CFG.n_kv_heads, CFG.head_dim))
            eng.pool.place(plan, kv, kv + 1)
            reqs.append(r)
        g = DecodeBatch(reqs, list(range(dop)),
                        {r.rid: r.rid % dop for r in reqs})
        fn, args, _ = eng.executor._decode_spmd_setup(g)
        prev = eng.model.attn_impl
        eng.model.attn_impl = eng.executor._paged_impl
        try:
            txt = fn.lower(*args).compile().as_text()
        finally:
            eng.model.attn_impl = prev
        return hlo_census(txt)["flops"]

    for dop in (2, 4):
        rep = census(dop, False)
        shd = census(dop, True)
        ratio = shd / rep
        # the paged attention partial is full-B on every rank in BOTH
        # programs (it is already 1/n-sized via the KV sharding), so the
        # ratio sits a couple of percent above the ideal 1/n
        assert ratio <= 1 / dop + 0.05, (dop, rep, shd, ratio)
    print("DECODE-FLOPS-OK")


def case_join_instance():
    """fail_instance mid-decode + join_instance on the real MeshExecutor
    path: KV on the failed instance drops, its requests recompute on the
    survivor, the rejoined instance serves follow-up work on its own mirror
    device, the invariant sanitizer holds after every event, and every
    token sequence (first wave AND post-rejoin wave) matches the serial
    oracle."""
    from repro.engine.invariants import InvariantChecker

    model = build_model(CFG)
    params = model.init(jax.random.PRNGKey(0))
    dop = 2
    mesh = make_test_mesh(data=dop, model=8 // dop)
    eng = LoongServeEngine(CFG, dop, 4000, store_values=True, model=model,
                           params=params, page_size=16, mesh=mesh)
    chk = InvariantChecker(eng)
    chk.arm()
    rng = np.random.default_rng(37)
    batch = _prefill_batch(eng, rng, [33, 17, 26], max_new=4, rid0=100)
    wave1 = list(batch.requests)
    eng._on_prefill_done(batch)
    t_join = eng.clock + 0.5
    eng.fail_instance(1, at=eng.clock)
    eng.join_instance(1, at=t_join)
    # second wave arrives after the rejoin: full scheduling path, both
    # instances (incl. the rejoined one) take prefill + decode work
    wave2 = []
    for i in range(3):
        n = int(rng.integers(16, 40))
        r = Request(input_len=n, max_new_tokens=4, arrival=t_join + 0.1,
                    prompt=rng.integers(0, CFG.vocab_size, n).tolist())
        wave2.append(r)
        eng.submit(r)
    used_after_rejoin = [False]

    def watch(e, kind, payload):
        if e.clock > t_join and e.pool.pools[1].used > 0:
            used_after_rejoin[0] = True

    eng.event_hooks.append(watch)
    # recompute folds emitted tokens into r.prompt — snapshot the ORIGINAL
    # prompts now so the oracle replays what the user actually submitted
    prompts = {r.rid: list(r.prompt) for r in wave1 + wave2}
    m = eng.run()
    assert len(m.finished) == len(wave1) + len(wave2)
    assert not eng.failed
    assert used_after_rejoin[0], "rejoined instance never took work"
    assert eng.pool.pools[1].device is not None  # mirror binding survives
    assert chk.leaked_slots() == 0
    assert eng.pool.total_used == 0
    for r in wave1 + wave2:
        want = kref.serial_decode_oracle(model, params, prompts[r.rid], 3)
        assert want == r.output_tokens, (r.rid, want, r.output_tokens)
    print("JOIN-INSTANCE-OK")


def case_unified():
    """ISSUE-9 unified continuous-batching step on the mesh: (1) engine e2e
    with `prefill_chunk_tokens` set — short prompts decode WHILE a long
    prompt's chunked prefill runs, the fused iteration dispatches as ONE
    shard_map program (`unified_iteration_spmd`), decode rows ride prefill
    iterations, and every token sequence matches the serial dense oracle;
    (2) StableHLO evidence that the interleaved path really is one fused
    program: the compiled unified program contains BOTH the prefill ring's
    collective-permute and the decode merge's reduce-scatter/all-reduce in a
    single module; (3) the switched ring chunk (static per-rank lax.switch
    dispatch, ISSUE-9 satellite) stays parity-exact with the interpret-mode
    Pallas kernel INSIDE the shard_map region."""
    import copy

    from repro.manager.scheduler import ManagerConfig

    model = build_model(CFG)
    params = model.init(jax.random.PRNGKey(0))
    dop = 2
    mesh = make_test_mesh(data=dop, model=8 // dop)
    rng = np.random.default_rng(7)
    reqs = []
    for _ in range(4):
        reqs.append(Request(
            input_len=24, max_new_tokens=24, arrival=0.0,
            prompt=rng.integers(0, CFG.vocab_size, 24).tolist(),
        ))
    reqs.append(Request(
        input_len=300, max_new_tokens=6, arrival=0.01,
        prompt=rng.integers(0, CFG.vocab_size, 300).tolist(),
    ))
    ops.reset_dispatch_counts()
    eng = LoongServeEngine(CFG, dop, 416, store_values=True, model=model,
                           params=params, page_size=16, mesh=mesh,
                           mcfg=ManagerConfig(prefill_chunk_tokens=48))
    assert type(eng.executor).__name__ == "MeshExecutor"
    rs = copy.deepcopy(reqs)
    for r in rs:
        eng.submit(r)
    m = eng.run()
    assert len(m.finished) == len(rs)
    d = dict(ops.dispatch_counts)
    # the fused path really ran as SPMD shard_map programs, with decode
    # rows riding prefill iterations
    assert d.get("unified_iteration_spmd", 0) >= 1, d
    assert d.get("unified_step", 0) >= 1, d
    assert d.get("unified_decode_tokens", 0) > 0, d
    assert d.get("unified_prefill_tokens", 0) == sum(
        r.input_len for r in rs
    ), d
    unified_keys = [
        k for k in eng.executor._programs if k[0] == "unified_spmd"
    ]
    assert unified_keys, list(eng.executor._programs)
    for r in rs:
        want = kref.serial_decode_oracle(
            model, params, r.prompt, r.max_new_tokens - 1
        )
        assert want == r.output_tokens, (r.rid, want, r.output_tokens)

    # ---- StableHLO: one compiled module holds BOTH phases' collectives —
    # the ring's collective-permute (prefill chunk plane) and the merge's
    # reduce-scatter (decode prefix plane)
    from repro.engine.executor import _USeg
    from repro.manager.scheduler import PrefillBatch, UnifiedWork

    eng2 = LoongServeEngine(CFG, dop, 416, store_values=True, model=model,
                           params=params, page_size=16, mesh=mesh,
                           mcfg=ManagerConfig(prefill_chunk_tokens=48))
    # a 600-token prompt exceeds one 416-slot pool, so its placement spans
    # both instances; resuming at 480 gives every rank a prefix plane
    batch = _prefill_batch(eng2, rng, [600], max_new=4)
    r_long = batch.requests[0]
    work = UnifiedWork(batch, [])
    work.chunks = {r_long.rid: (480, 48)}  # a mid-prompt resumed chunk
    segs = eng2.executor._unified_segments(work)
    setup = eng2.executor._unified_spmd_setup(work, segs)
    assert setup is not None
    fn, args, _ = setup
    prev = eng2.model.attn_impl
    eng2.model.attn_impl = eng2.executor._unified_impl
    try:
        txt = fn.lower(*args).compile().as_text()
    finally:
        eng2.model.attn_impl = prev
    assert "collective-permute" in txt, "prefill ring plane missing"
    assert "reduce-scatter" in txt, "decode merge plane missing"
    assert "all-reduce" in txt, "pmax LSE exchange missing"

    # ---- switched ring chunk through the interpret-mode Pallas kernel:
    # the per-rank lax.switch static specialization inside shard_map == the
    # dense packed oracle
    lens = [5, 1, 17, 9, 12]
    h, kvh, hd = 4, 2, 32
    q, k, v, off = _packed_case(0, lens, h, kvh, hd, bucket=64)
    total = sum(lens)
    want = np.asarray(kref.packed_prefill_ref(
        jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), jnp.asarray(off),
    ))
    for n_ring in (2, 4):
        ring_mesh = make_test_mesh(data=n_ring, model=1)
        out = np.asarray(jax.jit(
            lambda q_, k_, v_, o_, _m=ring_mesh: esp.ring_packed_prefill_spmd(
                _m, q_, k_, v_, o_, max_seq_len=32, block_q=8, block_k=8,
                impl="interpret",
            )
        )(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), jnp.asarray(off)))
        np.testing.assert_allclose(
            out[:total], want[:total], atol=2e-5,
            err_msg=f"interpret switched ring n={n_ring}",
        )
    print("UNIFIED-OK")


CASES = {
    "ring_parity": case_ring_parity,
    "unified": case_unified,
    "join_instance": case_join_instance,
    "engine_e2e": case_engine_e2e,
    "checkpoint_restore": case_checkpoint_restore,
    "decode_parity": case_decode_parity,
    "decode_e2e": case_decode_e2e,
    "decode_shard_parity": case_decode_shard_parity,
    "decode_flops": case_decode_flops,
}


if __name__ == "__main__":
    CASES[sys.argv[1]]()

"""Mamba2 / xLSTM equivalences: chunked-parallel prefill vs recurrent decode,
state-only folds vs full pass (the SP handoff's correctness basis)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, strategies as stst

from repro.configs import REGISTRY, reduced
from repro.models import ssm, xlstm


@pytest.fixture(scope="module")
def mamba_setup():
    cfg = reduced(REGISTRY["zamba2-2.7b"])
    p = ssm.init_mamba2(
        jax.random.PRNGKey(0), cfg.d_model, expand=cfg.ssm_expand,
        head_dim=cfg.ssm_head_dim, state=cfg.ssm_state,
        conv_width=cfg.ssm_conv_width, dtype=jnp.float32,
    )
    return cfg, p


def test_mamba_chunked_equals_stepwise(mamba_setup):
    cfg, p = mamba_setup
    b, t = 2, 37
    x = jax.random.normal(jax.random.PRNGKey(1), (b, t, cfg.d_model)) * 0.1
    y_full, st_full = ssm.mamba2_forward(p, x, cfg, None)
    st = ssm.init_ssm_state(cfg, b)
    ys = []
    for i in range(t):
        y, st = ssm.mamba2_decode_step(p, x[:, i : i + 1], cfg, st)
        ys.append(y)
    y_seq = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_seq), np.asarray(y_full),
                               atol=3e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(st.h), np.asarray(st_full.h),
                               atol=3e-4, rtol=1e-3)


def test_mamba_state_only_matches_full(mamba_setup):
    cfg, p = mamba_setup
    b, t, chunk = 2, 64, 16
    d_in = cfg.ssm_expand * cfg.d_model
    nh = d_in // cfg.ssm_head_dim
    ks = jax.random.split(jax.random.PRNGKey(2), 4)
    x = jax.random.normal(ks[0], (b, t, nh, cfg.ssm_head_dim))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, t, nh)))
    a = -jnp.exp(jax.random.normal(ks[2], (nh,)) * 0.3)
    bb = jax.random.normal(ks[3], (b, t, cfg.ssm_state))
    cc = jax.random.normal(ks[0], (b, t, cfg.ssm_state))
    _, h_full = ssm.ssd_chunk_scan(x, dt, a, bb, cc, chunk)
    h_seg, d_seg = ssm.ssd_state_only(x, dt, a, bb, chunk)
    np.testing.assert_allclose(np.asarray(h_seg), np.asarray(h_full),
                               rtol=1e-4, atol=1e-5)
    # decay_seg: state with nonzero init evolves as h*decay + h_seg
    h0 = jax.random.normal(ks[1], h_full.shape)
    _, h_with = ssm.ssd_chunk_scan(x, dt, a, bb, cc, chunk, h0)
    np.testing.assert_allclose(
        np.asarray(h_with),
        np.asarray(h0 * d_seg[:, :, None, None] + h_seg),
        rtol=1e-4, atol=1e-5,
    )


@given(t=stst.sampled_from([15, 32, 51]), chunk=stst.sampled_from([8, 16]),
       seed=stst.integers(0, 1000))
@settings(max_examples=10, deadline=None)
def test_mlstm_chunkwise_equals_stepwise(t, chunk, seed):
    b, h, dh = 1, 2, 8
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    q = jax.random.normal(ks[0], (b, t, h, dh))
    k = jax.random.normal(ks[1], (b, t, h, dh))
    v = jax.random.normal(ks[2], (b, t, h, dh))
    ig = jax.random.normal(ks[3], (b, t, h))
    fg = jax.random.normal(ks[4], (b, t, h)) + 2.0
    out_c, st_c = xlstm.mlstm_chunkwise(q, k, v, ig, fg, chunk)
    st = xlstm.init_mlstm_state_raw(b, h, dh, dh)
    outs = []
    for i in range(t):
        o, st = xlstm.mlstm_step(q[:, i], k[:, i], v[:, i], ig[:, i],
                                 fg[:, i], st)
        outs.append(o[:, None])
    out_s = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(out_c), np.asarray(out_s),
                               atol=1e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(st_c.c), np.asarray(st.c),
                               atol=1e-4, rtol=1e-3)


def test_mlstm_state_only_and_combine():
    b, h, dh, t, chunk = 1, 2, 8, 48, 8
    ks = jax.random.split(jax.random.PRNGKey(7), 5)
    q = jax.random.normal(ks[0], (b, t, h, dh))
    k = jax.random.normal(ks[1], (b, t, h, dh))
    v = jax.random.normal(ks[2], (b, t, h, dh))
    ig = jax.random.normal(ks[3], (b, t, h))
    fg = jax.random.normal(ks[4], (b, t, h)) + 2.0
    _, st_full = xlstm.mlstm_chunkwise(q, k, v, ig, fg, chunk)
    st_only, btot = xlstm.mlstm_state_only(k, v, ig, fg, chunk)
    np.testing.assert_allclose(np.asarray(st_only.c), np.asarray(st_full.c),
                               atol=1e-4, rtol=1e-3)
    # monoid: state(first half) ∘ segment(second half) == state(full)
    half = t // 2
    s1, _ = xlstm.mlstm_state_only(k[:, :half], v[:, :half], ig[:, :half],
                                   fg[:, :half], chunk)
    s2, b2 = xlstm.mlstm_state_only(k[:, half:], v[:, half:], ig[:, half:],
                                    fg[:, half:], chunk)
    comb = xlstm.mlstm_combine_states(s1, s2, b2)
    np.testing.assert_allclose(np.asarray(comb.c), np.asarray(st_full.c),
                               atol=1e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(comb.n), np.asarray(st_full.n),
                               atol=1e-4, rtol=1e-3)


def test_slstm_step_equals_scan():
    cfg = reduced(REGISTRY["xlstm-350m"])
    p = xlstm.init_slstm(jax.random.PRNGKey(0), cfg, jnp.float32)
    b, t = 2, 9
    x = jax.random.normal(jax.random.PRNGKey(1), (b, t, cfg.d_model)) * 0.3
    y_full, st_full = xlstm.slstm_block_forward(p, x, cfg, None)
    st = xlstm.init_slstm_state(cfg, b)
    ys = []
    for i in range(t):
        y, st = xlstm.slstm_block_step(p, x[:, i : i + 1], cfg, st)
        ys.append(y)
    np.testing.assert_allclose(
        np.asarray(jnp.concatenate(ys, axis=1)), np.asarray(y_full),
        atol=1e-4, rtol=1e-3,
    )

"""Distributed KV pool: token granularity, fragmentation, migration,
placement properties (hypothesis)."""
import numpy as np
import pytest
from hypothesis_compat import given, settings, strategies as stst

from repro.configs import REGISTRY, reduced
from repro.kvcache import DistributedKVPool, KVPool, OutOfSlots

CFG = reduced(REGISTRY["lwm-7b"])


def test_paper_fig4_fragmentation():
    """Fig. 4: free slots 1+2+3 across instances; a 6-token request fits the
    unified pool but not any locality-constrained single instance."""
    dp = DistributedKVPool(CFG, 4, 100, store_values=False)
    for i, used in enumerate([99, 98, 97, 100]):
        dp.pools[i].alloc(1000 + i, list(range(used)))
    assert dp.total_free == 6
    assert dp.max_contiguous_request() == 3
    assert dp.fragmentation_waste() == 3
    plan = dp.plan_placement(7, list(range(6)), [0, 1, 2, 3])
    assert plan.n_tokens == 6
    dp.place(plan)
    assert dp.total_free == 0


@given(
    frees=stst.lists(stst.integers(0, 50), min_size=2, max_size=6),
    n_tok=stst.integers(1, 120),
    seed=stst.integers(0, 100),
)
@settings(max_examples=40, deadline=None)
def test_placement_plan_properties(frees, n_tok, seed):
    n = len(frees)
    dp = DistributedKVPool(CFG, n, 64, store_values=False)
    for i, f in enumerate(frees):
        used = 64 - min(f, 64)
        if used:
            dp.pools[i].alloc(1000 + i, list(range(used)))
    targets = list(range(n))
    total_free = dp.total_free
    if n_tok > total_free:
        with pytest.raises(OutOfSlots):
            dp.plan_placement(1, list(range(n_tok)), targets)
        return
    plan = dp.plan_placement(1, list(range(n_tok)), targets)
    # covers every token exactly once
    toks = sorted(t for ts in plan.assignment.values() for t in ts)
    assert toks == list(range(n_tok))
    # respects per-instance free space
    for i, ts in plan.assignment.items():
        assert len(ts) <= dp.pools[i].free_slots
    dp.place(plan)
    assert dp.request_tokens(1) == n_tok


def test_values_roundtrip_and_migration():
    dp = DistributedKVPool(CFG, 4, 64)
    n_attn = max(CFG.n_attention_applications, 1)
    k = np.random.default_rng(0).normal(
        size=(n_attn, 20, CFG.n_kv_heads, CFG.head_dim)
    )
    plan = dp.plan_placement(5, list(range(20)), [0, 1, 2, 3])
    dp.place(plan, k, k + 1)
    pos, kk, vv = dp.gather_request(5)
    assert pos.tolist() == list(range(20))
    np.testing.assert_allclose(kk, k, atol=1e-6)
    np.testing.assert_allclose(vv, k + 1, atol=1e-6)
    src = plan.instances()[0]
    moved = dp.migrate_request(5, src, [0, 1, 2, 3])
    assert moved > 0 and dp.migrated_bytes == moved
    pos2, k2, v2 = dp.gather_request(5)
    np.testing.assert_allclose(k2, k, atol=1e-6)
    assert not dp.pools[src].tokens_of(5)


def test_fill_reserved_slots():
    pool = KVPool(CFG, 32)
    pool.alloc(1, [0, 1, 2])
    n_attn = max(CFG.n_attention_applications, 1)
    k = np.ones((n_attn, 3, CFG.n_kv_heads, CFG.head_dim))
    pool.fill(1, [0, 1, 2], k, 2 * k)
    pos, kk, vv = pool.gather(1)
    np.testing.assert_allclose(kk, 1.0)
    np.testing.assert_allclose(vv, 2.0)


def test_swa_window_eviction():
    pool = KVPool(CFG, 16, store_values=False)
    pool.alloc(1, list(range(10)))
    freed = pool.free_positions(1, [0, 1, 2, 3])
    assert freed == 4
    assert pool.free_slots == 16 - 6
    assert sorted(pool.tokens_of(1)) == [4, 5, 6, 7, 8, 9]


def test_alloc_free_invariants():
    pool = KVPool(CFG, 8, store_values=False)
    pool.alloc(1, [0, 1, 2])
    pool.alloc(2, [0, 1])
    with pytest.raises(OutOfSlots):
        pool.alloc(3, list(range(5)))
    assert pool.free_request(1) == 3
    assert pool.free_slots == 6
    pool.alloc(3, list(range(5)))
    assert pool.used == 7

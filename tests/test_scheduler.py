"""Four-step scheduler: DP batching vs brute force, SIB fit accuracy,
dispatch/allocation/scaling behaviors."""
import numpy as np
import pytest
from hypothesis_compat import given, settings, strategies as stst

from repro.configs import REGISTRY
from repro.engine.request import Request
from repro.kvcache import DistributedKVPool
from repro.manager import (
    SIB,
    DecodeBatch,
    GlobalManager,
    ManagerConfig,
    dp_batching,
    dp_batching_naive,
    make_prefill_cost,
)

CFG = REGISTRY["lwm-7b"]


# ------------------------------------------------------------- DP batching
@given(
    lens=stst.lists(stst.integers(100, 100_000), min_size=1, max_size=7),
    caps=stst.lists(stst.integers(10_000, 200_000), min_size=1, max_size=6),
    seed=stst.integers(0, 50),
)
@settings(max_examples=30, deadline=None)
def test_dp_monotone_safety_properties(lens, caps, seed):
    """The windowed DP never (a) beats the true optimum, (b) declares an
    instance infeasible that the exhaustive DP can solve."""
    sib = SIB(CFG)
    lens = sorted(lens, reverse=True)
    caps = sorted(caps)
    cost = make_prefill_cost(sib, lens)
    v_fast, _ = dp_batching(lens, caps, cost, monotone=True)
    v_naive, _ = dp_batching_naive(lens, caps, cost)
    if v_naive == float("inf"):
        assert v_fast == float("inf")
    else:
        assert v_fast < float("inf")
        assert v_naive <= v_fast + 1e-15


def test_dp_monotone_statistical_quality():
    """REPRODUCTION FINDING (EXPERIMENTS.md §Notes): the paper's Eq. 6
    monotone-window speedup is exact only under quadrangle-inequality cost
    structure, which our fitted/napkin SIB cost violates on a few % of
    instances. We pin the heuristic's quality distribution instead: mean
    within 1%, p95 within 10% of the exhaustive optimum."""
    import random

    sib = SIB(CFG)
    rnd = random.Random(42)
    ratios = []
    for _ in range(300):
        n, m = rnd.randint(1, 6), rnd.randint(1, 5)
        lens = sorted((rnd.randint(100, 100_000) for _ in range(n)), reverse=True)
        caps = sorted(rnd.randint(10_000, 200_000) for _ in range(m))
        cost = make_prefill_cost(sib, lens)
        v_fast, _ = dp_batching(lens, caps, cost, monotone=True)
        v_naive, _ = dp_batching_naive(lens, caps, cost)
        if v_naive == float("inf"):
            continue
        ratios.append(v_fast / v_naive)
    assert ratios
    ratios.sort()
    mean = sum(ratios) / len(ratios)
    p95 = ratios[int(len(ratios) * 0.95)]
    assert mean < 1.01, mean
    assert p95 < 1.10, p95


@given(
    lens=stst.lists(stst.integers(100, 50_000), min_size=1, max_size=6),
    m=stst.integers(1, 5),
    seed=stst.integers(0, 50),
)
@settings(max_examples=25, deadline=None)
def test_dp_monotone_bounded_suboptimality(lens, m, seed):
    """REPRODUCTION FINDING (EXPERIMENTS.md §Notes): the paper's Eq. 6
    monotone-split speedup is exact only under quadrangle-inequality cost
    structure; our fitted/napkin SIB cost violates QI on ~9% of random
    instances. The windowed DP is therefore a heuristic — we pin its
    suboptimality to <=10% and its cost to never beat the exact optimum."""
    sib = SIB(CFG)
    lens = sorted(lens, reverse=True)
    caps = [10_000_000] * m  # capacity never binds
    cost = make_prefill_cost(sib, lens)
    v_fast, _ = dp_batching(lens, caps, cost, monotone=True)
    v_naive, _ = dp_batching_naive(lens, caps, cost)
    assert v_naive <= v_fast + 1e-15
    assert v_fast <= v_naive * 1.10, (lens, m, v_fast, v_naive)


def test_dp_batching_respects_capacity():
    sib = SIB(CFG)
    lens = [50_000, 40_000, 1_000]
    caps = [30_000, 30_000, 60_000]
    cost = make_prefill_cost(sib, lens)
    val, splits = dp_batching(lens, caps, cost)
    assert splits, "feasible split must exist"
    d = [0] + list(np.cumsum(lens))
    v = [0] + list(np.cumsum(caps))
    for s in splits:
        need = d[s.req_hi] - d[s.req_lo]
        have = v[s.inst_hi] - v[s.inst_lo]
        assert need <= have
    # all requests covered exactly once, instances disjoint
    covered = sorted(
        i for s in splits for i in range(s.req_lo, s.req_hi)
    )
    assert covered == list(range(len(lens)))


def test_dp_infeasible_returns_empty():
    sib = SIB(CFG)
    lens = [100_000]
    caps = [10_000, 10_000]
    val, splits = dp_batching(lens, caps, make_prefill_cost(sib, lens))
    assert val == float("inf") and splits == []


# --------------------------------------------------------------------- SIB
def test_sib_fit_accuracy():
    """Fig. 14: fitted analytical model within 10% on held-out batches."""
    sib = SIB(CFG)
    rng = np.random.default_rng(0)
    alpha, beta, gamma = 0.004, 2.1e-6, 3.3e-12
    for _ in range(30):
        lens = rng.integers(500, 150_000, rng.integers(1, 5))
        s1, s2 = float(lens.sum()), float((lens.astype(float) ** 2).sum())
        t = alpha + beta * s1 + gamma * s2
        sib.record_prefill(4, list(lens), t * (1 + rng.normal() * 0.02))
    errs = []
    for _ in range(20):
        lens = rng.integers(500, 150_000, rng.integers(1, 5))
        s1, s2 = float(lens.sum()), float((lens.astype(float) ** 2).sum())
        truth = alpha + beta * s1 + gamma * s2
        errs.append(abs(sib.prefill_time(4, list(lens)) - truth) / truth)
    assert float(np.mean(errs)) < 0.10, np.mean(errs)


def test_sib_straggler_model():
    sib = SIB(CFG)
    base = sib.prefill_time(4, [10_000], instances=[0, 1, 2, 3])
    sib.set_instance_speed(2, 0.5)
    slow = sib.prefill_time(4, [10_000], instances=[0, 1, 2, 3])
    assert slow == pytest.approx(base * 2)
    ok = sib.prefill_time(4, [10_000], instances=[0, 1, 3])
    assert ok == pytest.approx(base * 4 / 3, rel=0.35)  # dop 3 slower but unthrottled


def test_decode_time_scales_with_dop():
    sib = SIB(CFG)
    t1 = sib.decode_time(1, 8, 1_000_000)
    t4 = sib.decode_time(4, 8, 1_000_000)
    assert t4 < t1  # HBM-bound decode gains from more instances


# ----------------------------------------------------------- four-step plan
def _mk_manager(n=8, cap=200_000):
    sib = SIB(CFG)
    pool = DistributedKVPool(CFG, n, cap, store_values=False)
    return GlobalManager(CFG, sib, pool, ManagerConfig()), pool, sib


def test_dispatch_respects_memory():
    gm, pool, _ = _mk_manager(n=2, cap=10_000)
    big = Request(input_len=50_000, max_new_tokens=10)
    plan = gm.schedule([big], [], idle_instances=[0, 1], now=0.0)
    assert not plan.prefill  # cannot fit anywhere


def test_proactive_scale_down_targets_and_placement():
    gm, pool, _ = _mk_manager()
    req = Request(input_len=100_000, max_new_tokens=64)
    plan = gm.schedule([req], [], idle_instances=list(range(8)), now=0.0)
    assert plan.prefill
    b = plan.prefill[0]
    assert b.dop >= len(b.scale_down_to) >= 1
    placed = sum(
        len(toks) for toks in b.placement[req.rid].values()
    )
    assert placed == req.input_len
    # placement targets are a subset of the scale-down group
    assert set(b.placement[req.rid]) <= set(b.scale_down_to)
    # slots were reserved
    assert pool.request_tokens(req.rid) == req.input_len


def test_decode_scale_up_on_memory_pressure():
    gm, pool, sib = _mk_manager(n=4, cap=1_000)
    reqs = [Request(input_len=900, max_new_tokens=512) for _ in range(2)]
    for i, r in enumerate(reqs):
        pool.pools[i].alloc(r.rid, list(range(900)))
        r.generated = 1
    g = DecodeBatch(reqs, [0, 1], {reqs[0].rid: 0, reqs[1].rid: 1})
    plan = gm.schedule([], [g], idle_instances=[2, 3], now=0.0)
    assert plan.decode
    assert len(plan.decode[0].instances) > 2  # scaled up


def test_multi_master_assignment_uniform():
    gm, pool, _ = _mk_manager(n=4)
    reqs = [Request(input_len=100, max_new_tokens=8) for _ in range(8)]
    for r in reqs:
        r.generated = 1
    masters = gm._assign_masters(reqs, [0, 1, 2, 3])
    counts = {}
    for m in masters.values():
        counts[m] = counts.get(m, 0) + 1
    assert max(counts.values()) - min(counts.values()) <= 1


def test_decode_group_merging():
    gm, pool, _ = _mk_manager(n=8)
    a = [Request(input_len=100, max_new_tokens=8) for _ in range(2)]
    b = [Request(input_len=100, max_new_tokens=8) for _ in range(2)]
    for r in a + b:
        r.generated = 1
    g1 = DecodeBatch(a, [0], {r.rid: 0 for r in a})
    g2 = DecodeBatch(b, [1], {r.rid: 1 for r in b})
    plan = gm.schedule([], [g1, g2], idle_instances=[], now=0.0)
    # alpha-dominated tiny batches -> merged into one group
    assert len(plan.decode) == 1
    assert len(plan.decode[0].requests) == 4

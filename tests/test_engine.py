"""Serving engine: sim + real mode invariants, oracle-token equivalence,
fault tolerance, checkpoint/restore, baselines."""
import copy
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.baselines import (
    ChunkedPrefillEngine,
    FixedGroupsEngine,
    PDDisaggEngine,
    StaticTPEngine,
)
from repro.configs import REGISTRY, reduced
from repro.data import poisson_workload, with_prompts
from repro.engine.request import Phase, Request
from repro.engine.server import LoongServeEngine
from repro.models import build_model

CFG = REGISTRY["lwm-7b"]


def _workload(n=30, seed=3):
    return poisson_workload("mixed", n, rate=0.5, seed=seed)


def test_sim_engine_completes_all_zero_scaling_migration():
    eng = LoongServeEngine(CFG, 8, 250_000)
    reqs = _workload()
    for r in reqs:
        eng.submit(r)
    m = eng.run()
    assert len(m.finished) == len(reqs)
    assert m.scaling_migration_bytes == 0  # ESP's zero-overhead invariant
    assert all(r.phase == Phase.FINISHED for r in m.finished)
    assert all(r.generated == r.max_new_tokens for r in m.finished)


def test_real_engine_tokens_match_oracle():
    cfg = reduced(CFG)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = LoongServeEngine(cfg, 4, 2000, store_values=True, model=model,
                           params=params)
    rng = np.random.default_rng(1)
    reqs = []
    for i in range(4):
        ln = int(rng.integers(16, 80))
        r = Request(input_len=ln, max_new_tokens=6, arrival=i * 0.01,
                    prompt=rng.integers(0, cfg.vocab_size, ln).tolist())
        reqs.append(r)
        eng.submit(r)
    eng.run()
    for r in reqs:
        toks = jnp.asarray(np.asarray(r.prompt)[None], jnp.int32)
        logits, cache = model.prefill(params, {"tokens": toks})
        nxt = int(np.argmax(np.asarray(logits[0, -1])))
        out = [nxt]
        S = r.input_len + 8
        k_pad = jnp.zeros((cache.k.shape[0], 1, S) + cache.k.shape[3:],
                          cache.k.dtype).at[:, :, : r.input_len].set(cache.k)
        v_pad = jnp.zeros_like(k_pad).at[:, :, : r.input_len].set(cache.v)
        cache = cache._replace(k=k_pad, v=v_pad)
        for _ in range(5):
            logits, cache, kvs = model.decode(params, jnp.asarray([nxt], jnp.int32), cache)
            pos = int(cache.length[0]) - 1
            cache = cache._replace(
                k=cache.k.at[:, :, pos : pos + 1].set(kvs[0]),
                v=cache.v.at[:, :, pos : pos + 1].set(kvs[1]),
            )
            nxt = int(np.argmax(np.asarray(logits[0])))
            out.append(nxt)
        assert out == r.output_tokens, (r.rid, out, r.output_tokens)


def test_failure_recovery():
    eng = LoongServeEngine(CFG, 8, 250_000)
    reqs = _workload(20, seed=5)
    for r in reqs:
        eng.submit(r)
    eng.fail_instance(3, at=2.0)
    eng.fail_instance(5, at=4.0)
    eng.join_instance(3, at=50.0)
    m = eng.run()
    assert len(m.finished) == len(reqs)  # all complete despite failures
    assert 5 in eng.failed and 3 not in eng.failed


def test_checkpoint_restore_roundtrip():
    eng = LoongServeEngine(CFG, 8, 250_000)
    reqs = _workload(16, seed=6)
    for r in reqs:
        eng.submit(r)
    eng.run(max_time=3.0)
    done_before = len(eng.metrics.finished)
    with tempfile.NamedTemporaryFile(suffix=".ckpt") as f:
        eng.checkpoint(f.name)
        eng2 = LoongServeEngine(CFG, 8, 250_000)
        eng2.restore(f.name)
    m = eng2.run()
    assert len(m.finished) == len(reqs)
    assert len(m.finished) >= done_before


def test_straggler_mitigation_allocates_around_slow_instance():
    eng = LoongServeEngine(CFG, 4, 250_000)
    eng.sib.set_instance_speed(0, 0.25)  # a 4x straggler
    reqs = _workload(10, seed=8)
    for r in reqs:
        eng.submit(r)
    m = eng.run()
    assert len(m.finished) == len(reqs)


@pytest.mark.parametrize("ctor", [
    lambda: StaticTPEngine(CFG, 8, 250_000),
    lambda: ChunkedPrefillEngine(CFG, 8, 250_000),
    lambda: PDDisaggEngine(CFG, 8, 250_000),
    lambda: FixedGroupsEngine(CFG, 8, 250_000, groups=[[i] for i in range(8)]),
])
def test_baselines_complete(ctor):
    eng = ctor()
    reqs = poisson_workload("sharegpt", 20, rate=2.0, seed=9)
    for r in copy.deepcopy(reqs):
        eng.submit(r)
    m = eng.run()
    assert len(m.finished) + m.rejected >= 19  # replicated groups may reject


def test_pd_disagg_rejects_what_unified_pool_serves():
    """Paper §7.2: PD-disagg OOMs on long requests (half the memory per
    phase); LoongServe's unified pool serves them."""
    long_req = Request(input_len=1_300_000, max_new_tokens=16)
    pd = PDDisaggEngine(CFG, 8, 200_000)
    pd.submit(copy.deepcopy(long_req))
    mpd = pd.run()
    ls = LoongServeEngine(CFG, 8, 200_000)
    ls.submit(copy.deepcopy(long_req))
    mls = ls.run()
    assert mpd.rejected == 1 or len(mpd.finished) == 0
    assert len(mls.finished) == 1


def test_loongserve_beats_baselines_on_long_context():
    reqs = poisson_workload("lveval", 40, rate=0.15, seed=7)
    results = {}
    for name, ctor in [
        ("loongserve", lambda: LoongServeEngine(CFG, 8, 250_000)),
        ("vllm", lambda: StaticTPEngine(CFG, 8, 250_000)),
        ("pd", lambda: PDDisaggEngine(CFG, 8, 250_000)),
    ]:
        eng = ctor()
        for r in copy.deepcopy(reqs):
            eng.submit(r)
        results[name] = eng.run().summary()
    assert results["loongserve"]["norm_e2e_mean"] < results["vllm"]["norm_e2e_mean"]
    assert results["loongserve"]["norm_e2e_mean"] < results["pd"]["norm_e2e_mean"]

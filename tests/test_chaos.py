"""Chaos harness + invariant sanitizer: seeded fault-injection soaks with
zero-violation/zero-leak acceptance, bit-for-bit determinism, real-mode
oracle parity under injected NaNs/faults/pressure, and unit coverage of
every graceful-degradation path (retry, quarantine, preemption,
backpressure, dropped migration, checkpoint taxonomy, liveness tick)."""
import os
import pickle

import jax
import numpy as np
import pytest

from repro.chaos import ChaosConfig, ChaosMonkey
from repro.configs import REGISTRY, reduced
from repro.data import poisson_workload
from repro.engine.invariants import InvariantChecker, InvariantViolation
from repro.engine.request import Phase, Request
from repro.engine.server import (
    CHECKPOINT_FORMAT_VERSION,
    CheckpointError,
    LoongServeEngine,
)
from repro.kernels import ops
from repro.kernels import ref as kref
from repro.kvcache.distributed import DistributedKVPool
from repro.kvcache.pool import OutOfSlots
from repro.models import build_model

CFG = REGISTRY["lwm-7b"]

# CI's chaos-soak job sweeps this over extra fixed seeds; any seed must
# satisfy the same acceptance (zero violations, zero leaks, all finish)
SOAK_SEED = int(os.environ.get("REPRO_CHAOS_SEED", "11"))

SOAK_RATES = dict(
    fail_rate=0.02, rejoin_rate=0.06, straggler_rate=0.05, slowdown_rate=0.02,
    pressure_rate=0.05, release_rate=0.04, dispatch_fault_rate=0.25,
    nan_rate=0.03, min_alive=2,
)


def _armed(eng, chaos_cfg, seed):
    """Chaos FIRST, checker SECOND: the sanitizer validates post-injection
    state after every event."""
    monkey = ChaosMonkey(eng, chaos_cfg, seed=seed)
    chk = InvariantChecker(eng)
    monkey.arm()
    chk.arm()
    return monkey, chk


def _sim_soak(seed, *, n_req=60, max_events=3000):
    eng = LoongServeEngine(CFG, 6, 24_000, admission_watermark=0.1)
    reqs = poisson_workload("mixed", n_req, rate=2.0, seed=11, max_len=16_000)
    for r in reqs:
        eng.submit(r)
    monkey, chk = _armed(eng, ChaosConfig(**SOAK_RATES), seed)
    eng.run(max_events=max_events)
    monkey.disarm()
    eng.run()
    return eng, reqs, monkey, chk


# --------------------------------------------------------------------- soaks
def test_sim_chaos_soak_zero_violations_zero_leaks():
    """Capstone soak: thousands of sanitizer checks under all sim-applicable
    injectors, every request completes, nothing leaks."""
    eng, reqs, monkey, chk = _sim_soak(seed=SOAK_SEED)
    assert chk.checks >= 2000
    assert all(r.phase is Phase.FINISHED for r in reqs)
    assert chk.leaked_slots() == 0
    assert eng.pool.total_used == 0
    actions = {t[1] for t in monkey.trace}
    # dispatch faults need real-mode dispatch guards (covered below); all
    # other injectors must have fired in the soak
    for a in ("fail", "rejoin", "straggle", "slowdown", "pressure",
              "release", "poison"):
        assert a in actions, f"injector {a!r} never fired"
    m = eng.metrics.summary()
    for k in ("dropped_migrations", "dispatch_retries",
              "dispatch_declared_failures", "nan_quarantined", "preemptions",
              "recomputed_tokens", "backpressure_deferrals"):
        assert k in m


def test_chaos_same_seed_identical_trace_and_metrics():
    """Determinism: one rng stream drives every injection decision, so the
    same (seed, workload, rates) replays bit-for-bit."""
    runs = []
    for _ in range(2):
        eng, reqs, monkey, chk = _sim_soak(seed=7, n_req=25, max_events=800)
        assert all(r.phase is Phase.FINISHED for r in reqs)
        assert chk.leaked_slots() == 0
        runs.append((monkey.trace_fingerprint(), eng.metrics.summary()))
    assert runs[0][0] == runs[1][0]
    assert runs[0][1] == runs[1][1]
    # and a different seed takes a different path
    _, _, other, _ = _sim_soak(seed=8, n_req=25, max_events=800)
    assert other.trace_fingerprint() != runs[0][0]


@pytest.fixture(scope="module")
def real_model():
    cfg = reduced(CFG)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _real_workload(cfg, eng, n=10, seed=7):
    rng = np.random.default_rng(seed)
    reqs, orig = [], {}
    for i in range(n):
        ilen = int(rng.integers(16, 49))
        mnt = int(rng.integers(4, 9))
        prompt = rng.integers(0, cfg.vocab_size, ilen).tolist()
        r = Request(input_len=ilen, max_new_tokens=mnt, arrival=i * 0.01,
                    prompt=list(prompt))
        reqs.append(r)
        eng.submit(r)
        orig[r.rid] = (list(prompt), mnt)
    return reqs, orig


def _assert_oracle_parity(cfg, model, params, reqs, orig):
    """Every request's emitted tokens must match the serial dense-cache
    oracle on its ORIGINAL prompt — chaos (evictions, recomputes, retries,
    quarantines) may reshuffle work but never change tokens."""
    for r in reqs:
        prompt0, mnt0 = orig[r.rid]
        oracle = kref.serial_decode_oracle(model, params, prompt0, mnt0 - 1)
        assert list(r.output_tokens) == list(oracle), r.rid


def test_real_chaos_soak_oracle_parity(real_model):
    """Real-mode soak: all six injectors (incl. dispatch faults + NaN
    poison), zero violations/leaks, and bit-for-bit token parity with the
    serial oracle for every request."""
    cfg, model, params = real_model
    eng = LoongServeEngine(cfg, 3, 600, store_values=True, model=model,
                           params=params, admission_watermark=0.15)
    reqs, orig = _real_workload(cfg, eng)
    chaos = ChaosConfig(
        fail_rate=0.05, rejoin_rate=0.3, straggler_rate=0.2,
        slowdown_rate=0.1, pressure_rate=0.25, release_rate=0.15,
        ballast_frac=0.3, dispatch_fault_rate=0.2, nan_rate=0.12,
        min_alive=2,
    )
    # seed chosen so every injector fires within the soak window under the
    # current scheduler (boundary admission batches the workload into fewer
    # events, so the old seed's 5% fail draw never landed)
    monkey, chk = _armed(eng, chaos, seed=7)
    eng.run(max_events=300)
    monkey.disarm()
    eng.run()
    assert all(r.phase is Phase.FINISHED for r in reqs)
    assert chk.leaked_slots() == 0
    assert eng.pool.total_used == 0
    actions = {t[1] for t in monkey.trace}
    for a in ("fail", "rejoin", "straggle", "slowdown", "pressure",
              "dispatch_fault", "poison"):
        assert a in actions, f"injector {a!r} never fired"
    assert eng.metrics.dispatch_retries > 0
    assert eng.metrics.nan_quarantined > 0
    _assert_oracle_parity(cfg, model, params, reqs, orig)


# ---------------------------------------------------- degradation unit paths
def test_liveness_tick_revives_stalled_engine():
    """busy_until inflated with no completion event behind it (the straggler
    injection shape): the run loop must tick to the next idle horizon and
    finish the work instead of draining the queue and abandoning it."""
    eng = LoongServeEngine(CFG, 2, 1000)
    r = Request(input_len=50, max_new_tokens=4, arrival=0.0)
    eng.submit(r)
    for i in range(eng.n):
        eng.busy_until[i] = 100.0
    m = eng.run()
    assert len(m.finished) == 1
    assert eng.clock >= 100.0  # finished AFTER the stall horizon


def test_decode_oom_preempts_and_recomputes():
    """Foreign memory pressure mid-decode shrinks the pool under an admitted
    request: the token append must preempt/evict-recompute, never crash or
    emit different tokens."""
    eng = LoongServeEngine(CFG, 1, 2000)
    reqs = [Request(input_len=100, max_new_tokens=100, arrival=0.0)
            for _ in range(2)]
    for r in reqs:
        eng.submit(r)
    state = {"phase": 0}

    def hook(e, kind, payload):
        if kind != "decode_done":
            return
        if state["phase"] == 0:  # squeeze: leave < max_new free slots
            grab = e.pool.pools[0].free_slots - 20
            e.pool.pools[0].alloc(-99, list(range(grab)))
            state["phase"] = 1
        elif state["phase"] == 1 and e.metrics.preemptions > 0:
            e.pool.pools[0].free_request(-99)  # pressure subsides
            e._push(e.clock + 1e-3, "tick", None)
            state["phase"] = 2

    eng.event_hooks.append(hook)
    m = eng.run()
    assert state["phase"] == 2
    assert m.preemptions >= 1
    assert m.recomputed_tokens > 0
    assert len(m.finished) == 2
    assert eng.pool.total_used == 0


def test_backpressure_defers_admission_then_drains():
    eng = LoongServeEngine(CFG, 2, 2000, admission_watermark=0.99)
    reqs = [Request(input_len=150, max_new_tokens=10, arrival=i * 0.01)
            for i in range(4)]
    for r in reqs:
        eng.submit(r)
    m = eng.run()
    assert m.backpressure_deferrals > 0
    assert len(m.finished) == 4


def test_dropped_migration_counted_not_fatal():
    """A migration refused by the pool (OutOfSlots) is dropped and counted;
    the request keeps serving from its source instance."""
    eng = LoongServeEngine(CFG, 4, 8000)
    reqs = poisson_workload("mixed", 30, rate=2.0, seed=3, max_len=6000)
    for r in reqs:
        eng.submit(r)
    attempts = [0]

    def refuse(rid, src, dsts):
        attempts[0] += 1
        raise OutOfSlots("forced refusal")

    orig = eng.pool.migrate_request
    eng.pool.migrate_request = refuse
    monkey, chk = _armed(
        eng,
        ChaosConfig(fail_rate=0.03, rejoin_rate=0.1, pressure_rate=0.08,
                    release_rate=0.06, min_alive=2),
        seed=4,
    )
    eng.run(max_events=1500)
    monkey.disarm()
    eng.pool.migrate_request = orig
    # while patched, every refusal must be dropped AND counted, 1:1
    assert attempts[0] > 0
    assert eng.metrics.dropped_migrations == attempts[0]
    m = eng.run()
    # the drain (real pool) may legitimately drop more on planner/pool
    # divergence — also counted, never fatal
    assert m.dropped_migrations >= attempts[0]
    assert len(m.finished) == len(reqs)
    assert chk.leaked_slots() == 0


def test_migration_is_transactional_on_refusal():
    """plan_placement raising mid-migration must leave the source copy
    intact (no token loss) and no partial destination copies."""
    pool = DistributedKVPool(CFG, 3, 100, store_values=False)
    pool.pools[0].alloc(1, range(80))
    pool.pools[1].alloc(-1, range(95))  # foreign pressure fills the dsts
    pool.pools[2].alloc(-2, range(95))
    with pytest.raises(OutOfSlots):
        pool.migrate_request(1, 0, [1, 2])
    assert len(pool.pools[0].tokens_of(1)) == 80  # source untouched
    assert not pool.pools[1].tokens_of(1)
    assert not pool.pools[2].tokens_of(1)
    assert pool.migrated_bytes == 0


def test_checkpoint_error_taxonomy(tmp_path):
    eng = LoongServeEngine(CFG, 2, 1000)
    with pytest.raises(CheckpointError, match="not found"):
        eng.restore(str(tmp_path / "nope.ckpt"))

    corrupt = tmp_path / "corrupt.ckpt"
    corrupt.write_bytes(b"\x80\x04 this is not a pickle")
    with pytest.raises(CheckpointError, match="truncated or corrupt"):
        eng.restore(str(corrupt))

    unstamped = tmp_path / "unstamped.ckpt"
    with open(unstamped, "wb") as f:
        pickle.dump({"clock": 0.0}, f)
    with pytest.raises(CheckpointError, match="format-version stamp"):
        eng.restore(str(unstamped))

    future = tmp_path / "future.ckpt"
    with open(future, "wb") as f:
        pickle.dump({"format_version": CHECKPOINT_FORMAT_VERSION + 1}, f)
    with pytest.raises(CheckpointError, match="format version"):
        eng.restore(str(future))

    # a good checkpoint still restores after all those rejections
    good = tmp_path / "good.ckpt"
    eng.submit(Request(input_len=40, max_new_tokens=4, arrival=0.0))
    eng.checkpoint(str(good))
    eng2 = LoongServeEngine(CFG, 2, 1000)
    eng2.restore(str(good))
    assert len(eng2.run().finished) == 1


def test_nan_quarantine_recomputes_to_oracle_tokens(real_model):
    """A poisoned logits row quarantines ONLY that request; after requeue +
    recompute its tokens still match the oracle exactly."""
    cfg, model, params = real_model
    eng = LoongServeEngine(cfg, 2, 600, store_values=True, model=model,
                           params=params)
    reqs, orig = _real_workload(cfg, eng, n=2, seed=3)
    eng._logit_poison.add(reqs[0].rid)
    m = eng.run()
    assert len(m.finished) == 2
    assert m.nan_quarantined == 1
    assert eng.pool.total_used == 0
    _assert_oracle_parity(cfg, model, params, reqs, orig)


def test_dispatch_retry_then_declared_failure(real_model):
    """Transient dispatch faults are retried with backoff; a persistent
    fault (> max retries consecutive) declares the instance failed and the
    work relocates — tokens still match the oracle either way."""
    cfg, model, params = real_model

    # a) transient burst shorter than the retry budget: retried, no failure
    eng = LoongServeEngine(cfg, 3, 600, store_values=True, model=model,
                           params=params)
    reqs, orig = _real_workload(cfg, eng, n=3, seed=9)
    calls = [0]

    def burst(point):
        if point == "decode_dispatch":
            calls[0] += 1
            if calls[0] <= 2:
                raise ops.TransientDispatchError("test burst")

    ops.set_fault_hook(burst)
    try:
        m = eng.run()
    finally:
        ops.set_fault_hook(None)
    assert len(m.finished) == 3
    assert m.dispatch_retries >= 2
    assert m.dispatch_declared_failures == 0
    _assert_oracle_parity(cfg, model, params, reqs, orig)

    # b) persistent fault: retries exhaust, instance declared failed,
    # requests relocate to the survivors and still finish correctly
    eng = LoongServeEngine(cfg, 3, 600, store_values=True, model=model,
                           params=params)
    reqs, orig = _real_workload(cfg, eng, n=3, seed=9)
    calls = [0]

    def persistent(point):
        if point == "decode_dispatch":
            calls[0] += 1
            if calls[0] <= eng.dispatch_max_retries + 1:
                raise ops.TransientDispatchError("test persistent")

    ops.set_fault_hook(persistent)
    try:
        m = eng.run()
    finally:
        ops.set_fault_hook(None)
    assert len(m.finished) == 3
    assert m.dispatch_declared_failures == 1
    assert len(eng.failed) == 1
    _assert_oracle_parity(cfg, model, params, reqs, orig)


def test_invariant_checker_flags_manual_leak():
    """Negative control: the sanitizer itself must fire on a genuinely
    inconsistent state (slots held by a rid the engine does not know)."""
    eng = LoongServeEngine(CFG, 2, 1000)
    eng.submit(Request(input_len=40, max_new_tokens=4, arrival=0.0))
    eng.run()
    chk = InvariantChecker(eng)
    chk.check()  # clean state passes
    eng.pool.pools[0].alloc(12345, [0, 1, 2])
    with pytest.raises(InvariantViolation, match=r"\[I1\]"):
        chk.check()


# fixed default (independent of the chaos-soak seed matrix): the kill-heavy
# acceptance bounds below are validated for this seed; CI's dedicated
# kill-heavy leg pins the same value explicitly
KILL_SEED = int(os.environ.get("REPRO_CHAOS_KILL_SEED", "131"))


def test_sim_chaos_kill_heavy_salvage_soak():
    """Kill-heavy soak (ISSUE 10 acceptance): instance failures dominate
    the injection mix and elastic KV salvage must carry recovery — a
    positive `salvage_ratio` and total recompute strictly below the
    workload's total tokens (full-recompute recovery cannot stay under
    that bound at this failure rate), with every failure audited by the
    monkey's salvage assertions, the sanitizer green after every event,
    zero leaks, and every request finishing."""
    eng = LoongServeEngine(CFG, 6, 24_000, admission_watermark=0.1)
    reqs = poisson_workload("mixed", 60, rate=2.0, seed=11, max_len=16_000)
    for r in reqs:
        eng.submit(r)
    monkey, chk = _armed(eng, ChaosConfig(
        fail_rate=0.08, rejoin_rate=0.20, min_alive=2, max_injections=40,
    ), KILL_SEED)
    eng.run(max_events=3000)
    monkey.disarm()
    eng.run()
    assert all(r.phase is Phase.FINISHED for r in reqs)
    assert chk.leaked_slots() == 0
    assert eng.pool.total_used == 0
    assert sum(1 for t in monkey.trace if t[1] == "fail") >= 5
    assert eng.metrics.salvaged_tokens > 0
    snap = eng.metrics.snapshot()
    assert snap["salvage_ratio"] > 0
    assert monkey.salvage_ratio() == snap["salvage_ratio"]
    assert eng.metrics.recomputed_tokens < sum(r.seq_len for r in reqs)

"""Ring-fused packed prefill (DoP>1 ESP groups): kernel/chunk parity with
the dense oracle across DoP x {GQA, sliding window, softcap} in interpret and
XLA modes, striped shard-offset helpers, the lazy host copy for
`fill_packed` (device-only prefill critical path, on-demand sync), the
placement-aliveness requeue guard, and an e2e engine test asserting a DoP=2
packed prefill reproduces the serial-oracle token sequence with zero
per-request serial prefill calls and zero mirror re-uploads."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import REGISTRY, reduced
from repro.core import esp, striped
from repro.engine.request import Phase, Request
from repro.engine.server import LoongServeEngine
from repro.kernels import ops
from repro.kernels import ref as kref
from repro.manager.scheduler import PrefillBatch
from repro.models import build_model

CFG = reduced(REGISTRY["lwm-7b"])


def _packed_case(seed, lens, h, kvh, d, bucket):
    rng = np.random.default_rng(seed)
    total = sum(lens)
    assert total <= bucket
    off = np.full(len(lens) + 1, total, np.int32)
    off[0] = 0
    c = 0
    for i, n in enumerate(lens):
        c += n
        off[i + 1] = c
    q = rng.normal(size=(bucket, h, d)).astype(np.float32)
    k = rng.normal(size=(bucket, kvh, d)).astype(np.float32)
    v = rng.normal(size=(bucket, kvh, d)).astype(np.float32)
    return q, k, v, off


# ------------------------------------------------------- striped helpers


def test_shard_offsets_match_bruteforce():
    """shard_offsets[b] == number of shard-local tokens with global packed
    index < seq_offsets[b], for every shard and stride."""
    off = np.array([0, 5, 6, 23, 32, 44], np.int64)
    for n in (2, 3, 4):
        for r in range(n):
            got = np.asarray(striped.shard_offsets(off, n, r))
            want = [sum(1 for g in range(o) if g % n == r) for o in off]
            np.testing.assert_array_equal(got, want)
            # per-request runs are contiguous in the shard's local order
            assert (np.diff(got) >= 0).all()


def test_ring_chunk_schedule_covers_every_chunk_once():
    """Replaying the ring_pairs ppermute schedule hands every rank every
    chunk exactly once over the ring (incl. disjoint subgroups)."""
    for n, g in [(2, None), (4, None), (8, 4)]:
        sched = striped.ring_chunk_schedule(n, g)
        gg = g or n
        assert len(sched) == gg
        for r in range(n):
            seen = [sched[s][r] for s in range(gg)]
            base = (r // gg) * gg
            assert sorted(seen) == list(range(base, base + gg)), (n, g, r)
        assert sched[0] == list(range(n))  # step 0: own chunk


# ------------------------------------------------- kernel / chunk parity


@pytest.mark.parametrize("impl", ["xla", "interpret"])
@pytest.mark.parametrize("dop", [2, 4])
@pytest.mark.parametrize("window,softcap", [(None, None), (7, None), (None, 5.0)])
def test_ring_prefill_matches_dense_oracle(impl, dop, window, softcap):
    """The full fused ring (one chunk launch per instance per ring step,
    carried (acc, m, l) state) equals the single-launch dense packed oracle
    for mixed lengths (incl. length-1) under GQA, sliding window and logit
    softcap, at DoP 2 and 4; bucket padding never leaks into real rows."""
    lens = [5, 1, 17, 9, 12]
    h, kvh, d = 4, 2, 32
    q, k, v, off = _packed_case(0, lens, h, kvh, d, bucket=64)
    total = sum(lens)
    out = np.asarray(esp.ring_packed_prefill(
        jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), jnp.asarray(off),
        dop, window=window, softcap=softcap, max_seq_len=32, impl=impl,
        block_q=8, block_k=8,
    ))
    dense = np.asarray(kref.packed_prefill_ref(
        jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), jnp.asarray(off),
        window=window, softcap=softcap,
    ))
    np.testing.assert_allclose(out[:total], dense[:total], atol=2e-5)


@pytest.mark.parametrize("impl", ["xla", "interpret"])
def test_ring_chunk_step_matches_chunk_oracle(impl):
    """A single ring step (one chunk folded into a non-trivial carry) equals
    the dense per-chunk oracle — validates the carried-state contract, not
    just the fully-reduced ring."""
    lens = [3, 11, 8, 2]
    n = 2
    q, k, v, off = _packed_case(1, lens, 4, 2, 16, bucket=32)
    qs = jnp.asarray(q[1::n])  # shard 1 queries
    offs = [striped.shard_offsets(off, n, r) for r in range(n)]
    carry = None
    for step, c in enumerate([1, 0]):  # own chunk, then the rotated one
        kc, vc = jnp.asarray(k[c::n]), jnp.asarray(v[c::n])
        carry = ops.prefill_ring_chunk(
            qs, kc, vc, offs[1], offs[c], carry, q_shard=1, k_shard=c,
            n_shards=n, max_seq_len=16, impl=impl, block_q=8, block_k=8,
        )
        ref_carry = kref.packed_prefill_ring_chunk_ref(
            qs, kc, vc, jnp.asarray(off),
            (jnp.zeros_like(carry[0]), jnp.full_like(carry[1], -jnp.inf),
             jnp.zeros_like(carry[2])) if step == 0 else ref_carry,
            q_shard=1, k_shard=c, n_shards=n,
        )
        for got, want in zip(carry, ref_carry):
            got, want = np.asarray(got), np.asarray(want)
            fin = np.isfinite(want)
            np.testing.assert_allclose(got[fin], want[fin], atol=2e-5)
            np.testing.assert_array_equal(np.isfinite(got), fin)


def test_ring_banded_fallback_band_widths():
    """The banded XLA chunk fallback equals the dense chunk oracle for every
    static reach bound, including bands narrower than the shard axis."""
    lens = [3, 11, 8, 2]
    n = 4
    q, k, v, off = _packed_case(2, lens, 4, 2, 16, bucket=32)
    offs = [striped.shard_offsets(off, n, r) for r in range(n)]
    empty = (
        jnp.zeros((32 // n, 4, 16), jnp.float32),
        jnp.full((32 // n, 4), -jnp.inf, jnp.float32),
        jnp.zeros((32 // n, 4), jnp.float32),
    )
    for r, c in [(0, 3), (2, 1), (3, 3)]:
        want = kref.packed_prefill_ring_chunk_ref(
            jnp.asarray(q[r::n]), jnp.asarray(k[c::n]), jnp.asarray(v[c::n]),
            jnp.asarray(off), empty, q_shard=r, k_shard=c, n_shards=n,
        )
        for max_len in (11, 16, 32, None):
            got = kref.packed_prefill_ring_chunk_banded(
                jnp.asarray(q[r::n]), jnp.asarray(k[c::n]),
                jnp.asarray(v[c::n]), offs[r], offs[c], empty,
                q_shard=r, k_shard=c, n_shards=n, block_q=4,
                max_seq_len=max_len,
            )
            for g, w in zip(got, want):
                g, w = np.asarray(g), np.asarray(w)
                fin = np.isfinite(w)
                np.testing.assert_allclose(g[fin], w[fin], atol=2e-5)


# --------------------------------------------------------- engine / e2e


def _prefill_batch(eng, rng, lengths, rid0=0, max_new=8):
    n_inst = len(eng.pool.pools)
    reqs, placement = [], {}
    for j, ln in enumerate(lengths):
        n = int(ln)
        r = Request(input_len=n, max_new_tokens=max_new,
                    prompt=rng.integers(0, eng.cfg.vocab_size, n).tolist())
        r.rid, r.phase = rid0 + j, Phase.PREFILL
        plan = eng.pool.plan_placement(r.rid, list(range(n)), range(n_inst))
        eng.pool.place(plan)
        placement[r.rid] = plan.assignment
        reqs.append(r)
    return PrefillBatch(reqs, list(range(n_inst)),
                        scale_down_to=list(range(n_inst)),
                        placement=placement)


def test_engine_dop2_prefill_serial_oracle_zero_reupload():
    """e2e: a DoP=2 packed prefill batch runs ZERO per-request serial
    model.prefill calls (dispatch counters), dispatches the ring-chunk
    kernel, reproduces the serial-oracle token sequence through decode, and
    uploads ZERO mirror slots for the prefill KV (write-through + lazy host
    copy: the critical path is device-only)."""
    model = build_model(CFG)
    params = model.init(jax.random.PRNGKey(0))
    eng = LoongServeEngine(CFG, 2, 4000, store_values=True, model=model,
                           params=params, page_size=16)
    rng = np.random.default_rng(23)
    # pre-create the mirrors so creation uploads don't mask the invariant
    for pool in eng.pool.pools:
        pool.device_kv()
        pool.mirror_uploaded_slots = 0
        pool.mirror_full_syncs = 0
    batch = _prefill_batch(eng, rng, [33, 17, 50, 8], max_new=4)
    reqs = list(batch.requests)
    ops.reset_dispatch_counts()
    eng._on_prefill_done(batch)  # runs the DoP=2 packed prefill + transitions
    assert ops.dispatch_counts.get("prefill_serial_model", 0) == 0
    assert ops.dispatch_counts["prefill_ring_chunk"] == 4  # dop^2 per step
    assert any(key[3] == 2 for key in eng._prefill_programs)  # a DoP=2 program
    for pool in eng.pool.pools:
        assert pool.mirror_uploaded_slots == 0  # prefill KV: zero re-upload
        assert pool.mirror_full_syncs == 0
        assert pool.dirty_slot_count() == 0
        assert pool.host_syncs == 0  # critical path stayed device-only
    # drive decode to completion (join event is a no-op that kicks the
    # scheduler's _try_schedule loop)
    eng._push(eng.clock, "join", 0)
    m = eng.run()
    assert len(m.finished) == len(reqs)
    assert ops.dispatch_counts.get("prefill_serial_model", 0) == 0
    # token parity: packed DoP=2 prefill + paged decode == serial oracle
    for r in reqs:
        toks = jnp.asarray(np.asarray(r.prompt)[None], jnp.int32)
        logits, cache = model.prefill(params, {"tokens": toks})
        nxt = int(np.argmax(np.asarray(logits[0, -1])))
        out = [nxt]
        S = r.input_len + 8
        k_pad = jnp.zeros((cache.k.shape[0], 1, S) + cache.k.shape[3:],
                          cache.k.dtype).at[:, :, : r.input_len].set(cache.k)
        v_pad = jnp.zeros_like(k_pad).at[:, :, : r.input_len].set(cache.v)
        cache = cache._replace(k=k_pad, v=v_pad)
        for _ in range(3):
            logits, cache, kvs = model.decode(
                params, jnp.asarray([nxt], jnp.int32), cache
            )
            pos = int(cache.length[0]) - 1
            cache = cache._replace(
                k=cache.k.at[:, :, pos : pos + 1].set(kvs[0]),
                v=cache.v.at[:, :, pos : pos + 1].set(kvs[1]),
            )
            nxt = int(np.argmax(np.asarray(logits[0])))
            out.append(nxt)
        assert out == r.output_tokens, (r.rid, out, r.output_tokens)


def test_checkpoint_forces_lazy_host_sync():
    """state_dict snapshots the host copy, so checkpointing after a packed
    prefill must force the deferred device->host download first."""
    model = build_model(CFG)
    params = model.init(jax.random.PRNGKey(0))
    eng = LoongServeEngine(CFG, 2, 1024, store_values=True, model=model,
                           params=params, page_size=16)
    rng = np.random.default_rng(29)
    batch = _prefill_batch(eng, rng, [21, 42])
    eng._real_prefill(batch)
    pool = eng.pool.pools[0]
    assert pool.stale_host_slot_count() > 0 and pool.host_syncs == 0
    pool.state_dict()
    assert pool.stale_host_slot_count() == 0 and pool.host_syncs == 1
    kd, _, _ = pool.device_kv()
    np.testing.assert_allclose(np.asarray(kd), pool.k, atol=1e-6)


def test_full_mirror_resync_preserves_stale_fill_packed_kv():
    """A forced FULL mirror resync (host-write burst tripping the dirty
    tracker) must pull stale fill_packed slots down to the host first —
    otherwise the resync would overwrite the mirror's packed-prefill KV
    with never-synced host data and decode would attend over garbage."""
    model = build_model(CFG)
    params = model.init(jax.random.PRNGKey(0))
    eng = LoongServeEngine(CFG, 1, 512, store_values=True, model=model,
                           params=params, page_size=16)
    rng = np.random.default_rng(37)
    batch = _prefill_batch(eng, rng, [30, 45])
    eng._real_prefill(batch)
    pool = eng.pool.pools[0]
    assert pool.stale_host_slot_count() > 0
    kd_before, _, _ = pool.device_kv()
    ref = {r.rid: np.asarray(kd_before[:, pool.slots_of(r.rid)])
           for r in batch.requests}
    # host-write burst > capacity/4 on ANOTHER request -> _dirty_full
    n_burst = pool.capacity // 4 + 16
    kb = rng.normal(size=(pool.n_attn, n_burst, CFG.n_kv_heads,
                          CFG.head_dim)).astype(np.float32)
    pool.write(999, list(range(10_000, 10_000 + n_burst)), kb, kb)
    assert pool.dirty_slot_count() == pool.capacity  # full resync pending
    kd, _, _ = pool.device_kv()
    for r in batch.requests:  # packed KV survived the full resync
        np.testing.assert_allclose(
            np.asarray(kd[:, pool.slots_of(r.rid)]), ref[r.rid], atol=1e-6
        )


def test_prefill_done_requeues_requests_with_lost_placement():
    """A request whose reserved placement references a failed instance must
    be requeued for recompute instead of silently scattering a partial KV —
    the guard that backstops the epoch stamp when it is unavailable (e.g.
    after a checkpoint restore dropped the launch-time state)."""
    model = build_model(CFG)
    params = model.init(jax.random.PRNGKey(0))
    eng = LoongServeEngine(CFG, 3, 4000, store_values=True, model=model,
                           params=params, page_size=8)
    rng = np.random.default_rng(31)
    batch = _prefill_batch(eng, rng, [20, 30, 25])
    victim = next(
        i for i in range(3)
        if any(batch.placement[r.rid].get(i) for r in batch.requests)
    )
    lost = [r for r in batch.requests
            if batch.placement[r.rid].get(victim)]
    kept = [r for r in batch.requests if r not in lost]
    # simulate the post-restore scenario: the instance is failed but the
    # requeue bookkeeping (and the epoch stamp) was lost with the checkpoint
    eng.failed.add(victim)
    eng.busy_until[victim] = float("inf")
    eng._on_prefill_done(batch)
    for r in lost:
        assert r.phase is Phase.PENDING
        assert r in eng.pending
        assert eng.pool.request_tokens(r.rid) == 0  # reservation freed
    for r in kept:
        assert r.phase is Phase.DECODE
        assert len(r.output_tokens) == 1

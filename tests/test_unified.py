"""Unified continuous-batching iteration: chunk-resume parity against the
serial oracle across chunk schedules (single chunk, ragged tail, one token
per chunk), decode-token flow while a long prompt is mid-prefill (the
tentpole behavior: chunked prefill riders instead of a decode stall), the
fused-step ops counters, and the invariant sanitizer (incl. the I6
"unified_done" event extension) over the fused path with a mid-chain
instance failure."""
import copy

import jax
import numpy as np
import pytest

from repro.configs import REGISTRY, reduced
from repro.engine.request import Phase, Request
from repro.engine.server import LoongServeEngine
from repro.kernels import ops
from repro.kernels import ref as kref
from repro.manager.scheduler import ManagerConfig
from repro.models import build_model

CFG = reduced(REGISTRY["lwm-7b"])


@pytest.fixture(scope="module")
def model_params():
    model = build_model(CFG)
    params = model.init(jax.random.PRNGKey(0))
    return model, params


def _mixed_requests(rng, n_short=4, short_len=24, short_new=40,
                    long_len=600, long_new=8, long_at=0.05):
    """The tentpole workload: short prompts mid-decode when one long prompt
    arrives whose chunked prefill overlaps their instances."""
    reqs = []
    for _ in range(n_short):
        reqs.append(Request(
            input_len=short_len, max_new_tokens=short_new, arrival=0.0,
            prompt=rng.integers(0, CFG.vocab_size, short_len).tolist(),
        ))
    reqs.append(Request(
        input_len=long_len, max_new_tokens=long_new, arrival=long_at,
        prompt=rng.integers(0, CFG.vocab_size, long_len).tolist(),
    ))
    return reqs


@pytest.mark.parametrize("chunk", [1000, 7, 1])
def test_chunk_resume_parity(model_params, chunk):
    """Chunk-resume == one-shot prefill: for every chunk schedule (whole
    prompt in one chunk, ragged tail chunks, one token per chunk) the
    engine's token sequences equal the serial dense oracle — the paged pool
    really is the carried flash state between chunks."""
    model, params = model_params
    rng = np.random.default_rng(17)
    reqs = []
    for ln in (13, 21, 5):
        reqs.append(Request(
            input_len=ln, max_new_tokens=4, arrival=0.0,
            prompt=rng.integers(0, CFG.vocab_size, ln).tolist(),
        ))
    ops.reset_dispatch_counts()
    eng = LoongServeEngine(
        CFG, 2, 2000, store_values=True, model=model, params=params,
        mcfg=ManagerConfig(prefill_chunk_tokens=chunk),
    )
    for r in reqs:
        eng.submit(r)
    m = eng.run()
    assert len(m.finished) == len(reqs)
    assert ops.dispatch_counts["unified_step"] > 0
    assert ops.dispatch_counts["unified_prefill_tokens"] == sum(
        r.input_len for r in reqs
    )
    if chunk == 1:  # one token per chunk -> one iteration per prompt token
        assert ops.dispatch_counts["unified_step"] >= sum(
            r.input_len for r in reqs
        )
    for r in reqs:
        want = kref.serial_decode_oracle(model, params, r.prompt, 3)
        assert want == r.output_tokens, (chunk, r.rid, want, r.output_tokens)


def test_decode_flows_during_long_prefill(model_params):
    """While the long prompt is mid-prefill, decode tokens keep flowing:
    fused iterations carry nonzero decode rows (the riders), the short
    requests finish with oracle-exact tokens, and the long prompt's own
    sequence is oracle-exact too (chunked prefill == one-shot prefill)."""
    model, params = model_params
    rng = np.random.default_rng(19)
    reqs = _mixed_requests(rng)
    eng = LoongServeEngine(
        CFG, 2, 704, store_values=True, model=model, params=params,
        page_size=16, mcfg=ManagerConfig(prefill_chunk_tokens=64),
    )
    rs = copy.deepcopy(reqs)
    long_rid = rs[-1]
    # per-iteration decode/prefill token mix, recorded at each fused dispatch
    mix = []
    orig = eng.executor.unified

    def spy(work):
        before = (ops.dispatch_counts["unified_prefill_tokens"],
                  ops.dispatch_counts["unified_decode_tokens"])
        out = orig(work)
        mix.append((
            ops.dispatch_counts["unified_prefill_tokens"] - before[0],
            ops.dispatch_counts["unified_decode_tokens"] - before[1],
            long_rid.phase is Phase.PREFILL and long_rid.prefill_pos > 0,
        ))
        return out

    eng.executor.unified = spy
    ops.reset_dispatch_counts()
    for r in rs:
        eng.submit(r)
    m = eng.run()
    assert len(m.finished) == len(rs)
    # the long prompt really was chunked (several fused iterations touched
    # it) AND decode rows rode along while it was mid-prefill
    long_iters = [(p, d) for p, d, mid in mix if mid]
    assert len(long_iters) >= 3, mix
    riding = [d for _, d in long_iters if d > 0]
    assert riding, f"no decode tokens flowed during the long prefill: {mix}"
    assert ops.dispatch_counts["unified_decode_tokens"] >= len(riding)
    for r in rs:
        want = kref.serial_decode_oracle(
            model, params, r.prompt, r.max_new_tokens - 1
        )
        assert want == r.output_tokens, (r.rid, want, r.output_tokens)


def test_invariants_hold_over_unified_chain_with_failure(model_params):
    """The engine sanitizer (I1-I8, with I6 extended to `unified_done`
    events) stays green after every event of a unified-chain run, including
    an instance failure landing mid-chain; every request still finishes via
    the normal requeue/recompute path."""
    from repro.engine.invariants import InvariantChecker

    model, params = model_params
    rng = np.random.default_rng(23)
    reqs = _mixed_requests(rng, short_new=12, long_len=200, long_new=4)
    eng = LoongServeEngine(
        CFG, 2, 416, store_values=True, model=model, params=params,
        page_size=16, mcfg=ManagerConfig(prefill_chunk_tokens=48),
    )
    chk = InvariantChecker(eng)
    chk.arm()
    for r in copy.deepcopy(reqs):
        eng.submit(r)
    # step until a unified link is in flight, then fail one of its instances
    guard = 0
    while not any(e[2] == "unified_done" for e in eng.events):
        assert eng.events and guard < 500, "no unified chain launched"
        eng.run(max_events=1)
        guard += 1
    work = next(e[3] for e in eng.events if e[2] == "unified_done")
    victim = work.alive_instances(eng.failed)[0]
    eng.fail_instance(victim)
    m = eng.run()
    assert len(m.finished) == len(reqs)
    assert chk.leaked_slots() == 0
    assert eng.pool.total_used == 0

"""Batched paged flash-decode: numerical equivalence with the per-request
dense path, ragged edge cases (zero-length / max-length), multi-shard
multi-master merges, launch-count invariants, and the real-mode engine
end-to-end on a page_size>1 pool."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import REGISTRY, reduced
from repro.kernels import ops
from repro.kvcache import KVPool
from repro.models import attention as A

CFG = reduced(REGISTRY["lwm-7b"])


def _ragged_pool_case(seed, b, page, n_pages, kvh, d, contiguous=True):
    """Random paged storage + block tables for a ragged batch, including a
    zero-length and a max-length request."""
    rng = np.random.default_rng(seed)
    cap = n_pages * page
    lengths = rng.integers(1, cap // b + 1, b).astype(np.int32)
    lengths[0] = 0  # zero-length request
    lengths[-1] = cap // b  # max-length request for this layout
    k_pages = rng.normal(size=(n_pages, page, kvh, d)).astype(np.float32)
    v_pages = rng.normal(size=(n_pages, page, kvh, d)).astype(np.float32)
    pos_pages = np.zeros((n_pages, page), np.int32)
    max_pages = int(max(-(-lengths // page)))
    table = np.zeros((b, max_pages), np.int32)
    free = list(rng.permutation(n_pages))  # scattered, non-contiguous pages
    for i in range(b):
        npg = -(-int(lengths[i]) // page)
        pages = [free.pop() for _ in range(npg)]
        table[i, :npg] = pages
        for j, pg in enumerate(pages):
            pos_pages[pg] = np.arange(j * page, (j + 1) * page)
    q = rng.normal(size=(b, 1, 2 * kvh, d)).astype(np.float32)
    return q, k_pages, v_pages, table, lengths, pos_pages


@pytest.mark.parametrize("impl", ["xla", "interpret"])
@pytest.mark.parametrize("window", [None, 5])
def test_paged_equals_per_request_dense(impl, window):
    """One batched paged launch == per-request flash_decode_partial (dense
    gather) on the normalized output, for a ragged batch incl. zero-length
    and max-length requests (acceptance tolerance 1e-5)."""
    b, page, n_pages, kvh, d = 6, 8, 30, 2, 32
    q, kp, vp, table, lengths, pos = _ragged_pool_case(3, b, page, n_pages, kvh, d)
    qpos = lengths.astype(np.int32)  # query position == cached token count
    p_new = ops.paged_decode_partial(
        jnp.asarray(q), jnp.asarray(kp), jnp.asarray(vp), table, lengths, pos,
        query_pos=qpos, window=window, impl=impl,
    )
    out_new = np.asarray(A.finalize_partial(p_new))
    for i in range(b):
        n = int(lengths[i])
        if n == 0:
            np.testing.assert_allclose(out_new[i], 0.0, atol=1e-7)
            continue
        npg = -(-n // page)
        dense_k = kp[table[i, :npg]].reshape(npg * page, kvh, d)[None, :n]
        dense_v = vp[table[i, :npg]].reshape(npg * page, kvh, d)[None, :n]
        # pad to a block multiple for the dense kernel's tiling constraint
        pad = (-n) % 8
        if pad:
            dense_k = np.pad(dense_k, ((0, 0), (0, pad), (0, 0), (0, 0)))
            dense_v = np.pad(dense_v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        p_old = ops.decode_partial(
            jnp.asarray(q[i : i + 1]), jnp.asarray(dense_k),
            jnp.asarray(dense_v), jnp.asarray([n], jnp.int32),
            window=window, impl=impl, block_k=8,
        )
        np.testing.assert_allclose(
            np.asarray(A.finalize_partial(p_old))[0], out_new[i], atol=1e-5
        )


def test_paged_shards_compose_to_full_multi_master():
    """Partials from per-instance paged launches merge (multi-master combine)
    to exactly the dense full-cache decode — the ESP invariant."""
    rng = np.random.default_rng(7)
    b, kvh, d, h = 3, 2, 16, 4
    page = 4
    lengths = np.array([0, 11, 29], np.int32)
    s_max = int(lengths.max())
    k_full = rng.normal(size=(b, s_max, kvh, d)).astype(np.float32)
    v_full = rng.normal(size=(b, s_max, kvh, d)).astype(np.float32)
    q = jnp.asarray(rng.normal(size=(b, 1, h, d)), jnp.float32)
    # scatter tokens token-granularly across 2 "instances" (even/odd split),
    # each instance packing its share into its own pages
    parts = []
    for inst in range(2):
        n_pages = 16
        kp = np.zeros((n_pages, page, kvh, d), np.float32)
        vp = np.zeros((n_pages, page, kvh, d), np.float32)
        pos = np.zeros((n_pages, page), np.int32)
        local = [np.arange(inst, lengths[i], 2) for i in range(b)]
        llen = np.array([len(x) for x in local], np.int32)
        maxp = int(max(-(-llen // page)))
        table = np.zeros((b, maxp), np.int32)
        nxt = 0
        for i in range(b):
            npg = -(-int(llen[i]) // page)
            pages = list(range(nxt, nxt + npg))
            nxt += npg
            table[i, :npg] = pages
            flat = np.concatenate([local[i], np.zeros((-len(local[i])) % page, np.int64)])
            for j, pg in enumerate(pages):
                sl = slice(j * page, (j + 1) * page)
                pos[pg] = flat[sl]
                valid = min(len(local[i]) - j * page, page)
                kp[pg, :valid] = k_full[i, local[i][j * page : j * page + valid]]
                vp[pg, :valid] = v_full[i, local[i][j * page : j * page + valid]]
        parts.append(ops.paged_decode_partial(
            q, jnp.asarray(kp), jnp.asarray(vp), table, llen, pos,
            query_pos=lengths, impl="interpret",
        ))
    merged = A.combine_partials(parts)
    ref = A.finalize_partial(ops.decode_partial(
        q, jnp.asarray(k_full), jnp.asarray(v_full), jnp.asarray(lengths),
        impl="xla",
    ))
    np.testing.assert_allclose(np.asarray(merged), np.asarray(ref), atol=1e-5)


def test_one_launch_per_instance_independent_of_batch():
    """The paged decode impl issues exactly one kernel dispatch per instance
    per layer — never one per request."""
    from repro.core.paged_decode import PagedDecodeAttnImpl, PagedShard

    rng = np.random.default_rng(0)
    page, n_pages, kvh, d, h, L = 4, 8, 2, 8, 4, 3
    for b in (1, 9):
        shards = []
        for inst in range(2):
            kp = jnp.asarray(rng.normal(size=(L, n_pages, page, kvh, d)), jnp.float32)
            vp = jnp.asarray(rng.normal(size=(L, n_pages, page, kvh, d)), jnp.float32)
            table = np.tile(np.arange(2, dtype=np.int32), (b, 1))
            lengths = np.full(b, 2 * page, np.int32)
            pos = np.tile(np.arange(2 * page, dtype=np.int32).reshape(2, page), (4, 1))
            shards.append(PagedShard(kp, vp, jnp.asarray(table),
                                     jnp.asarray(lengths), jnp.asarray(pos)))
        impl = PagedDecodeAttnImpl(impl="xla")
        q = jnp.asarray(rng.normal(size=(b, 1, h, d)), jnp.float32)
        k_new = jnp.asarray(rng.normal(size=(b, 1, kvh, d)), jnp.float32)
        v_new = jnp.asarray(rng.normal(size=(b, 1, kvh, d)), jnp.float32)
        impl.begin_step(shards)
        ops.reset_dispatch_counts()
        for _ in range(L):  # one decode_attn call per layer, as the stack does
            impl.decode_attn(q, None, None, k_new, v_new,
                             np.full(b, 2 * page, np.int32), window=None,
                             softcap=None)
        impl.end_step()
        assert ops.dispatch_counts["paged_decode_partial"] == 2 * L  # 2 instances
        assert ops.dispatch_counts["decode_partial"] == 0


def test_layer_cursor_mismatch_raises():
    """The armed impl's layer cursor is verified against the number of
    per-layer storage planes: an over-run raises at the offending
    decode_attn call, an under-run raises at end_step — a model/impl
    stack-order mismatch can no longer read the wrong layer's pages
    silently."""
    from repro.core.paged_decode import PagedDecodeAttnImpl, PagedShard

    rng = np.random.default_rng(1)
    page, n_pages, kvh, d, h, L, b = 4, 4, 2, 8, 4, 3, 2
    kp = jnp.asarray(rng.normal(size=(L, n_pages, page, kvh, d)), jnp.float32)
    shard = PagedShard(
        kp, kp, jnp.asarray(np.zeros((b, 1), np.int32)),
        jnp.asarray(np.full(b, page, np.int32)),
        jnp.asarray(np.arange(n_pages * page, dtype=np.int32)
                    .reshape(n_pages, page)),
    )
    q = jnp.asarray(rng.normal(size=(b, 1, h, d)), jnp.float32)
    kn = jnp.asarray(rng.normal(size=(b, 1, kvh, d)), jnp.float32)
    cl = np.full(b, page, np.int32)

    def call(impl):
        impl.decode_attn(q, None, None, kn, kn, cl, window=None, softcap=None)

    # under-run: fewer decode_attn calls than stored planes
    impl = PagedDecodeAttnImpl(impl="xla")
    impl.begin_step([shard])
    for _ in range(L - 1):
        call(impl)
    with pytest.raises(AssertionError, match="layer planes"):
        impl.end_step()
    assert impl._shards is None  # disarmed despite the failed verification

    # over-run: the L+1-th call trips before reading out of bounds
    impl = PagedDecodeAttnImpl(impl="xla")
    impl.begin_step([shard])
    for _ in range(L):
        call(impl)
    with pytest.raises(AssertionError, match="stack mismatch"):
        call(impl)
    impl._layer = impl._n_planes  # repair so disarm verification passes
    impl.end_step()

    # exact consumption passes clean
    impl = PagedDecodeAttnImpl(impl="xla")
    impl.begin_step([shard])
    for _ in range(L):
        call(impl)
    impl.end_step()


def test_real_engine_paged_pool_matches_oracle_zero_migration():
    """Real-mode engine on a page_size>1 pool: generated tokens match the
    dense single-request oracle, decode issues no per-request dispatches, and
    ESP scaling stays zero-copy."""
    from repro.engine.request import Request
    from repro.engine.server import LoongServeEngine
    from repro.models import build_model

    cfg = reduced(REGISTRY["lwm-7b"])
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = LoongServeEngine(cfg, 4, 2000, store_values=True, model=model,
                           params=params, page_size=16)
    rng = np.random.default_rng(2)
    reqs = []
    for i in range(4):
        ln = int(rng.integers(16, 80))
        r = Request(input_len=ln, max_new_tokens=5, arrival=i * 0.01,
                    prompt=rng.integers(0, cfg.vocab_size, ln).tolist())
        reqs.append(r)
        eng.submit(r)
    ops.reset_dispatch_counts()
    m = eng.run()
    assert len(m.finished) == len(reqs)
    assert m.scaling_migration_bytes == 0
    assert ops.dispatch_counts["paged_decode_partial"] > 0
    assert ops.dispatch_counts["decode_partial"] == 0
    # the engine must have restored the caller's dense impl on the model
    from repro.models.transformer import DefaultAttnImpl

    assert type(model.attn_impl) is DefaultAttnImpl
    for r in reqs:
        toks = jnp.asarray(np.asarray(r.prompt)[None], jnp.int32)
        logits, cache = model.prefill(params, {"tokens": toks})
        nxt = int(np.argmax(np.asarray(logits[0, -1])))
        out = [nxt]
        S = r.input_len + 8
        k_pad = jnp.zeros((cache.k.shape[0], 1, S) + cache.k.shape[3:],
                          cache.k.dtype).at[:, :, : r.input_len].set(cache.k)
        v_pad = jnp.zeros_like(k_pad).at[:, :, : r.input_len].set(cache.v)
        cache = cache._replace(k=k_pad, v=v_pad)
        for _ in range(4):
            logits, cache, kvs = model.decode(
                params, jnp.asarray([nxt], jnp.int32), cache
            )
            pos = int(cache.length[0]) - 1
            cache = cache._replace(
                k=cache.k.at[:, :, pos : pos + 1].set(kvs[0]),
                v=cache.v.at[:, :, pos : pos + 1].set(kvs[1]),
            )
            nxt = int(np.argmax(np.asarray(logits[0])))
            out.append(nxt)
        assert out == r.output_tokens, (r.rid, out, r.output_tokens)

"""Property-based tests (hypothesis) on the system's core invariants.

`hypothesis` is an optional dev dependency (requirements-dev.txt); every test
here is property-based, so the whole module skips when it is missing.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as stst  # noqa: E402

from repro.core import striped as st
from repro.models import attention as A

SETTINGS = dict(max_examples=25, deadline=None)


@given(
    s=stst.integers(2, 8).map(lambda k: k * 12),
    n=stst.sampled_from([2, 3, 4, 6]),
)
@settings(**SETTINGS)
def test_stripe_unstripe_identity(s, n):
    if s % n:
        s = (s // n) * n
    x = np.arange(2 * s * 3).reshape(2, s, 3)
    y = st.unstripe(st.stripe(jnp.asarray(x), n), n)
    np.testing.assert_array_equal(np.asarray(y), x)


@given(
    n=stst.sampled_from([2, 4, 8]),
    s=stst.sampled_from([16, 32, 64]),
    seed=stst.integers(0, 10_000),
)
@settings(**SETTINGS)
def test_partial_merge_order_invariance(n, s, seed):
    """Merging KV-shard partials must be exact regardless of shard order —
    the invariant multi-master decode and the ring both rely on."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    b, h, d = 1, 2, 16
    q = jax.random.normal(ks[0], (b, 4, h, d))
    k = jax.random.normal(ks[1], (b, s, h, d))
    v = jax.random.normal(ks[2], (b, s, h, d))
    full = A.full_attention(q, k, v, causal=False)
    per = s // n
    parts = [
        A.partial_attention(q, k[:, i * per:(i + 1) * per],
                            v[:, i * per:(i + 1) * per], None)
        for i in range(n)
    ]
    rng = np.random.default_rng(seed)
    order = rng.permutation(n)
    out = A.combine_partials([parts[i] for i in order]).astype(jnp.float32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(full, np.float32),
                               atol=1e-5)


@given(
    seed=stst.integers(0, 10_000),
    n=stst.sampled_from([2, 4]),
)
@settings(**SETTINGS)
def test_ring_schedule_covers_all_pairs_once(seed, n):
    """Simulated ring: every (q-stripe, kv-stripe) pair is computed exactly
    once — no redundant or missing compute."""
    seen = set()
    for step in range(n):
        for dev in range(n):
            kv_owner = (dev - step) % n
            pair = (dev, kv_owner)
            assert pair not in seen
            seen.add(pair)
    assert len(seen) == n * n


@given(
    s=stst.sampled_from([24, 48]),
    n=stst.sampled_from([2, 4]),
    window=stst.sampled_from([None, 8, 16]),
    seed=stst.integers(0, 1000),
)
@settings(**SETTINGS)
def test_host_ring_equals_dense(s, n, window, seed):
    """Host-level simulation of the striped ring (no shard_map) == dense."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    b, h, d = 1, 2, 8
    q = jax.random.normal(ks[0], (b, s, h, d))
    k = jax.random.normal(ks[1], (b, s, h, d))
    v = jax.random.normal(ks[2], (b, s, h, d))
    ref = A.full_attention(q, k, v, causal=True, window=window)
    pos = np.asarray(st.striped_positions(s, n))
    qs, ks_, vs = (np.asarray(st.stripe(x, n)) for x in (q, k, v))
    per = s // n
    outs = []
    for dev in range(n):
        sl = slice(dev * per, (dev + 1) * per)
        acc = None
        for step in range(n):
            src = (dev - step) % n
            ssl = slice(src * per, (src + 1) * per)
            mask = A.mask_from_positions(
                jnp.asarray(pos[sl]), jnp.asarray(pos[ssl]), causal=True,
                window=window,
            )
            part = A.partial_attention(
                jnp.asarray(qs[:, sl]), jnp.asarray(ks_[:, ssl]),
                jnp.asarray(vs[:, ssl]), mask,
            )
            acc = part if acc is None else A.merge_partial(acc, part)
        outs.append(A.finalize_partial(acc))
    out = st.unstripe(jnp.concatenate(outs, axis=1), n)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref, np.float32),
                               atol=1e-5)

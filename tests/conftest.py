import os
import sys

# Tests see the default single CPU device (the dry-run sets its own 512-device
# flag in-process; SPMD equivalence tests run via subprocess).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

"""Optional-hypothesis shim.

`hypothesis` is an *optional* dev dependency (declared in
requirements-dev.txt).  Importing `given/settings/strategies` from here keeps
a module's plain tests collectible when it is absent: property tests are
skipped with a clear reason instead of failing the whole collection.
"""
import pytest

try:
    from hypothesis import given, settings, strategies  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised on minimal installs
    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        def deco(fn):
            return pytest.mark.skip(reason="hypothesis not installed")(fn)

        return deco

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn

        return deco

    class _StrategyStub:
        """Accepts any strategy construction; never executed (tests skip)."""

        def __getattr__(self, _name):
            def _stub(*args, **kwargs):
                return _StrategyStub()

            return _stub

        def map(self, _fn):
            return self

    strategies = _StrategyStub()

"""Mesh-executor equivalence tests: each case runs in a subprocess with an
8-virtual-device host platform (the main pytest process keeps the default
single device); bodies live in tests/mesh_exec_cases.py.

Covers the ISSUE-4 acceptance matrix: shard_map ring prefill == dense
oracle for DoP {2, 4} x {GQA, sliding window, softcap} (both ring
orderings), the engine e2e through the MeshExecutor with zero serial /
zero in-process-replay dispatches and zero mirror re-uploads, and
checkpoint/restore under the sharded per-device mirror — plus the ISSUE-5
decode matrix: SPMD paged decode == dense oracle for DoP {2, 4} x {GQA,
window, softcap} x {overlapped, barriered}, and engine decode through the
one-shard_map-program path with zero per-shard Python-loop merges — and
the ISSUE-6 batch-sharded decode matrix: the all_gather/psum_scatter
multi-master boundary == dense oracle on physically batch-sharded
operands, engine e2e through the in-program sampling + routed-KV path,
and the HLO dot-FLOP census showing per-rank decode FLOPs ~1/n of the
replicated program."""
import os
import pathlib
import subprocess
import sys

ROOT = pathlib.Path(__file__).parent.parent


def _run_case(case: str, devices: int = 8) -> None:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = str(ROOT / "src")
    out = subprocess.run(
        [sys.executable, str(ROOT / "tests" / "mesh_exec_cases.py"), case],
        env=env, capture_output=True, text=True, timeout=1200,
    )
    assert out.returncode == 0, out.stdout + "\n" + out.stderr
    marker = f"{case.replace('_', '-').upper()}-OK"
    assert marker in out.stdout, out.stdout


def test_mesh_ring_parity_matrix():
    _run_case("ring_parity")


def test_mesh_engine_e2e():
    _run_case("engine_e2e")


def test_mesh_checkpoint_restore():
    _run_case("checkpoint_restore")


def test_mesh_decode_parity_matrix():
    _run_case("decode_parity")


def test_mesh_decode_e2e():
    _run_case("decode_e2e")


def test_mesh_decode_shard_parity_matrix():
    _run_case("decode_shard_parity")


def test_mesh_decode_flop_census():
    _run_case("decode_flops")


def test_mesh_join_instance_recovery():
    _run_case("join_instance")


def test_mesh_unified_step():
    _run_case("unified")

"""Paged pool internals: page accounting under fragmentation, block_table
correctness (incl. after SWA eviction), OutOfSlots at exact-capacity
boundaries, checkpoint state round-trip."""
import numpy as np
import pytest

from repro.configs import REGISTRY, reduced
from repro.kvcache import DistributedKVPool, KVPool, OutOfSlots

CFG = reduced(REGISTRY["lwm-7b"])


def _decode_table(pool, rid):
    """Reconstruct a request's (positions, k, v) through block_table — the
    exact addressing contract the paged kernel uses."""
    table, lengths = pool.block_table([rid])
    n = int(lengths[0])
    P = pool.page_size
    slots = (table[0].astype(np.int64)[:, None] * P + np.arange(P)).reshape(-1)[:n]
    return pool.slot_pos[slots], pool.k[:, slots], pool.v[:, slots]


def test_paged_alloc_free_fragmentation_interleaved():
    """Interleaved alloc/free of many requests must never leak or double-book
    pages, and surviving requests' data must stay addressable via the block
    table."""
    P = 4
    pool = KVPool(CFG, 40 * P, store_values=True, page_size=P)
    rng = np.random.default_rng(0)
    n_attn = pool.n_attn
    live = {}  # rid -> (positions, k)
    next_rid = 0
    for step in range(200):
        if live and (rng.random() < 0.4 or pool.free_slots < 8 * P):
            rid = rng.choice(list(live))
            pos, _ = live.pop(rid)
            assert pool.free_request(rid) == len(pos)
        else:
            n = int(rng.integers(1, 11))
            if n > pool.free_slots:
                continue
            rid = next_rid
            next_rid += 1
            pos = list(range(n))
            k = rng.normal(size=(n_attn, n, CFG.n_kv_heads, CFG.head_dim))
            pool.write(rid, pos, k, k + 1)
            live[rid] = (pos, k.astype(np.float32))
    # accounting invariants
    assert pool.used == sum(len(p) for p, _ in live.values())
    owned = np.concatenate(
        [pool._reqs[rid].pages[: pool._reqs[rid].n_pages] for rid in live]
    ) if live else np.empty(0, np.int32)
    free = pool._free_pages[: pool._n_free_pages]
    both = np.concatenate([owned, free])
    assert len(np.unique(both)) == len(both) == pool.n_pages  # no leak/dup
    # data still addressable through the block table
    for rid, (pos, k) in live.items():
        tpos, kk, vv = _decode_table(pool, rid)
        np.testing.assert_array_equal(np.sort(tpos), pos)
        order = np.argsort(tpos, kind="stable")
        np.testing.assert_allclose(kk[:, order], k, atol=1e-6)
        np.testing.assert_allclose(vv[:, order], k + 1, atol=1e-6)


def test_block_table_after_free_positions_swa_eviction():
    """SWA eviction (free_positions) compacts the packed-page layout: the
    block table must keep addressing exactly the surviving tokens."""
    P = 4
    pool = KVPool(CFG, 8 * P, store_values=True, page_size=P)
    n = 14
    k = np.arange(n, dtype=np.float32)[None, :, None, None] * np.ones(
        (pool.n_attn, n, CFG.n_kv_heads, CFG.head_dim), np.float32
    )
    pool.write(1, list(range(n)), k, 10 * k)
    freed = pool.free_positions(1, [0, 1, 2, 3, 5])  # prefix + a hole
    assert freed == 5
    keep = [4] + list(range(6, n))
    tpos, kk, vv = _decode_table(pool, 1)
    np.testing.assert_array_equal(np.sort(tpos), keep)
    order = np.argsort(tpos, kind="stable")
    np.testing.assert_allclose(kk[0, order, 0, 0], keep)
    np.testing.assert_allclose(vv[0, order, 0, 0], [10 * p for p in keep])
    # 10 survivors -> 3 pages; 5 pages free again
    assert pool._reqs[1].n_pages == 3
    assert pool.free_slots == 5 * P
    # gather (migration path) agrees with the table view
    gpos, gk, _ = pool.gather(1)
    np.testing.assert_array_equal(gpos, keep)
    np.testing.assert_allclose(gk[0, :, 0, 0], keep)
    # evicting everything else returns the request's remaining pages
    assert pool.free_positions(1, keep) == len(keep)
    assert pool.used == 0 and pool.free_slots == 8 * P
    assert pool.block_table([1])[1][0] == 0


def test_out_of_slots_exact_capacity_boundaries():
    P = 4
    pool = KVPool(CFG, 3 * P, store_values=False, page_size=P)
    # fill to the exact page boundary
    pool.alloc(1, list(range(P)))
    pool.alloc(2, list(range(2 * P)))
    assert pool.free_slots == 0 and pool.used == 3 * P
    with pytest.raises(OutOfSlots):
        pool.alloc(3, [0])  # no free page, no slack anywhere
    # one token short of the boundary: tail slack belongs to request 2 only
    pool.free_request(2)
    pool.alloc(2, list(range(2 * P - 1)))
    assert pool.free_slots == 0  # conservative: no whole free page
    with pytest.raises(OutOfSlots):
        pool.alloc(3, [0])  # other requests cannot use 2's slack
    pool.alloc(2, [2 * P - 1])  # 2 itself can extend into its slack
    assert pool.used == 3 * P
    with pytest.raises(OutOfSlots):
        pool.alloc(2, [2 * P])  # now truly full, even for 2
    # freeing releases whole pages again
    assert pool.free_request(1) == P
    assert pool.free_slots == P
    pool.alloc(3, list(range(P)))


def test_page_size_one_token_exact_semantics():
    """page_size=1 keeps the legacy token-granular accounting bit-for-bit:
    free tokens are always allocatable regardless of fragmentation."""
    pool = KVPool(CFG, 8, store_values=False)  # default page_size=1
    pool.alloc(1, [0, 1, 2])
    pool.alloc(2, [0, 1])
    pool.free_positions(1, [1])  # a hole
    assert pool.free_slots == 4
    pool.alloc(3, list(range(4)))  # exactly the free tokens
    assert pool.used == 8
    with pytest.raises(OutOfSlots):
        pool.alloc(4, [0])


def test_state_dict_roundtrip_preserves_tables():
    P = 2
    pool = KVPool(CFG, 6 * P, store_values=False, page_size=P)
    pool.alloc(7, list(range(5)))
    pool.alloc(8, list(range(100, 103)))
    pool.free_positions(7, [0])
    state = pool.state_dict()
    t_before = pool.block_table([7, 8])
    pool2 = KVPool(CFG, 6 * P, store_values=False, page_size=P)
    pool2.load_state_dict(state)
    t_after = pool2.block_table([7, 8])
    np.testing.assert_array_equal(t_before[0], t_after[0])
    np.testing.assert_array_equal(t_before[1], t_after[1])
    assert pool2.used == pool.used and pool2.free_slots == pool.free_slots
    np.testing.assert_array_equal(pool2.slot_pos, pool.slot_pos)


def test_distributed_pool_page_size_plumbs_through():
    dp = DistributedKVPool(CFG, 3, 32, store_values=False, page_size=4)
    assert all(p.page_size == 4 for p in dp.pools)
    plan = dp.plan_placement(1, list(range(20)), [0, 1, 2])
    dp.place(plan)
    tables = [p.block_table([1]) for p in dp.pools]
    assert sum(int(l[0]) for _, l in tables) == 20
